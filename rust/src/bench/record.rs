//! `mergequant bench` — the versioned benchmark suite behind the
//! repo-root `BENCH_<n>.json` snapshots: Figure-3 decode throughput per
//! method, Table-2 prefill throughput, Table-3 memory accounting, the
//! PR-6 shared-prefix fleet axis (prefix cache on vs off against the
//! PR-5 paged baseline, DESIGN.md §14), the PR-7 bursty
//! mixed-priority axis (preemptive classes on vs off, DESIGN.md §15),
//! the PR-9 kernel axis (scalar vs best-SIMD GEMM GOPS + decode
//! tok/s, plus the dynamic-vs-channel-static quant-overhead arms,
//! DESIGN.md §17), and the PR-10 speculative axis (self-speculative
//! decode at draft_k ∈ {2, 4, 8} against the plain single-token
//! baseline, DESIGN.md §18).
//!
//! Counter-valued fields (prefill rows, hit rate, matched tokens, peak
//! concurrency, preemption counts, TTFT in forward calls) are
//! deterministic — identical on every machine — while wall-clock fields
//! (tok/s, TTFT in ms) are machine-dependent and refreshed with
//! `mergequant bench --record`.

use std::path::Path;
use std::time::Instant;

use crate::coordinator::router::dispatch::{Candidate, Dispatcher,
                                           Placement};
use crate::coordinator::{
    Event, GenerationParams, Request, Response, RouterConfig, Scheduler,
    SchedulerConfig,
};
use crate::engine::{memory, Engine, KvCache, KvDtype, Workspace};
use crate::util::json::{num, obj, s, Json};

use super::synthetic_model;

const METHODS: [&str; 4] = ["fp16", "rtn", "quarot", "mergequant"];

/// Fleet geometry: FLEET requests over one PREFIX_TOKS-token system
/// prompt, each with a private SUFFIX_TOKS-token tail. Sized so the
/// 24-block arena admits every lane when prefixes are shared but only
/// three when each lane prefills privately.
const FLEET: usize = 8;
const PREFIX_TOKS: usize = 96;
const SUFFIX_TOKS: usize = 8;
const MAX_NEW: usize = 16;

/// Router-axis geometry (DESIGN.md §16): SESSIONS multi-turn chats of
/// TURNS turns each. Every turn's prompt is the previous prompt plus
/// the previous completion plus TURN_TOKS fresh user tokens, so a turn
/// that lands on the replica that served the session before hits warm
/// prefix blocks; a turn that lands anywhere else re-prefills cold.
const SESSIONS: usize = 6;
const TURNS: usize = 3;
const BASE_TOKS: usize = 32;
const TURN_TOKS: usize = 8;
const CHAT_MAX_NEW: usize = 8;

/// Sharding-throughput arm: independent single-turn requests
/// round-robined across the fleet, every replica decoding on its own
/// thread.
const TP_REQS: usize = 16;
const TP_PROMPT_TOKS: usize = 48;
const TP_MAX_NEW: usize = 16;

/// Speculative-axis geometry (DESIGN.md §18): one greedy lane, a
/// 24-token prompt and 16 new tokens. With a full-depth self-draft
/// (`draft_layers: 0`) the draft IS the target, so every proposal is
/// accepted and the counters are exact functions of (prompt, max_new,
/// draft_k): 15 post-prefill tokens land in ⌈15/(k+1)⌉ target
/// forwards.
const SPEC_PROMPT_TOKS: usize = 24;
const SPEC_MAX_NEW: usize = 16;

fn method_engine(method: &str) -> Engine {
    Engine::new(synthetic_model(method, 64, 128, 2, 96))
}

/// Kernel-axis GEMM tile (DESIGN.md §17): large enough that the inner
/// i8 dot dominates, small enough for the fast suite.
const KERN_M: usize = 48;
const KERN_N: usize = 256;
const KERN_J: usize = 192;

/// Kernel axis: for every microkernel variant this host can run, pin
/// the dispatch table to it and measure the serial i8 GEMM and the
/// packed-INT4 (W4A4) GEMM in GOPS plus single-lane decode tok/s on
/// the channel-static synthetic bundle. The axis is its own
/// determinism witness: every variant's accumulator block must be
/// bitwise the scalar one (available() lists scalar first). The
/// previously active kernel is restored before returning.
fn kernel_axis(fast: bool) -> Json {
    use crate::quant::gemm::{gemm_i8, gemm_i8_packed4};
    use crate::quant::{pack, simd};
    let prev = simd::active().kind();
    let (m, n, j) = (KERN_M, KERN_N, KERN_J);
    let reps = if fast { 2 } else { 8 };
    let (pf, dec) = if fast { (32, 16) } else { (64, 64) };
    let mut rng = crate::util::rng::Rng::new(0xD0717);
    let xq: Vec<i8> =
        (0..m * n).map(|_| rng.usize(0, 256) as u8 as i8).collect();
    let wt: Vec<i8> =
        (0..j * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
    let mut packed = Vec::with_capacity(j * n.div_ceil(2));
    for c in 0..j {
        packed.extend(pack::pack_int4(&wt[c * n..(c + 1) * n]));
    }
    let ops = (2 * m * n * j) as f64;
    let mut arms = Vec::new();
    let mut pinned: Option<Vec<i32>> = None;
    for kind in simd::available() {
        assert!(simd::force(kind), "probed kernel must install");
        let mut acc = vec![0i32; m * j];
        let mut best_i8 = f64::INFINITY;
        for _ in 0..reps {
            let t = Instant::now();
            gemm_i8(&xq, &wt, m, n, j, &mut acc);
            best_i8 = best_i8.min(t.elapsed().as_secs_f64());
        }
        match &pinned {
            Some(base) => assert_eq!(&acc, base,
                "{} i8 GEMM diverged from scalar", kind.name()),
            None => pinned = Some(acc.clone()),
        }
        let mut scratch = Vec::new();
        let mut acc4 = vec![0i32; m * j];
        let mut best_p4 = f64::INFINITY;
        for _ in 0..reps {
            let t = Instant::now();
            gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch,
                            &mut acc4);
            best_p4 = best_p4.min(t.elapsed().as_secs_f64());
        }
        assert_eq!(Some(&acc4), pinned.as_ref(),
                   "{} packed GEMM diverged from scalar", kind.name());
        let decode = method_row("mergequant_static", pf, dec)
            .get("decode_tok_s")
            .and_then(Json::as_f64)
            .unwrap_or(0.0);
        arms.push(obj(vec![
            ("kernel", s(kind.name())),
            ("gemm_i8_gops", num(ops / best_i8 / 1e9)),
            ("gemm_w4a4_gops", num(ops / best_p4 / 1e9)),
            ("decode_tok_s", num(decode)),
        ]));
    }
    simd::force(prev);
    obj(vec![
        ("m", num(m as f64)),
        ("n", num(n as f64)),
        ("j", num(j as f64)),
        ("best", s(simd::best().kind().name())),
        ("arms", Json::Arr(arms)),
    ])
}

/// Dynamic-vs-static quant-overhead axis (Fig. 3): the synthetic
/// bundle with per-token dynamic o/down ("mergequant", the pre-§17
/// runtime) against per-channel static o/down ("mergequant_static",
/// zero per-token scale math). Wall-clock like every tok/s field.
fn quant_overhead_axis(pf: usize, dec: usize) -> Json {
    obj(vec![
        ("dynamic", method_row("mergequant", pf, dec)),
        ("channel_static", method_row("mergequant_static", pf, dec)),
    ])
}

/// Find the newest `BENCH_<n>.json` in `dir` with `n` strictly below
/// the current suite version and render a one-line delta: the fig3
/// mergequant decode throughput (wall-clock — "n/a" in committed
/// snapshots, which null machine-local fields) and the shared-prefix
/// prefill-row counter (deterministic, so a drift here is a real
/// regression). `None` when no earlier snapshot is readable.
pub fn delta_vs_previous(cur: &Json, dir: &Path) -> Option<String> {
    let cur_v = cur.get("version").and_then(Json::as_f64)? as i64;
    let mut best: Option<(i64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()?.flatten() {
        let name = entry.file_name();
        let v: i64 = match name
            .to_string_lossy()
            .strip_prefix("BENCH_")
            .and_then(|r| r.strip_suffix(".json"))
            .and_then(|d| d.parse().ok())
        {
            Some(v) => v,
            None => continue,
        };
        if v < cur_v && best.as_ref().is_none_or(|(b, _)| v > *b) {
            best = Some((v, entry.path()));
        }
    }
    let (v, path) = best?;
    let prev =
        Json::parse(&std::fs::read_to_string(&path).ok()?).ok()?;
    let decode = |j: &Json| -> Option<f64> {
        if let Some(Json::Arr(ms)) = j.get("methods") {
            for m in ms {
                if m.get("method").and_then(Json::as_str)
                    == Some("mergequant")
                {
                    return m.get("decode_tok_s").and_then(Json::as_f64);
                }
            }
        }
        None
    };
    let rows = |j: &Json| {
        j.get("prefix_fleet")
            .and_then(|p| p.get("shared"))
            .and_then(|sh| sh.get("prefill_rows"))
            .and_then(Json::as_f64)
    };
    let fmt = |x: Option<f64>| match x {
        Some(x) => format!("{x:.1}"),
        None => "n/a".into(),
    };
    Some(format!(
        "delta vs BENCH_{v}.json: mergequant decode {} tok/s \
         (prev {}), shared prefill_rows {} (prev {})",
        fmt(decode(cur)),
        fmt(decode(&prev)),
        fmt(rows(cur)),
        fmt(rows(&prev))
    ))
}

/// Per-method decode + prefill throughput (Figure 3 / Table 2 axes) on
/// the synthetic bundle: one lane, `pf` prompt tokens, `dec` decode
/// steps, best-of-3 wall clock.
fn method_row(method: &str, pf: usize, dec: usize) -> Json {
    let engine = method_engine(method);
    let cfg = engine.config().clone();
    let prompt: Vec<u32> =
        (0..pf).map(|t| 3 + (t as u32 * 7) % 90).collect();
    let mut prefill_s = f64::INFINITY;
    let mut decode_s = f64::INFINITY;
    for _ in 0..3 {
        let mut ws = Workspace::new();
        let mut c = KvCache::new(cfg.n_layers, pf + dec + 1, cfg.d_model);
        let t0 = Instant::now();
        engine.prefill(&prompt, &mut c, &mut ws).unwrap();
        prefill_s = prefill_s.min(t0.elapsed().as_secs_f64());
        let t1 = Instant::now();
        for i in 0..dec {
            let tok = 3 + (i as u32 * 13) % 90;
            let mut refs = [&mut c];
            engine.decode_batch(&[tok], &mut refs, &mut ws).unwrap();
        }
        decode_s = decode_s.min(t1.elapsed().as_secs_f64());
    }
    obj(vec![
        ("method", s(method)),
        ("prefill_tok_s", num(pf as f64 / prefill_s)),
        ("decode_tok_s", num(dec as f64 / decode_s)),
    ])
}

/// Table-3 memory accounting rows (deterministic byte totals).
fn memory_rows() -> Json {
    let mut rows = Vec::new();
    for method in ["fp16", "mergequant"] {
        let engine = method_engine(method);
        for kv in [KvDtype::F32, KvDtype::Int8] {
            let mb = memory::account_model(&engine.model, 8, 2048, kv);
            rows.push(obj(vec![
                ("method", s(method)),
                ("kv", s(kv.as_str())),
                ("weights_bytes", num(mb.weights as f64)),
                ("kv_bytes", num(mb.kv_cache as f64)),
                ("total_bytes", num(mb.total() as f64)),
            ]));
        }
    }
    Json::Arr(rows)
}

fn fleet_scheduler(prefix: bool) -> Scheduler {
    let engine = method_engine("mergequant");
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 16,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 24,
            max_seq: 256,
            max_prefills_per_iter: 1,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: prefix,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

/// Arena of exactly 4 blocks × 16 tokens for the preemption axis: the
/// low-class lane's decode growth plus the 33-token high-class prompt
/// cannot coexist, so the classed run must preempt and the unclassed
/// run must queue.
fn preempt_scheduler() -> Scheduler {
    let engine = method_engine("mergequant");
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 4,
            max_seq: 64,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

/// One bursty mixed-priority run (DESIGN.md §15): a long low-class
/// decode lane holds the arena, then a high-class request bursts in.
/// `classed` gives the burst priority 2 (it preempts the lane and is
/// served immediately); unclassed it queues behind the whole decode.
/// Deterministic fields: `preemptions` (1 vs 0), `prefill_rows`
/// (66 = 16 + 33 + 17-token resume recompute, vs 49), `generated`
/// (44 both — preemption changes scheduling, never streams),
/// `ttft_calls_high` (the forward call that sampled the burst's first
/// token: 3 vs 41) and `slo_violations` (1 — the low lane carries an
/// impossible deadline in both runs).
fn preempt_run(classed: bool) -> Json {
    let mut sched = preempt_scheduler();
    let low_prompt: Vec<u32> =
        (0..16u32).map(|t| 3 + (t * 7) % 90).collect();
    let high_prompt: Vec<u32> =
        (0..33u32).map(|t| 5 + (t * 3) % 90).collect();
    let t0 = Instant::now();
    sched.submit(Request::with_params(0, low_prompt, GenerationParams {
        priority: 0,
        deadline_ms: Some(0),
        ..GenerationParams::greedy(40)
    })).unwrap();
    sched.step(); // prefill + first token (1 block)
    sched.step(); // second token claims the lane's second block
    sched.take_events();
    sched.submit(Request::with_params(1, high_prompt, GenerationParams {
        priority: if classed { 2 } else { 0 },
        ..GenerationParams::greedy(4)
    })).unwrap();
    let mut ttft_calls_high = 0u64;
    while sched.has_work() {
        sched.step();
        for ev in sched.take_events() {
            if ttft_calls_high == 0
                && matches!(ev, Event::Token { id: 1, .. })
            {
                // forward_calls was bumped by the call that produced
                // this frame — TTFT measured in engine calls, not ms.
                ttft_calls_high = sched.metrics.forward_calls;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = &sched.metrics;
    obj(vec![
        ("classed", Json::Bool(classed)),
        ("preemptions", num(m.preemptions as f64)),
        ("slo_violations", num(m.slo_violations as f64)),
        ("prefill_rows", num(m.prefill_rows as f64)),
        ("generated", num(m.generated_tokens as f64)),
        ("ttft_calls_high", num(ttft_calls_high as f64)),
        ("tok_s", num(m.generated_tokens as f64 / wall)),
        ("ttft_p50_ms", num(m.ttft_summary().p50 * 1e3)),
    ])
}

/// One replica of the router axis: the whole-box arena (256 blocks ×
/// 16 tokens) split by [`RouterConfig::per_replica`] — the same split
/// `mergequant route` applies.
fn router_replica_scheduler(replicas: usize) -> Scheduler {
    let whole_box = SchedulerConfig {
        max_batch: 8,
        kv_slabs: 0,
        kv_block: 16,
        kv_blocks: 256,
        max_seq: 128,
        max_prefills_per_iter: 1,
        queue_cap: 64,
        prefill_chunk: 0,
        threads: 1,
        kv_dtype: KvDtype::F32,
        prefix_cache: true,
        prefix_cache_blocks: 0,
        max_decode_latency: 0,
        speculative: false,
        draft_k: 0,
        draft_layers: 0,
    };
    let per = RouterConfig::new(replicas, whole_box).per_replica();
    Scheduler::new(method_engine("mergequant"), per)
}

/// Session base prompts start on distinct tokens so no two sessions
/// ever share a KV block — every prefix hit below is a same-session
/// hit, never accidental cross-session sharing.
fn chat_base(session: usize) -> Vec<u32> {
    (0..BASE_TOKS)
        .map(|j| 3 + ((session * 31 + j * 7) % 89) as u32)
        .collect()
}

fn chat_turn(session: usize, turn: usize) -> Vec<u32> {
    (0..TURN_TOKS)
        .map(|j| 5 + ((session * 13 + turn * 17 + j * 5) % 89) as u32)
        .collect()
}

/// One router-axis arm: SESSIONS chats × TURNS sequential turns over
/// `replicas` synchronously-stepped scheduler replicas — the exact
/// dispatch code `mergequant route` runs ([`Dispatcher`]), driven
/// deterministically (no gateway threads, no wall-clock in any
/// counter). `affinity` routes through the session-pinning dispatcher;
/// the baseline shuffles placement `(session + turn) % replicas`, so
/// consecutive turns always land on different replicas and re-prefill
/// cold. Returns the axis row plus every completion in submission
/// order, for cross-arm bitwise comparison: placement must never
/// change stream content.
fn router_run(replicas: usize, affinity: bool)
              -> (Json, Vec<Vec<u32>>) {
    let mut scheds: Vec<Scheduler> = (0..replicas)
        .map(|_| router_replica_scheduler(replicas))
        .collect();
    let mut dispatcher = Dispatcher::new(true);
    let mut dispatched = vec![0u64; replicas];
    let (mut hits, mut misses) = (0u64, 0u64);
    let mut prompts: Vec<Vec<u32>> =
        (0..SESSIONS).map(chat_base).collect();
    let mut streams: Vec<Vec<u32>> = Vec::new();
    let t0 = Instant::now();
    let mut next_id = 0u64;
    for turn in 0..TURNS {
        for (session, prompt) in prompts.iter_mut().enumerate() {
            if turn > 0 {
                prompt.extend(chat_turn(session, turn));
            }
            let sid = format!("chat-{session}");
            let idx = if affinity {
                let cands: Vec<Candidate> = scheds
                    .iter()
                    .enumerate()
                    .map(|(i, sc)| {
                        let mut stats = sc.stats();
                        stats.replica = i;
                        Candidate { generation: 0, stats }
                    })
                    .collect();
                let (idx, placement) = dispatcher
                    .choose(Some(&sid), &cands)
                    .expect("non-empty fleet");
                match placement {
                    Placement::AffinityHit => hits += 1,
                    Placement::Pinned | Placement::Repinned => {
                        misses += 1;
                    }
                    Placement::LeastLoaded => {}
                }
                idx
            } else {
                (session + turn) % replicas
            };
            dispatched[idx] += 1;
            let params = GenerationParams {
                session: Some(sid),
                ..GenerationParams::greedy(CHAT_MAX_NEW)
            };
            scheds[idx]
                .submit(Request::with_params(next_id, prompt.clone(),
                                             params))
                .unwrap();
            next_id += 1;
            let rs = scheds[idx].run_to_completion();
            assert_eq!(rs.len(), 1);
            assert!(rs[0].error.is_none(),
                    "chat turn failed: {:?}", rs[0].error);
            prompt.extend(&rs[0].tokens);
            streams.push(rs[0].tokens.clone());
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mut lookups, mut phits, mut matched) = (0u64, 0u64, 0u64);
    let (mut prefill, mut generated) = (0u64, 0u64);
    for sc in &scheds {
        lookups += sc.metrics.prefix_lookups;
        phits += sc.metrics.prefix_hits;
        matched += sc.metrics.prefix_matched_tokens;
        prefill += sc.metrics.prefill_rows;
        generated += sc.metrics.generated_tokens;
    }
    let row = obj(vec![
        ("replicas", num(replicas as f64)),
        ("affinity", Json::Bool(affinity)),
        ("dispatch", Json::Arr(
            dispatched.iter().map(|&d| num(d as f64)).collect())),
        ("affinity_hits", num(hits as f64)),
        ("affinity_misses", num(misses as f64)),
        ("prefix_lookups", num(lookups as f64)),
        ("prefix_hits", num(phits as f64)),
        ("prefix_hit_rate", num(if lookups == 0 {
            0.0
        } else {
            phits as f64 / lookups as f64
        })),
        ("matched_tokens", num(matched as f64)),
        ("prefill_rows", num(prefill as f64)),
        ("generated", num(generated as f64)),
        ("tok_s", num(generated as f64 / wall)),
    ]);
    (row, streams)
}

/// Sharding-throughput arm: TP_REQS independent prompts round-robined
/// across `replicas` schedulers, each replica run to completion on its
/// own thread. Only `tok_s` is wall-clock; the counters and streams
/// stay deterministic. Returns streams ordered by request id.
fn router_throughput(replicas: usize) -> (Json, Vec<Vec<u32>>) {
    let mut scheds: Vec<Scheduler> = (0..replicas)
        .map(|_| router_replica_scheduler(replicas))
        .collect();
    for i in 0..TP_REQS {
        let prompt: Vec<u32> = (0..TP_PROMPT_TOKS)
            .map(|j| 3 + ((i * 29 + j * 7) % 89) as u32)
            .collect();
        scheds[i % replicas]
            .submit(Request::new(i as u64, prompt, TP_MAX_NEW))
            .unwrap();
    }
    let t0 = Instant::now();
    let mut responses: Vec<Response> = std::thread::scope(|scope| {
        let handles: Vec<_> = scheds
            .iter_mut()
            .map(|sc| scope.spawn(move || sc.run_to_completion()))
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("replica thread"))
            .collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), TP_REQS);
    for r in &responses {
        assert!(r.error.is_none(), "lane failed: {:?}", r.error);
    }
    let generated: u64 =
        scheds.iter().map(|sc| sc.metrics.generated_tokens).sum();
    let row = obj(vec![
        ("replicas", num(replicas as f64)),
        ("requests", num(TP_REQS as f64)),
        ("generated", num(generated as f64)),
        ("tok_s", num(generated as f64 / wall)),
    ]);
    (row, responses.into_iter().map(|r| r.tokens).collect())
}

/// One shared-prefix fleet run; returns the axis row. Deterministic
/// fields: `prefill_rows` (832 unshared vs 160 shared), `hit_rate`
/// (0.875: 7 of 8 lanes), `matched_tokens` (7 × 96), `peak_active`
/// (8 shared vs 3 — the arena fits every lane only when the 96-token
/// prefix is stored once).
fn fleet_run(prefix: bool) -> Json {
    let mut sched = fleet_scheduler(prefix);
    let t0 = Instant::now();
    for i in 0..FLEET as u64 {
        let mut prompt: Vec<u32> =
            (0..PREFIX_TOKS).map(|t| 3 + (t as u32 * 5) % 90).collect();
        prompt.extend(
            (0..SUFFIX_TOKS).map(|t| 7 + (t as u32 * 11 + i as u32) % 90));
        sched.submit(Request::new(i, prompt, MAX_NEW)).unwrap();
    }
    let rs = sched.run_to_completion();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(rs.len(), FLEET);
    for r in &rs {
        assert!(r.error.is_none(), "fleet lane failed: {:?}", r.error);
    }
    let m = &sched.metrics;
    obj(vec![
        ("prefix_cache", Json::Bool(prefix)),
        ("requests", num(FLEET as f64)),
        ("prefill_rows", num(m.prefill_rows as f64)),
        ("peak_active", num(m.peak_active as f64)),
        ("hit_rate", num(m.prefix_hit_rate())),
        ("matched_tokens", num(m.prefix_matched_tokens as f64)),
        ("shared_blocks_peak", num(m.prefix_shared_blocks as f64)),
        ("bytes_saved_peak", num(m.prefix_bytes_saved as f64)),
        ("tok_s", num(m.generated_tokens as f64 / wall)),
        ("ttft_p50_ms", num(m.ttft_summary().p50 * 1e3)),
    ])
}

/// Single-lane arena for the speculative axis: `draft_k == 0` is the
/// plain (non-speculative) PR-9 decode baseline; any other k turns the
/// full-depth self-draft lane on.
fn spec_scheduler(draft_k: usize) -> Scheduler {
    let engine = method_engine("mergequant");
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 8,
            max_seq: 64,
            max_prefills_per_iter: 1,
            queue_cap: 16,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: draft_k > 0,
            draft_k,
            draft_layers: 0,
        },
    )
}

/// One speculative-axis arm; returns the row plus the emitted stream
/// (speculation must be bitwise invisible — every arm is compared to
/// the `draft_k == 0` baseline). Deterministic fields: at full-depth
/// self-draft acceptance is exactly 1.0, `decode_forwards` is
/// ⌈15/(k+1)⌉ (15, 5, 3, 2 for k = 0, 2, 4, 8) and `draft_forwards`
/// is one per proposed token (0, 10, 12, 13); only `tok_s` (and the
/// derived `decode_speedup`) are wall-clock.
fn spec_run(draft_k: usize) -> (Json, Vec<u32>) {
    let mut sched = spec_scheduler(draft_k);
    let prompt: Vec<u32> = (0..SPEC_PROMPT_TOKS)
        .map(|t| 3 + (t as u32 * 7) % 90)
        .collect();
    let t0 = Instant::now();
    sched.submit(Request::new(0, prompt, SPEC_MAX_NEW)).unwrap();
    let rs = sched.run_to_completion();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(rs.len(), 1);
    assert!(rs[0].error.is_none(),
            "speculative lane failed: {:?}", rs[0].error);
    let m = &sched.metrics;
    let row = obj(vec![
        ("draft_k", num(draft_k as f64)),
        ("decode_forwards", num(m.decode_iterations as f64)),
        ("draft_forwards", num(m.draft_forwards as f64)),
        ("verify_forwards", num(m.verify_forwards as f64)),
        ("draft_proposed", num(m.draft_proposed as f64)),
        ("draft_accepted", num(m.draft_accepted as f64)),
        ("acceptance_rate", num(m.acceptance_rate())),
        ("tokens_per_forward", num(m.tokens_per_forward())),
        ("generated", num(m.generated_tokens as f64)),
        ("tok_s", num(m.generated_tokens as f64 / wall)),
    ]);
    (row, rs[0].tokens.clone())
}

/// Run the whole suite; `fast` shrinks the wall-clock axes only — the
/// deterministic counters are identical either way.
pub fn run_suite(fast: bool) -> Json {
    let (pf, dec) = if fast { (64, 16) } else { (256, 64) };
    let methods: Vec<Json> =
        METHODS.iter().map(|m| method_row(m, pf, dec)).collect();
    let off = fleet_run(false);
    let on = fleet_run(true);
    let saved_rows = off.get("prefill_rows").and_then(Json::as_f64)
        .unwrap_or(0.0)
        - on.get("prefill_rows").and_then(Json::as_f64).unwrap_or(0.0);
    let p_on = preempt_run(true);
    let p_off = preempt_run(false);
    let calls_saved = p_off.get("ttft_calls_high")
        .and_then(Json::as_f64).unwrap_or(0.0)
        - p_on.get("ttft_calls_high").and_then(Json::as_f64)
            .unwrap_or(0.0);
    // Router axis (DESIGN.md §16): the suite is its own determinism
    // witness — every arm must produce bitwise-identical completions,
    // because routing decides placement, never stream content.
    let (r1, chat_streams) = router_run(1, true);
    let (r2, a2) = router_run(2, true);
    let (r4, a4) = router_run(4, true);
    let (h2, b2) = router_run(2, false);
    let (h4, b4) = router_run(4, false);
    for (arm, st) in [("affinity-2", &a2), ("affinity-4", &a4),
                      ("shuffle-2", &b2), ("shuffle-4", &b4)] {
        assert_eq!(st, &&chat_streams,
                   "routing changed stream content ({arm})");
    }
    let (tp1, tp_streams) = router_throughput(1);
    let (tp2, u2) = router_throughput(2);
    let (tp4, u4) = router_throughput(4);
    for (arm, st) in [("throughput-2", &u2), ("throughput-4", &u4)] {
        assert_eq!(st, &&tp_streams,
                   "sharding changed stream content ({arm})");
    }
    // Speculative axis (DESIGN.md §18): every arm's stream must be
    // bitwise the non-speculative baseline's — the suite is its own
    // determinism witness here too.
    let (sp_base, sp_stream) = spec_run(0);
    let base_tok_s =
        sp_base.get("tok_s").and_then(Json::as_f64).unwrap_or(0.0);
    let mut sp_arms = Vec::new();
    for k in [2usize, 4, 8] {
        let (mut row, st) = spec_run(k);
        assert_eq!(st, sp_stream,
                   "speculation changed stream content (draft_k={k})");
        let tok_s =
            row.get("tok_s").and_then(Json::as_f64).unwrap_or(0.0);
        if let Json::Obj(m) = &mut row {
            m.insert("decode_speedup".into(),
                     num(if base_tok_s > 0.0 {
                         tok_s / base_tok_s
                     } else {
                         0.0
                     }));
        }
        sp_arms.push(row);
    }
    obj(vec![
        ("suite", s("mergequant-bench")),
        ("version", num(10.0)),
        ("fast", Json::Bool(fast)),
        ("model", s("synthetic d64 ff128 L2 v96")),
        ("methods", Json::Arr(methods)),
        ("memory", memory_rows()),
        ("kernels", kernel_axis(fast)),
        ("quant_overhead", quant_overhead_axis(pf, dec)),
        ("speculative", obj(vec![
            ("prompt_toks", num(SPEC_PROMPT_TOKS as f64)),
            ("max_new", num(SPEC_MAX_NEW as f64)),
            ("draft_layers", num(0.0)),
            ("baseline", sp_base),
            ("arms", Json::Arr(sp_arms)),
        ])),
        ("prefix_fleet", obj(vec![
            ("prefix_toks", num(PREFIX_TOKS as f64)),
            ("suffix_toks", num(SUFFIX_TOKS as f64)),
            ("max_new", num(MAX_NEW as f64)),
            ("unshared", off),
            ("shared", on),
            ("prefill_rows_saved", num(saved_rows)),
        ])),
        ("preempt_fleet", obj(vec![
            ("low_prompt_toks", num(16.0)),
            ("low_max_new", num(40.0)),
            ("high_prompt_toks", num(33.0)),
            ("high_max_new", num(4.0)),
            ("classed", p_on),
            ("unclassed", p_off),
            ("high_ttft_calls_saved", num(calls_saved)),
        ])),
        ("router_fleet", obj(vec![
            ("sessions", num(SESSIONS as f64)),
            ("turns", num(TURNS as f64)),
            ("base_toks", num(BASE_TOKS as f64)),
            ("turn_toks", num(TURN_TOKS as f64)),
            ("max_new", num(CHAT_MAX_NEW as f64)),
            ("affinity", Json::Arr(vec![r1, r2, r4])),
            ("shuffle", Json::Arr(vec![h2, h4])),
            ("throughput", obj(vec![
                ("requests", num(TP_REQS as f64)),
                ("prompt_toks", num(TP_PROMPT_TOKS as f64)),
                ("max_new", num(TP_MAX_NEW as f64)),
                ("arms", Json::Arr(vec![tp1, tp2, tp4])),
            ])),
        ])),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_axis_counters_are_the_committed_numbers() {
        // Pin the deterministic fields the committed BENCH_6.json
        // carries: an 8-lane fleet over a 96-token prefix prefills
        // 832 rows unshared vs 160 shared (7 × 96 = 672 saved), hits
        // 7/8, and only fits all 8 lanes concurrently when shared.
        let off = fleet_run(false);
        let on = fleet_run(true);
        let f = |j: &Json, k: &str| {
            j.get(k).and_then(Json::as_f64).unwrap()
        };
        assert_eq!(f(&off, "prefill_rows"), 832.0);
        assert_eq!(f(&on, "prefill_rows"), 160.0);
        assert_eq!(f(&on, "hit_rate"), 0.875);
        assert_eq!(f(&on, "matched_tokens"), 672.0);
        assert_eq!(f(&on, "peak_active"), 8.0);
        assert!(f(&off, "peak_active") <= 3.0,
                "unshared arena must throttle admission");
        assert!(f(&on, "ttft_p50_ms") >= 0.0);
    }

    #[test]
    fn router_axis_counters_are_the_committed_numbers() {
        // Pin the deterministic fields the committed BENCH_8.json
        // carries. 6 sessions × 3 turns with affinity: every turn
        // after a session's first is a pin hit (12 hits / 6 misses)
        // landing on warm prefix blocks (12 of 18 lookups hit) —
        // independent of fleet width. The shuffle baseline only hits
        // when (session + turn) mod replicas wraps a turn back onto a
        // replica that served the session before: 2 replicas wrap
        // turn 2 onto turn 0's replica (6 hits), 4 replicas never
        // wrap (0).
        let f = |j: &Json, k: &str| {
            j.get(k).and_then(Json::as_f64).unwrap()
        };
        let (r1, base) = router_run(1, true);
        let (r2, a2) = router_run(2, true);
        let (r4, a4) = router_run(4, true);
        for r in [&r1, &r2, &r4] {
            assert_eq!(f(r, "affinity_hits"), 12.0);
            assert_eq!(f(r, "affinity_misses"), 6.0);
            assert_eq!(f(r, "prefix_lookups"), 18.0);
            assert_eq!(f(r, "prefix_hits"), 12.0);
            assert_eq!(f(r, "generated"), 144.0,
                       "every turn decodes exactly max_new tokens");
        }
        // Idle-fleet dispatch spreads sessions: warm prefix blocks
        // count as held KV, so the least-loaded tie-break never dumps
        // every session on replica 0.
        let spread = |j: &Json| {
            let Some(Json::Arr(d)) = j.get("dispatch") else {
                panic!("dispatch must be an array");
            };
            assert!(d.iter().all(|v| v.as_f64().unwrap() > 0.0),
                    "idle-fleet dispatch must use every replica");
        };
        spread(&r2);
        spread(&r4);
        let (h2, b2) = router_run(2, false);
        let (h4, b4) = router_run(4, false);
        assert_eq!(f(&h2, "affinity_hits"), 0.0);
        assert_eq!(f(&h2, "prefix_hits"), 6.0);
        assert_eq!(f(&h4, "prefix_hits"), 0.0);
        assert_eq!(f(&h4, "matched_tokens"), 0.0);
        // Affinity lands strictly more warm-prefix tokens than any
        // shuffle (exact totals are block-granular — not pinned).
        assert!(f(&r2, "matched_tokens") > f(&h2, "matched_tokens"));
        assert!(f(&h2, "matched_tokens") > 0.0);
        assert!(f(&r2, "prefill_rows") < f(&h2, "prefill_rows"));
        // Placement decides where a stream runs, never its content.
        for st in [&a2, &a4, &b2, &b4] {
            assert_eq!(st, &base);
        }
    }

    #[test]
    fn router_throughput_streams_are_placement_invariant() {
        let (t1, base) = router_throughput(1);
        let (t2, u2) = router_throughput(2);
        let (t4, u4) = router_throughput(4);
        let f = |j: &Json, k: &str| {
            j.get(k).and_then(Json::as_f64).unwrap()
        };
        for t in [&t1, &t2, &t4] {
            assert_eq!(f(t, "generated"),
                       (TP_REQS * TP_MAX_NEW) as f64);
        }
        assert_eq!(u2, base);
        assert_eq!(u4, base);
    }

    #[test]
    fn kernel_axis_covers_the_host_and_agrees_bitwise() {
        // The bitwise scalar-vs-variant agreement is asserted inside
        // kernel_axis itself; here pin the structure: one arm per
        // host-available variant, scalar first, positive GOPS.
        let ax = kernel_axis(true);
        let Some(Json::Arr(arms)) = ax.get("arms") else {
            panic!("kernel axis must carry an arms array");
        };
        assert_eq!(arms.len(), crate::quant::simd::available().len());
        assert_eq!(arms[0].get("kernel").and_then(Json::as_str),
                   Some("scalar"));
        for a in arms {
            for k in ["gemm_i8_gops", "gemm_w4a4_gops", "decode_tok_s"] {
                assert!(a.get(k).and_then(Json::as_f64).unwrap() > 0.0,
                        "{k} must be positive");
            }
        }
    }

    #[test]
    fn quant_overhead_axis_names_both_arms() {
        let ax = quant_overhead_axis(16, 4);
        let m = |arm: &str| {
            ax.get(arm)
                .and_then(|a| a.get("method"))
                .and_then(Json::as_str)
                .map(String::from)
        };
        assert_eq!(m("dynamic").as_deref(), Some("mergequant"));
        assert_eq!(m("channel_static").as_deref(),
                   Some("mergequant_static"));
    }

    #[test]
    fn delta_line_reads_newest_older_snapshot() {
        let dir = std::env::temp_dir()
            .join(format!("mq_delta_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("BENCH_7.json"), r#"{"version":7}"#)
            .unwrap();
        std::fs::write(
            dir.join("BENCH_8.json"),
            r#"{"version":8,"methods":[{"method":"mergequant",
                "decode_tok_s":null}],
                "prefix_fleet":{"shared":{"prefill_rows":160}}}"#,
        )
        .unwrap();
        let cur = obj(vec![
            ("version", num(9.0)),
            ("methods", Json::Arr(vec![obj(vec![
                ("method", s("mergequant")),
                ("decode_tok_s", num(100.0)),
            ])])),
            ("prefix_fleet", obj(vec![("shared", obj(vec![
                ("prefill_rows", num(160.0)),
            ]))])),
        ]);
        let line = delta_vs_previous(&cur, &dir).unwrap();
        assert!(line.contains("BENCH_8.json"), "{line}");
        assert!(line.contains("100.0"), "{line}");
        assert!(line.contains("prev n/a"), "{line}");
        assert!(line.contains("160.0 (prev 160.0)"), "{line}");
        // Same-or-newer snapshots are never a baseline.
        let v7 = obj(vec![("version", num(7.0))]);
        assert!(delta_vs_previous(&v7, &dir).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn speculative_axis_counters_are_the_committed_numbers() {
        // Pin the deterministic fields the committed BENCH_10.json
        // carries. One lane, 24-token prompt, 16 new tokens: the
        // prefill emits the first token, the remaining 15 land in
        // ⌈15/(k+1)⌉ verify forwards (the last tick clamps its draft
        // to the tokens left), every full-depth proposal is accepted,
        // and the stream is bitwise the non-speculative baseline's.
        let f = |j: &Json, k: &str| {
            j.get(k).and_then(Json::as_f64).unwrap()
        };
        let (base, stream) = spec_run(0);
        assert_eq!(f(&base, "decode_forwards"), 15.0);
        assert_eq!(f(&base, "draft_forwards"), 0.0);
        assert_eq!(f(&base, "tokens_per_forward"), 1.0);
        assert_eq!(f(&base, "generated"), 16.0);
        for (k, want_dec, want_draft, want_tpf) in
            [(2usize, 5.0, 10.0, 3.0),
             (4, 3.0, 12.0, 5.0),
             (8, 2.0, 13.0, 7.5)]
        {
            let (row, st) = spec_run(k);
            assert_eq!(st, stream,
                       "speculation changed the stream (draft_k={k})");
            assert_eq!(f(&row, "decode_forwards"), want_dec,
                       "decode_forwards at draft_k={k}");
            assert_eq!(f(&row, "verify_forwards"), want_dec,
                       "verify_forwards at draft_k={k}");
            assert_eq!(f(&row, "draft_forwards"), want_draft,
                       "draft_forwards at draft_k={k}");
            assert_eq!(f(&row, "acceptance_rate"), 1.0,
                       "full-depth self-draft at draft_k={k}");
            assert_eq!(f(&row, "tokens_per_forward"), want_tpf,
                       "tokens_per_forward at draft_k={k}");
            assert_eq!(f(&row, "generated"), 16.0);
        }
    }

    #[test]
    fn preempt_axis_counters_are_the_committed_numbers() {
        // Pin the deterministic fields the committed BENCH_7.json
        // carries. Classed: the burst preempts the low lane at its
        // arrival call (first token on forward call 3) and the resume
        // recomputes 17 rows (66 total prefill rows). Unclassed: the
        // burst waits out the full 40-token decode (first token on
        // call 41, 49 prefill rows). Both runs generate the identical
        // 44 tokens and count the low lane's impossible deadline once.
        let on = preempt_run(true);
        let off = preempt_run(false);
        let f = |j: &Json, k: &str| {
            j.get(k).and_then(Json::as_f64).unwrap()
        };
        assert_eq!(f(&on, "preemptions"), 1.0);
        assert_eq!(f(&on, "prefill_rows"), 66.0);
        assert_eq!(f(&on, "ttft_calls_high"), 3.0);
        assert_eq!(f(&off, "preemptions"), 0.0);
        assert_eq!(f(&off, "prefill_rows"), 49.0);
        assert_eq!(f(&off, "ttft_calls_high"), 41.0);
        for run in [&on, &off] {
            assert_eq!(f(run, "generated"), 44.0,
                       "scheduling must never change what is generated");
            assert_eq!(f(run, "slo_violations"), 1.0);
        }
    }
}
