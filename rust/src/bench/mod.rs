//! Bench harness (criterion substitute): named measurements with warmup,
//! adaptive iteration counts, and paper-style table printing. Every
//! `rust/benches/*.rs` binary (one per paper table/figure) is built on
//! this and appends machine-readable JSON lines to
//! `artifacts/bench_results.jsonl` for EXPERIMENTS.md.

pub mod record;

use std::io::Write as _;
use std::time::Duration;

use crate::util::json::{num, obj, s, Json};
use crate::util::stats::{summarize, time_adaptive, Summary};

pub struct Bench {
    pub name: String,
    rows: Vec<(String, Summary, f64)>, // (label, timing, aux metric)
    min_time: Duration,
    max_iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let fast = std::env::var("MQ_BENCH_FAST").is_ok();
        Bench {
            name: name.into(),
            rows: Vec::new(),
            min_time: if fast { Duration::from_millis(50) }
                      else { Duration::from_millis(300) },
            max_iters: if fast { 10 } else { 200 },
        }
    }

    /// Measure a closure; returns the mean seconds.
    pub fn measure<F: FnMut()>(&mut self, label: &str, f: F) -> f64 {
        let times = time_adaptive(self.min_time, self.max_iters, f);
        let s = summarize(&times);
        let mean = s.mean;
        self.rows.push((label.to_string(), s, f64::NAN));
        eprintln!("  [{}] {label}: {:.3} ms (p50 {:.3} ms, n={})",
                  self.name, mean * 1e3,
                  self.rows.last().unwrap().1.p50 * 1e3,
                  self.rows.last().unwrap().1.n);
        mean
    }

    /// Record a non-timing metric row (accuracy, memory, speedup…).
    pub fn record(&mut self, label: &str, value: f64) {
        let mut s = Summary::default();
        s.mean = value;
        s.n = 1;
        self.rows.push((label.to_string(), s, value));
        eprintln!("  [{}] {label}: {value:.4}", self.name);
    }

    /// Print a paper-style table and persist JSON lines.
    pub fn finish(self, header: &str) {
        println!("\n=== {} — {header} ===", self.name);
        for (label, s, aux) in &self.rows {
            if aux.is_nan() {
                println!("{label:<48} {:>10.4} ms  (p50 {:.4}, p90 {:.4})",
                         s.mean * 1e3, s.p50 * 1e3, s.p90 * 1e3);
            } else {
                println!("{label:<48} {aux:>12.4}");
            }
        }
        let path = crate::artifacts_dir().join("bench_results.jsonl");
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
        {
            for (label, sum, aux) in &self.rows {
                let j = obj(vec![
                    ("bench", s(&self.name)),
                    ("label", s(label)),
                    ("mean_s", num(sum.mean)),
                    ("p50_s", num(sum.p50)),
                    ("n", num(sum.n as f64)),
                    ("value", if aux.is_nan() { Json::Null } else { num(*aux) }),
                ]);
                let _ = writeln!(f, "{}", j.to_string());
            }
        }
    }
}

/// Shared helper: does the full artifacts tree exist? Benches degrade to
/// synthetic-weight mode when it does not (CI without `make artifacts`).
pub fn artifacts_ready() -> bool {
    crate::artifacts_dir().join("manifest.json").exists()
}

/// Build a synthetic QModel for op-level benches that do not need trained
/// weights (Table 6, and fallbacks). `mode`: "fp16" | "mergequant" |
/// "mergequant_static" (o/down per-channel static W4A4, the PR-9
/// channel_static path) | "rtn" | "quarot".
pub fn synthetic_model(mode: &str, d: usize, ff: usize, n_layers: usize,
                       vocab: usize) -> crate::engine::QModel {
    use crate::engine::qmod::*;
    use crate::util::rng::Rng;
    let mut rng = Rng::new(0xC0FFEE);
    let config = ModelConfig {
        name: format!("synthetic-{mode}"),
        vocab,
        d_model: d,
        n_heads: (d / 32).max(1),
        d_ff: ff,
        n_layers,
        max_seq: 4096,
        rope_theta: 10000.0,
    };
    fn normal(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0f32; len];
        rng.fill_normal(&mut v, scale);
        v
    }
    fn fp_lin(rng: &mut Rng, n: usize, j: usize) -> Linear {
        Linear::Fp { wt: normal(rng, n * j, 0.05), n, j }
    }
    fn q_lin(rng: &mut Rng, n: usize, j: usize, mode: QuantMode) -> Linear {
        let wt: Vec<i8> =
            (0..n * j).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let mut packed = Vec::with_capacity(j * n.div_ceil(2));
        for c in 0..j {
            packed.extend(crate::quant::pack::pack_int4(&wt[c * n..(c + 1) * n]));
        }
        let scale: Vec<f32> = (0..j).map(|_| 0.01 + rng.f32() * 0.01).collect();
        Linear::Quant {
            qw: QWeight { n, j, wt, packed: Some(packed), scale, zero: None,
                          group: 0, bits: 4 },
            mode,
        }
    }
    fn make_norm(rng: &mut Rng, quant: bool, recon: bool, d: usize) -> Norm {
        Norm {
            g: (0..d).map(|_| 0.5 + rng.f32()).collect(),
            quant_qmax: if quant { Some(7) } else { None },
            recon_idx: if recon {
                Some((0..d).map(|_| rng.usize(0, d) as u32).collect())
            } else {
                None
            },
        }
    }
    fn dynq(rng: &mut Rng, n: usize, j: usize, h: bool, clip: f32) -> Linear {
        q_lin(rng, n, j, QuantMode::Dynamic {
            a_qmax: 7, a_clip: clip, hadamard: h })
    }
    /// Per-channel static activation quantization (DESIGN.md §17):
    /// reciprocal multipliers in a realistic scale band plus (when
    /// `permute`) a rotate-by-one reconstruction gather, so the fused
    /// quantize+gather path is exercised, not just the plain quantize.
    fn chanq(rng: &mut Rng, n: usize, j: usize, permute: bool) -> Linear {
        let a_inv: Vec<f32> =
            (0..n).map(|_| 1.0 / (0.02 + rng.f32() * 0.05)).collect();
        let recon_idx = permute
            .then(|| (0..n).map(|c| ((c + 1) % n) as u32).collect());
        q_lin(rng, n, j, QuantMode::ChannelStatic {
            a_inv, a_qmax: 7, recon_idx })
    }
    let mut layers = Vec::new();
    for _ in 0..n_layers {
        let layer = match mode {
            "fp16" => LayerWeights {
                attn_norm: make_norm(&mut rng, false, false, d),
                q: fp_lin(&mut rng, d, d),
                k: fp_lin(&mut rng, d, d),
                v: fp_lin(&mut rng, d, d),
                o: fp_lin(&mut rng, d, d),
                ffn_norm: make_norm(&mut rng, false, false, d),
                gate: fp_lin(&mut rng, d, ff),
                up: fp_lin(&mut rng, d, ff),
                down: fp_lin(&mut rng, ff, d),
            },
            "mergequant" => LayerWeights {
                attn_norm: make_norm(&mut rng, true, true, d),
                q: q_lin(&mut rng, d, d, QuantMode::Static),
                k: q_lin(&mut rng, d, d, QuantMode::Static),
                v: q_lin(&mut rng, d, d, QuantMode::Static),
                o: dynq(&mut rng, d, d, false, 0.75),
                ffn_norm: make_norm(&mut rng, true, true, d),
                gate: q_lin(&mut rng, d, ff, QuantMode::Static),
                up: q_lin(&mut rng, d, ff, QuantMode::Static),
                down: dynq(&mut rng, ff, d, false, 0.65),
            },
            "mergequant_static" => LayerWeights {
                attn_norm: make_norm(&mut rng, true, true, d),
                q: q_lin(&mut rng, d, d, QuantMode::Static),
                k: q_lin(&mut rng, d, d, QuantMode::Static),
                v: q_lin(&mut rng, d, d, QuantMode::Static),
                o: chanq(&mut rng, d, d, true),
                ffn_norm: make_norm(&mut rng, true, true, d),
                gate: q_lin(&mut rng, d, ff, QuantMode::Static),
                up: q_lin(&mut rng, d, ff, QuantMode::Static),
                down: chanq(&mut rng, ff, d, false),
            },
            "rtn" | "quarot" => {
                let had = mode == "quarot";
                LayerWeights {
                    attn_norm: make_norm(&mut rng, false, false, d),
                    q: dynq(&mut rng, d, d, false, 1.0),
                    k: dynq(&mut rng, d, d, false, 1.0),
                    v: dynq(&mut rng, d, d, false, 1.0),
                    o: dynq(&mut rng, d, d, false, 1.0),
                    ffn_norm: make_norm(&mut rng, false, false, d),
                    gate: dynq(&mut rng, d, ff, false, 1.0),
                    up: dynq(&mut rng, d, ff, false, 1.0),
                    down: dynq(&mut rng, ff, d, had, 1.0),
                }
            }
            other => panic!("unknown synthetic mode {other}"),
        };
        layers.push(layer);
    }
    // No KV scales attached: like a pre-format-2 bundle. Int8-KV users
    // call `Engine::ensure_kv_scales` (probe-calibration fallback).
    QModel {
        config,
        method: mode.into(),
        embed: normal(&mut rng, vocab * d, 0.02),
        outlier_gain: vec![1.0; d],
        final_norm: vec![1.0; d],
        lm_head_t: normal(&mut rng, vocab * d, 0.05),
        layers,
        kv: None,
    }
}
