//! `mergequant` — leader binary / CLI launcher.
//!
//! Subcommands:
//!   serve     — start the serving coordinator (+ optional TCP gateway)
//!   route     — start the replica-sharded front door: N engine
//!               replicas behind least-loaded dispatch with session
//!               affinity and graceful drain (DESIGN.md §16)
//!   eval      — perplexity + zero-shot accuracy of a bundle
//!   generate  — greedy generation from a prompt
//!   inspect   — dump bundle structure and memory accounting
//!   bench     — run the versioned benchmark suite (--record writes
//!               the repo-root BENCH_<n>.json snapshot)
//!   runtime   — load + run an AOT HLO artifact via PJRT (smoke)
//!
//! Run `mergequant <cmd> --help-less`: flags are documented below per arm.

use anyhow::{bail, Context, Result};

use mergequant::cli::Args;
use mergequant::config::{resolve_kv_slabs, ServeConfig};
use mergequant::coordinator::{
    server::TcpGateway, Router, RouterConfig, RouterGateway, Server,
};
use mergequant::engine::{Engine, QModel};
use mergequant::eval::{choice_accuracy, corpus, parse_task, perplexity};
use mergequant::{artifacts_dir, runtime};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn load_engine(model: &str, method: &str) -> Result<Engine> {
    let path = artifacts_dir()
        .join("models")
        .join(model)
        .join(format!("{method}.qmod"));
    let qm = QModel::load(&path)
        .with_context(|| format!("loading {}", path.display()))?;
    Ok(Engine::new(qm))
}

fn run() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("route") => cmd_route(&args),
        Some("eval") => cmd_eval(&args),
        Some("generate") => cmd_generate(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("bench") => cmd_bench(&args),
        Some("runtime") => cmd_runtime(&args),
        other => {
            eprintln!(
                "mergequant — 4-bit static quantization serving stack\n\
                 usage: mergequant <serve|route|eval|generate|inspect|\
                 bench|runtime> [--model NAME] [--method NAME] \
                 [--replicas N] [--threads N] \
                 [--kernel scalar|avx2|vnni|neon] \
                 [--kv-cache f32|int8] [--kv-block TOKENS] \
                 [--kv-blocks N] [--prefix-cache] \
                 [--prefix-cache-blocks N] [--max-decode-latency MS] \
                 [--speculative --draft-k K --draft-layers N] \
                 [--temperature T --top-k K \
                 --top-p P --seed S --stop T1,T2 --priority P \
                 --deadline-ms MS --session ID] …\n\
                 (got {other:?})"
            );
            bail!("unknown subcommand");
        }
    }
}

/// Resolve the serving config shared by `serve` and `route`: the JSON
/// config file first, then per-flag overrides.
fn serve_config(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(std::path::Path::new(path))?,
        None => ServeConfig::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.into();
    }
    if let Some(m) = args.get("method") {
        cfg.method = m.into();
    }
    cfg.port = args.get_usize("port", cfg.port as usize) as u16;
    cfg.replicas = args.get_usize("replicas", cfg.replicas).max(1);
    cfg.scheduler.max_batch =
        args.get_usize("max-batch", cfg.scheduler.max_batch);
    cfg.scheduler.max_seq = args.get_usize("max-seq", cfg.scheduler.max_seq);
    cfg.scheduler.kv_slabs = resolve_kv_slabs(
        args.get("kv-slabs").and_then(|v| v.parse().ok()),
        "--kv-slabs",
        cfg.scheduler.kv_slabs.max(cfg.scheduler.max_batch));
    // Paged KV (DESIGN.md §13): --kv-block sets the paging granularity
    // in tokens (0 = one block per max_seq sequence, the old slab
    // behaviour); --kv-blocks sets the arena size directly (0 = derive
    // from --kv-slabs at equal bytes — the back-compat path).
    cfg.scheduler.kv_block =
        args.get_usize("kv-block", cfg.scheduler.kv_block);
    cfg.scheduler.kv_blocks =
        args.get_usize("kv-blocks", cfg.scheduler.kv_blocks);
    // Intra-op kernel threads (0 = all cores); the scheduler applies it.
    cfg.scheduler.threads =
        args.get_usize("threads", cfg.scheduler.threads);
    // KV-cache storage dtype (f32 | int8); the scheduler sizes its KV
    // blocks with it (int8 = 4× more servable KV per box, DESIGN.md §10).
    if let Some(kv) = args.get("kv-cache") {
        cfg.scheduler.kv_dtype = mergequant::engine::KvDtype::parse(kv)
            .with_context(|| format!("bad --kv-cache {kv:?} (f32|int8)"))?;
    }
    // Prefix sharing (DESIGN.md §14): --prefix-cache turns the radix
    // index + CoW block sharing on (opt-in); --prefix-cache-blocks
    // bounds how many frozen blocks the index may retain (0 =
    // unbounded, pressure-evicted either way).
    if args.get_bool("prefix-cache") {
        cfg.scheduler.prefix_cache = true;
    }
    cfg.scheduler.prefix_cache_blocks = args
        .get_usize("prefix-cache-blocks", cfg.scheduler.prefix_cache_blocks);
    // SLO gate (DESIGN.md §15): --max-decode-latency sets the decode
    // latency target in ms; while the last decode-bearing forward call
    // exceeded it, new prefill admissions are deferred (0 = off).
    cfg.scheduler.max_decode_latency = args
        .get_usize("max-decode-latency",
                   cfg.scheduler.max_decode_latency as usize) as u64;
    // Self-speculative decoding (DESIGN.md §18): --speculative turns
    // the draft lane on (opt-in; token streams bitwise unchanged),
    // --draft-k sets tokens proposed per lane per iteration, and
    // --draft-layers truncates the draft model's depth (0 = full
    // depth, the pure self-draft).
    if args.get_bool("speculative") {
        cfg.scheduler.speculative = true;
    }
    cfg.scheduler.draft_k =
        args.get_usize("draft-k", cfg.scheduler.draft_k);
    cfg.scheduler.draft_layers =
        args.get_usize("draft-layers", cfg.scheduler.draft_layers);
    // Integer-microkernel pin (DESIGN.md §17): --kernel / config
    // "kernel" forces the dispatch table; unset keeps auto-dispatch
    // (or the MQ_KERNEL env override, honored lazily at first GEMM).
    if let Some(k) = args.get("kernel") {
        cfg.kernel = Some(k.into());
    }
    apply_kernel(cfg.kernel.as_deref())?;
    Ok(cfg)
}

/// Pin the process-wide integer microkernel when a spec was given.
/// Unlike the forgiving `MQ_KERNEL` env fallback, an *explicit* flag
/// or config key fails loudly — a deploy that asked for vnni should
/// not silently run scalar.
fn apply_kernel(spec: Option<&str>) -> Result<()> {
    use mergequant::quant::simd;
    let Some(name) = spec else { return Ok(()) };
    let kind = simd::KernelKind::parse(name).with_context(|| {
        format!("bad kernel {name:?} (want scalar|avx2|vnni|neon)")
    })?;
    if !simd::force(kind) {
        let avail: Vec<&str> =
            simd::available().iter().map(|k| k.name()).collect();
        bail!("kernel {name:?} is not available on this host \
               (available: {})", avail.join("|"));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = serve_config(args)?;
    let engine = load_engine(&cfg.model, &cfg.method)?;
    println!("serving {} / {} (params ~{:.1} MB quantized, quant {}, \
              {} kernel thread(s), {} microkernel, kv {}, arena {} \
              blocks × {} tokens, prefix cache {}, speculative {})",
             cfg.model, cfg.method,
             engine.model.weight_bytes() as f64 / 1e6,
             engine.model.quant_mode_name(),
             mergequant::quant::parallel::ThreadPool::resolve(
                 cfg.scheduler.threads),
             mergequant::quant::simd::active().kind().name(),
             cfg.scheduler.kv_dtype.as_str(),
             cfg.scheduler.total_blocks(),
             cfg.scheduler.block_tokens(),
             if cfg.scheduler.prefix_cache { "on" } else { "off" },
             if cfg.scheduler.speculative {
                 format!("on (k={}, draft_layers={})",
                         cfg.scheduler.draft_k.max(1),
                         cfg.scheduler.draft_layers)
             } else {
                 "off".into()
             });
    let server = std::sync::Arc::new(Server::start(engine, cfg.scheduler.clone()));
    let gateway = TcpGateway::start(server.clone(), cfg.port)?;
    println!("listening on {}", gateway.addr);
    println!("protocol: NDJSON, one request per line");
    println!("  v1 single-shot: {{\"prompt\":[1,2,3],\"max_new\":16}}");
    println!("  v2 streaming  : {{\"prompt\":[1,2,3],\"params\":{{\"max_new\":16,\
              \"temperature\":0.8,\"top_k\":40,\"top_p\":0.95,\"seed\":7,\
              \"stop_tokens\":[2],\"priority\":2,\"deadline_ms\":250}}}}");
    println!("  v2 frames     : one {{\"event\":\"token\",..}} per token, then \
              a terminal done/error frame");
    let secs = args.get_usize("run-secs", 0);
    if secs > 0 {
        std::thread::sleep(std::time::Duration::from_secs(secs as u64));
        gateway.stop();
        Ok(())
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
}

fn cmd_route(args: &Args) -> Result<()> {
    let cfg = serve_config(args)?;
    let replicas = cfg.replicas;
    // Pre-validate the bundle once so a bad --model/--method fails
    // loudly here instead of inside a replica factory thread.
    let engine = load_engine(&cfg.model, &cfg.method)?;
    let rcfg = RouterConfig::new(replicas, cfg.scheduler.clone());
    let per = rcfg.per_replica();
    println!("routing {} / {} across {} replica(s) (params ~{:.1} MB \
              quantized per replica, quant {}, {} microkernel, kv {}, \
              per-replica arena {} blocks × {} tokens, prefix cache \
              {}, affinity on)",
             cfg.model, cfg.method, replicas,
             engine.model.weight_bytes() as f64 / 1e6,
             engine.model.quant_mode_name(),
             mergequant::quant::simd::active().kind().name(),
             per.kv_dtype.as_str(),
             per.total_blocks(),
             per.block_tokens(),
             if per.prefix_cache { "on" } else { "off" });
    drop(engine);
    let model = cfg.model.clone();
    let method = cfg.method.clone();
    let router = std::sync::Arc::new(Router::start(rcfg, move |i| {
        // The bundle parsed above; a respawn that cannot reload it is
        // unrecoverable, so fail loudly.
        load_engine(&model, &method)
            .unwrap_or_else(|e| panic!("reloading replica {i}: {e:#}"))
    }));
    let gateway = RouterGateway::start(router.clone(), cfg.port)?;
    println!("listening on {}", gateway.addr);
    println!("protocol: NDJSON, one request per line (v1/v2 frames \
              identical to `serve`; params may add \"session\":\"ID\" \
              for replica affinity)");
    println!("  control: {{\"cmd\":\"stats\"}} | \
              {{\"cmd\":\"drain\",\"replica\":0}}");
    let secs = args.get_usize("run-secs", 0);
    if secs > 0 {
        std::thread::sleep(std::time::Duration::from_secs(secs as u64));
        gateway.stop();
        println!("{}", router.shutdown());
        Ok(())
    } else {
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny-llama-s");
    let method = args.get_or("method", "mergequant");
    let seq = args.get_usize("seq", 256);
    let mut engine = load_engine(model, method)?;
    engine.set_threads(args.get_usize("threads", 1));
    let art = artifacts_dir();
    println!("model={model} method={method}");
    for corpus_name in ["synth-wiki", "synth-c4"] {
        let toks = corpus::val_stream(&art, corpus_name)?;
        let limit = args.get_usize("max-tokens", toks.len());
        let ppl = perplexity(&engine, &toks[..limit.min(toks.len())], seq);
        println!("  ppl[{corpus_name}] = {ppl:.3}");
    }
    if args.get_bool("tasks") {
        for t in ["piqa", "arc-e", "arc-c", "hellaswag", "winogrande"] {
            let items = parse_task(&corpus::load_json(
                &art.join("tasks").join(format!("{t}.json")))?)?;
            let n = args.get_usize("task-items", items.len());
            let acc = choice_accuracy(&engine, &items[..n.min(items.len())]);
            println!("  acc[{t}] = {:.2}%", acc * 100.0);
        }
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny-llama-s");
    let method = args.get_or("method", "mergequant");
    let kv = mergequant::engine::KvDtype::parse(args.get_or("kv-cache", "f32"))
        .context("bad --kv-cache (f32|int8)")?;
    let mut engine = load_engine(model, method)?;
    engine.set_threads(args.get_usize("threads", 1));
    if kv == mergequant::engine::KvDtype::Int8 {
        engine.ensure_kv_scales()?;
    }
    let prompt: Vec<u32> = args
        .get_or("prompt", "1,17,42,99")
        .split(',')
        .filter_map(|t| t.trim().parse().ok())
        .collect();
    // Sampling knobs (GenerationParams surface): --temperature 0 (the
    // default) is the greedy seed path; anything else engages the seeded
    // top-k/top-p sampler — fixed --seed ⇒ bitwise-reproducible stream.
    let params = mergequant::coordinator::GenerationParams {
        max_new: args.get_usize("max-new", 32),
        temperature: args.get_f32("temperature", 0.0),
        top_k: args.get_usize("top-k", 0),
        top_p: args.get_f32("top-p", 1.0),
        seed: args.get_u64("seed", 0),
        stop_tokens: args
            .get_or("stop", "")
            .split(',')
            .filter_map(|t| t.trim().parse().ok())
            .collect(),
        // Scheduling class + deadline (DESIGN.md §15). Single-shot
        // generation never contends, so these only flow through for
        // parity with the serving path.
        priority: args.get_usize("priority", 0).min(u8::MAX as usize) as u8,
        deadline_ms: {
            let d = args.get_u64("deadline-ms", u64::MAX);
            if d == u64::MAX { None } else { Some(d) }
        },
        // Session affinity (DESIGN.md §16) is placement metadata for
        // the router tier; single-shot generation validates and
        // ignores it, same as a standalone server.
        session: args.get("session").map(String::from),
        // Speculation is a scheduler-lane concern (DESIGN.md §18);
        // single-shot generation runs the plain engine loop, so the
        // override has nothing to act on here.
        speculative: None,
    };
    params.validate().map_err(anyhow::Error::msg)?;
    let mut out = engine.generate_seeded(&prompt, params.max_new,
                                         prompt.len() + params.max_new + 8,
                                         kv, &params.sampler())?;
    // Honour --stop like the serving path does: cut at the first stop
    // token, inclusive. The sampler is counter-based, so the prefix is
    // identical to what the scheduler would have streamed.
    if let Some(pos) =
        out.iter().position(|t| params.stop_tokens.contains(t))
    {
        out.truncate(pos + 1);
    }
    println!("prompt:     {prompt:?}");
    if params.temperature > 0.0 {
        println!("sampling:   T={} top_k={} top_p={} seed={}",
                 params.temperature, params.top_k, params.top_p, params.seed);
    }
    println!("completion: {out:?} (kv {})", kv.as_str());
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let model = args.get_or("model", "tiny-llama-s");
    let method = args.get_or("method", "mergequant");
    let engine = load_engine(model, method)?;
    let m = &engine.model;
    let cfg = &m.config;
    println!("bundle  : {model}/{method}");
    println!("config  : d={} heads={} ff={} layers={} vocab={}",
             cfg.d_model, cfg.n_heads, cfg.d_ff, cfg.n_layers, cfg.vocab);
    println!("weights : {:.2} MB resident", m.weight_bytes() as f64 / 1e6);
    println!("kv scales: {}",
             if m.kv.is_some() { "calibrated (format 2)" } else { "absent" });
    let kv_dtype = mergequant::engine::KvDtype::parse(
        args.get_or("kv-cache", "f32")).context("bad --kv-cache")?;
    let mb = mergequant::engine::memory::account_model(
        m, args.get_usize("batch", 1), args.get_usize("seq", 2048), kv_dtype);
    println!("memory(batch-1, seq-2048 decode, kv {}): total {:.2} MB",
             kv_dtype.as_str(), mb.total() as f64 / 1e6);
    println!("  weights={:.2}MB kv={:.2}MB act={:.3}MB dyn_overhead={:.3}MB recon={:.3}MB",
             mb.weights as f64 / 1e6, mb.kv_cache as f64 / 1e6,
             mb.activations as f64 / 1e6, mb.dynamic_overhead as f64 / 1e6,
             mb.recon_indices as f64 / 1e6);
    for (i, l) in m.layers.iter().enumerate().take(
        if args.get_bool("all-layers") { usize::MAX } else { 1 }) {
        println!("layer {i}:");
        let modes = [("q", &l.q), ("k", &l.k), ("v", &l.v), ("o", &l.o),
                     ("gate", &l.gate), ("up", &l.up), ("down", &l.down)];
        for (name, lin) in modes {
            let desc = match lin {
                mergequant::engine::Linear::Fp { .. } => "fp32".to_string(),
                mergequant::engine::Linear::Quant { qw, mode } => format!(
                    "{:?} w{}b group={} {}", mode.name(), qw.bits,
                    qw.group,
                    if qw.zero.is_some() { "asym" } else { "sym" }),
            };
            println!("  {name:<5} {desc}");
        }
        println!("  attn_norm quant={:?} recon={}",
                 l.attn_norm.quant_qmax,
                 l.attn_norm.recon_idx.is_some());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    // The versioned suite behind the repo-root BENCH_<n>.json
    // snapshots: fig3 decode, table2 prefill, table3 memory, and the
    // shared-prefix fleet axis (DESIGN.md §14). Counter fields are
    // deterministic; wall-clock fields are machine-local and refreshed
    // by --record.
    let fast = args.get_bool("fast")
        || std::env::var("MQ_BENCH_FAST").is_ok();
    apply_kernel(args.get("kernel"))?;
    let j = mergequant::bench::record::run_suite(fast);
    println!("{}", j.to_string());
    // Regression visibility: diff the decode axis against the newest
    // committed BENCH_<n>.json snapshot (if one is readable here).
    if let Some(line) = mergequant::bench::record::delta_vs_previous(
        &j, std::path::Path::new("."))
    {
        eprintln!("{line}");
    }
    if args.get_bool("record") {
        let out = args.get_or("out", "BENCH_10.json");
        std::fs::write(out, format!("{}\n", j.to_string()))
            .with_context(|| format!("writing {out}"))?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

fn cmd_runtime(args: &Args) -> Result<()> {
    let name = args.get_or("artifact", "tiny-llama-s.prefill.fp32");
    let path = artifacts_dir().join("hlo").join(format!("{name}.hlo.txt"));
    let mut rt = runtime::Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    rt.load_hlo(name, &path)?;
    println!("compiled {name}");
    // smoke-execute with an arbitrary token batch from the HLO meta
    let meta = corpus::load_json(&artifacts_dir().join("hlo").join("hlo.json"))?;
    let info = meta.req(name).map_err(anyhow::Error::msg)?;
    let batch = info.req_usize("batch").map_err(anyhow::Error::msg)?;
    let seq = info.req_usize("seq").map_err(anyhow::Error::msg)?;
    let tokens: Vec<i32> = (0..batch * seq).map(|i| 3 + (i as i32 % 64)).collect();
    let logits = rt.execute_prefill_logits(name, &tokens, batch, seq)?;
    println!("executed: {} logits, first = {:.4}", logits.len(), logits[0]);
    Ok(())
}
