//! MergeQuant — accurate 4-bit static quantization of LLMs by channel-wise
//! calibration (Wang et al., 2025), reproduced as a three-layer
//! Rust + JAX + Pallas system.
//!
//! This crate is Layer 3: the runtime/serving side. It loads quantized
//! model bundles (`.qmod`) and AOT-compiled HLO produced by the build-time
//! Python layers, and provides:
//!
//! * [`quant`] — integer-kernel substrate: packed-INT4/INT8 GEMM with the
//!   per-output-column rescale epilogue that Quantization Step Migration
//!   aligns to, per-token dynamic quant ops (the baseline overhead), the
//!   dimension-reconstruction gather, the online block-Hadamard, and the
//!   parallel execution subsystem (`quant::parallel`: persistent worker
//!   pool + tiled multi-threaded kernels, DESIGN.md §7).
//! * [`engine`] — the native quantized inference engine (prefill + batched
//!   decode with KV cache) executing `.qmod` bundles on the parallel
//!   kernel substrate; bitwise deterministic for any thread count.
//! * [`runtime`] — PJRT wrapper (via the `xla` crate, behind the `pjrt`
//!   feature; a stub otherwise) executing the AOT-lowered JAX/Pallas HLO
//!   artifacts; parity-checked against [`engine`].
//! * [`coordinator`] — the serving layer: request router, continuous
//!   batcher, prefill/decode scheduler, KV pool, metrics.
//! * [`eval`] — perplexity + zero-shot choice-task evaluation (Tables 1,
//!   4, 5, 7; Fig. 1).
//! * [`bench`] — the measurement harness behind every paper table/figure
//!   (criterion is not vendored in this image; this is a from-scratch
//!   substrate, DESIGN.md §2).
//! * [`util`] — PRNG, JSON, stats, property-testing substrates.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod quant;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifacts tree (overridable via `MERGEQUANT_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MERGEQUANT_ARTIFACTS") {
        return p.into();
    }
    // Resolve relative to the crate manifest so tests/benches work from
    // any working directory.
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
