//! Threaded serving front-end.
//!
//! [`Server`] owns the scheduler on a worker thread and exposes:
//!   * an in-process async-ish API (`submit` → `Receiver<Response>`),
//!   * an optional TCP gateway speaking line-delimited JSON
//!     (`{"prompt":[..],"max_new":N}` → `{"id":..,"tokens":[..],…}`),
//!     which is what `examples/serve_e2e.rs` exercises end to end.
//!
//! The worker thread drives scheduling only; compute fans out from inside
//! the engine onto its intra-op pool, sized by
//! [`SchedulerConfig::threads`] (DESIGN.md §7).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::json::{num, obj, Json};

use super::request::{Request, Response};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::engine::Engine;

enum Msg {
    Submit(Request, Sender<Response>),
    Shutdown,
}

pub struct Server {
    tx: Sender<Msg>,
    worker: Option<JoinHandle<String>>,
    next_id: AtomicU64,
}

impl Server {
    pub fn start(engine: Engine, cfg: SchedulerConfig) -> Self {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || worker_loop(engine, cfg, rx));
        Server { tx, worker: Some(worker), next_id: AtomicU64::new(1) }
    }

    /// Submit a prompt; the response arrives on the returned channel.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize)
                  -> Receiver<Response> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::new(id, prompt, max_new);
        self.tx
            .send(Msg::Submit(req, rtx))
            .expect("server worker gone");
        rrx
    }

    /// Stop the worker and return its final metrics report.
    pub fn shutdown(mut self) -> String {
        let _ = self.tx.send(Msg::Shutdown);
        self.worker
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(engine: Engine, cfg: SchedulerConfig, rx: Receiver<Msg>)
               -> String {
    let mut sched = Scheduler::new(engine, cfg);
    let mut reply_map: std::collections::HashMap<u64, Sender<Response>> =
        std::collections::HashMap::new();
    let mut shutdown = false;
    loop {
        // Drain the mailbox: block only when idle.
        loop {
            let msg = if sched.has_work() {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match msg {
                Msg::Submit(req, reply) => {
                    reply_map.insert(req.id, reply);
                    if let Err(req) = sched.submit(req) {
                        // queue full — answer with empty tokens
                        if let Some(r) = reply_map.remove(&req.id) {
                            let _ = r.send(Response {
                                id: req.id,
                                tokens: Vec::new(),
                                ttft: std::time::Duration::ZERO,
                                latency: req.submitted.elapsed(),
                                prompt_len: req.prompt.len(),
                                error: Some("queue full".into()),
                            });
                        }
                    }
                }
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        sched.step();
        for resp in sched.take_completed() {
            if let Some(r) = reply_map.remove(&resp.id) {
                let _ = r.send(resp);
            }
        }
        if shutdown && !sched.has_work() {
            return sched.metrics.report();
        }
    }
}

// ---------------------------------------------------------------------
// TCP gateway (line-delimited JSON)
// ---------------------------------------------------------------------

pub struct TcpGateway {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl TcpGateway {
    /// Serve `server` on 127.0.0.1:<port> (0 = ephemeral).
    pub fn start(server: Arc<Server>, port: u16) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            // Connection handlers are detached: they block in read_line
            // until their client hangs up, so joining them on stop() would
            // deadlock against clients that keep their socket open.
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let srv = server.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, srv);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(TcpGateway { addr, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, server: Arc<Server>) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let j = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                writeln!(out, "{}", obj(vec![("error", Json::Str(e))])
                    .to_string())?;
                continue;
            }
        };
        let prompt: Vec<u32> = j
            .get("prompt")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_usize()).map(|v| v as u32)
                .collect())
            .unwrap_or_default();
        let max_new = j.get("max_new").and_then(Json::as_usize).unwrap_or(16);
        let resp = server.submit(prompt, max_new).recv()?;
        let mut fields = vec![
            ("id", num(resp.id as f64)),
            ("prompt_len", num(resp.prompt_len as f64)),
            ("ttft_ms", num(resp.ttft.as_secs_f64() * 1e3)),
            ("latency_ms", num(resp.latency.as_secs_f64() * 1e3)),
            ("tokens", Json::Arr(
                resp.tokens.iter().map(|&t| num(t as f64)).collect())),
        ];
        if let Some(e) = &resp.error {
            fields.push(("error", Json::Str(e.clone())));
        }
        let reply = obj(fields);
        writeln!(out, "{}", reply.to_string())?;
    }
}
