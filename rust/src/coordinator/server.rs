//! Threaded serving front-end — generation API v2 (DESIGN.md §11).
//!
//! [`Server`] owns the scheduler on a worker thread and exposes:
//!   * the in-process streaming API: [`Server::generate`] →
//!     [`RequestHandle`] yielding [`Event`] frames (one per token, then a
//!     terminal `Done`/`Error`) with [`RequestHandle::cancel`] tearing
//!     the sequence out of the continuous batch;
//!   * typed admission errors ([`SubmitError`]) — a dead worker or a full
//!     queue is a `Result`, never a panic;
//!   * a TCP gateway speaking NDJSON: v1 single-shot requests
//!     (`{"prompt":[..],"max_new":N}` → one summary object) and v2
//!     streaming requests (`{"prompt":[..],"params":{..}}` → one frame
//!     per token, then a terminal `done`/`error` frame).
//!
//! The worker thread drives scheduling only; compute fans out from inside
//! the engine onto its intra-op pool, sized by
//! [`SchedulerConfig::threads`] (DESIGN.md §7).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::json::{num, obj, s, Json};

use super::metrics::ReplicaStats;
use super::request::{
    Event, GenerationParams, Request, Response, SubmitError,
};
use super::scheduler::{Scheduler, SchedulerConfig};
use crate::engine::Engine;

enum Msg {
    Submit(Request, Sender<Event>, Sender<Result<(), SubmitError>>),
    Cancel(u64),
    /// Reply with a live [`ReplicaStats`] snapshot — answered between
    /// scheduler iterations, so it reflects at-most-one-tick-old load.
    Stats(Sender<ReplicaStats>),
    Shutdown,
}

/// Live handle on an in-flight request: an event stream plus a cancel
/// control. Dropping the handle without draining it cancels the request
/// on the worker's next delivery attempt (a vanished consumer must not
/// keep burning decode steps).
pub struct RequestHandle {
    id: u64,
    events: Receiver<Event>,
    ctl: Sender<Msg>,
}

impl std::fmt::Debug for RequestHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RequestHandle").field("id", &self.id).finish()
    }
}

impl RequestHandle {
    /// Server-assigned request id (matches every event's `id`).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Next event, blocking; `None` once the stream is closed (after the
    /// terminal frame, or if the worker died mid-request).
    pub fn recv(&self) -> Option<Event> {
        self.events.recv().ok()
    }

    /// Next event if one is already queued (non-blocking).
    pub fn try_recv(&self) -> Option<Event> {
        self.events.try_recv().ok()
    }

    /// Ask the scheduler to tear this request out of the continuous
    /// batch; its KV blocks are returned on the next scheduler iteration
    /// and the stream ends with `Done { finish: Cancelled }`. Safe to
    /// call at any point (no-op once the request has finished).
    pub fn cancel(&self) {
        let _ = self.ctl.send(Msg::Cancel(self.id));
    }

    /// Drain the stream to its terminal frame and return the summary.
    /// If the worker dies mid-stream, a synthetic error response carrying
    /// the tokens received so far is returned instead of panicking.
    pub fn wait(self) -> Response {
        let mut tokens = Vec::new();
        loop {
            match self.events.recv() {
                Ok(Event::Token { token, .. }) => tokens.push(token),
                Ok(Event::Done { response })
                | Ok(Event::Error { response }) => return response,
                Err(_) => {
                    let mut resp = Response::failed(
                        self.id, 0, std::time::Duration::ZERO,
                        SubmitError::WorkerGone.to_string());
                    resp.tokens = tokens;
                    return resp;
                }
            }
        }
    }
}

pub struct Server {
    tx: Sender<Msg>,
    worker: Mutex<Option<JoinHandle<String>>>,
    next_id: AtomicU64,
}

impl Server {
    pub fn start(engine: Engine, cfg: SchedulerConfig) -> Self {
        let (tx, rx) = channel::<Msg>();
        let worker = std::thread::spawn(move || worker_loop(engine, cfg, rx));
        Server {
            tx,
            worker: Mutex::new(Some(worker)),
            next_id: AtomicU64::new(1),
        }
    }

    /// Submit a generation request. Admission is synchronous: the handle
    /// is returned only once the request holds a queue slot, so
    /// backpressure ([`SubmitError::QueueFull`]), a dead worker
    /// ([`SubmitError::WorkerGone`]) and parameter validation all fail
    /// here — the event stream itself only ever carries progress.
    pub fn generate(&self, prompt: Vec<u32>, params: GenerationParams)
                    -> Result<RequestHandle, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.generate_as(id, prompt, params)
    }

    fn generate_as(&self, id: u64, prompt: Vec<u32>,
                   params: GenerationParams)
                   -> Result<RequestHandle, SubmitError> {
        params.validate().map_err(SubmitError::InvalidParams)?;
        if prompt.is_empty() {
            return Err(SubmitError::InvalidParams(
                "prompt must be non-empty".into()));
        }
        let (etx, erx) = channel();
        let (ack_tx, ack_rx) = channel();
        let req = Request::with_params(id, prompt, params);
        self.tx
            .send(Msg::Submit(req, etx, ack_tx))
            .map_err(|_| SubmitError::WorkerGone)?;
        match ack_rx.recv() {
            Ok(Ok(())) => Ok(RequestHandle {
                id,
                events: erx,
                ctl: self.tx.clone(),
            }),
            Ok(Err(e)) => Err(e),
            // Worker exited between accepting the message and acking.
            Err(_) => Err(SubmitError::WorkerGone),
        }
    }

    /// Live load snapshot of this server's scheduler (DESIGN.md §16) —
    /// the signal the router tier dispatches on. Answered by the worker
    /// between iterations; `Err` once the worker has exited.
    pub fn stats(&self) -> Result<ReplicaStats, SubmitError> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Stats(tx))
            .map_err(|_| SubmitError::WorkerGone)?;
        rx.recv().map_err(|_| SubmitError::WorkerGone)
    }

    /// Stop the worker and return its final metrics report. Subsequent
    /// [`Server::generate`] calls return [`SubmitError::WorkerGone`].
    pub fn shutdown(&self) -> String {
        let _ = self.tx.send(Msg::Shutdown);
        let handle = self.worker.lock().expect("worker mutex").take();
        handle.map(|h| h.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Ok(mut guard) = self.worker.lock() {
            if let Some(h) = guard.take() {
                let _ = h.join();
            }
        }
    }
}

fn worker_loop(engine: Engine, cfg: SchedulerConfig, rx: Receiver<Msg>)
               -> String {
    let queue_cap = cfg.queue_cap;
    let mut sched = Scheduler::new(engine, cfg);
    let mut sinks: std::collections::HashMap<u64, Sender<Event>> =
        std::collections::HashMap::new();
    let mut shutdown = false;
    loop {
        // Drain the mailbox: block only when idle.
        loop {
            let msg = if sched.has_work() {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            } else {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        shutdown = true;
                        break;
                    }
                }
            };
            match msg {
                Msg::Submit(req, events, ack) => {
                    let id = req.id;
                    match sched.submit(req) {
                        Ok(()) => {
                            sinks.insert(id, events);
                            let _ = ack.send(Ok(()));
                        }
                        Err(_rejected) => {
                            let _ = ack.send(Err(SubmitError::QueueFull {
                                cap: queue_cap,
                            }));
                        }
                    }
                }
                Msg::Cancel(id) => sched.cancel(id),
                // A vanished requester is fine — the snapshot is
                // advisory (the router may have timed out or died).
                Msg::Stats(reply) => {
                    let _ = reply.send(sched.stats());
                }
                Msg::Shutdown => {
                    shutdown = true;
                    break;
                }
            }
        }
        sched.step();
        for ev in sched.take_events() {
            let id = ev.id();
            let terminal = ev.is_terminal();
            if let Some(sink) = sinks.get(&id) {
                let delivered = sink.send(ev).is_ok();
                if terminal {
                    sinks.remove(&id);
                } else if !delivered {
                    // Consumer vanished mid-stream (handle dropped):
                    // tear the request out so its KV blocks come back.
                    sinks.remove(&id);
                    sched.cancel(id);
                }
            }
        }
        if shutdown && !sched.has_work() {
            return sched.metrics.report();
        }
    }
}

// ---------------------------------------------------------------------
// TCP gateway (NDJSON, v1 single-shot + v2 streaming)
// ---------------------------------------------------------------------

pub struct TcpGateway {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl TcpGateway {
    /// Serve `server` on 127.0.0.1:<port> (0 = ephemeral).
    pub fn start(server: Arc<Server>, port: u16) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            // Connection handlers are detached: they block in read_line
            // until their client hangs up, so joining them on stop() would
            // deadlock against clients that keep their socket open.
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let srv = server.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, srv);
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(TcpGateway { addr, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Top-level request keys the gateway accepts; anything else is a
/// protocol error (strictness catches client typos before they silently
/// change sampling behaviour).
const TOP_KEYS: &[&str] = &["prompt", "max_new", "params"];

fn handle_conn(stream: TcpStream, server: Arc<Server>) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let j = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                write_frame(&mut out, &error_frame(None, &e))?;
                continue;
            }
        };
        let (prompt, params, streaming) = match parse_request(&j) {
            Ok(parsed) => parsed,
            Err(msg) => {
                write_frame(&mut out, &error_frame(None, &msg))?;
                continue;
            }
        };
        match server.generate(prompt, params) {
            // Typed admission failure (queue full, dead worker, bad
            // params) — the v2 error frame the contract promises.
            Err(e) => {
                write_frame(&mut out, &error_frame(None, &e.to_string()))?;
            }
            Ok(handle) => {
                if streaming {
                    if let Err(e) = stream_events(&mut out, &handle) {
                        // Client hung up mid-stream: tear the request out
                        // of the batch so its KV blocks come back.
                        handle.cancel();
                        return Err(e);
                    }
                } else {
                    let resp = handle.wait();
                    write_frame(&mut out, &v1_frame(&resp))?;
                }
            }
        }
    }
}

/// Pump one request's events onto the wire; an `Err` means the client
/// connection failed mid-stream (the caller cancels the request).
/// Shared with the router gateway — replicas speak the identical v2
/// frame protocol (DESIGN.md §16).
pub(crate) fn stream_events(out: &mut TcpStream, handle: &RequestHandle)
                 -> anyhow::Result<()> {
    loop {
        match handle.recv() {
            Some(Event::Token { id, index, token }) => {
                write_frame(out, &obj(vec![
                    ("event", s("token")),
                    ("id", num(id as f64)),
                    ("index", num(index as f64)),
                    ("token", num(token as f64)),
                ]))?;
            }
            Some(Event::Done { response }) => {
                let mut fields = summary_fields(&response);
                fields.push(("event", s("done")));
                write_frame(out, &obj(fields))?;
                return Ok(());
            }
            Some(Event::Error { response }) => {
                let mut fields = summary_fields(&response);
                fields.push(("event", s("error")));
                fields.push(("error", s(response.error.as_deref()
                    .unwrap_or("request failed"))));
                write_frame(out, &obj(fields))?;
                return Ok(());
            }
            None => {
                write_frame(out, &error_frame(
                    Some(handle.id()),
                    &SubmitError::WorkerGone.to_string()))?;
                return Ok(());
            }
        }
    }
}

/// Decode one request line into `(prompt, params, streaming?)`. A request
/// is v2 (streaming) iff it carries a `params` object; v1 requests keep
/// the seed single-shot shape `{"prompt":[..],"max_new":N}`. Shared
/// with the router gateway.
pub(crate) fn parse_request(j: &Json)
                 -> Result<(Vec<u32>, GenerationParams, bool), String> {
    let Json::Obj(fields) = j else {
        return Err("request must be a JSON object".into());
    };
    for k in fields.keys() {
        if !TOP_KEYS.contains(&k.as_str()) {
            return Err(format!(
                "unknown field {k:?} (expected prompt, max_new or params)"));
        }
    }
    let prompt = parse_tokens(
        j.get("prompt").ok_or_else(|| "missing prompt".to_string())?,
        "prompt")?;
    match j.get("params") {
        Some(p) => {
            if j.get("max_new").is_some() {
                return Err(
                    "max_new belongs inside params for v2 requests".into());
            }
            Ok((prompt, parse_params(p)?, true))
        }
        None => {
            let max_new = match j.get("max_new") {
                None => 16,
                Some(v) => v.as_usize()
                    .ok_or_else(|| "max_new must be a number".to_string())?,
            };
            Ok((prompt, GenerationParams::greedy(max_new), false))
        }
    }
}

/// Decode a `params` object; unknown fields are protocol errors.
fn parse_params(j: &Json) -> Result<GenerationParams, String> {
    let Json::Obj(fields) = j else {
        return Err("params must be a JSON object".into());
    };
    let mut p = GenerationParams::default();
    for (k, v) in fields {
        let numeric = |name: &str| {
            v.as_f64().ok_or_else(|| format!("{name} must be a number"))
        };
        // Integer knobs are validated, not cast: `{"seed":-1}` must be a
        // protocol error, not a silent saturation to 0 (same strictness
        // as the unknown-field rejection). Wire integers are f64-exact
        // up to 2^53 — ample for token budgets and PRNG keys.
        let integer = |name: &str| -> Result<u64, String> {
            let n = numeric(name)?;
            if !(n >= 0.0 && n.fract() == 0.0 && n <= 9.0e15) {
                return Err(format!(
                    "{name} must be a non-negative integer (got {n})"));
            }
            Ok(n as u64)
        };
        match k.as_str() {
            "max_new" => p.max_new = integer("max_new")? as usize,
            "temperature" => p.temperature = numeric("temperature")? as f32,
            "top_k" => p.top_k = integer("top_k")? as usize,
            "top_p" => p.top_p = numeric("top_p")? as f32,
            "seed" => p.seed = integer("seed")?,
            "stop_tokens" => {
                p.stop_tokens = parse_tokens(v, "stop_tokens")?;
            }
            // Traffic shaping (DESIGN.md §15): priority class (higher
            // = more important; may transparently preempt strictly
            // lower classes) and an observational latency deadline.
            "priority" => {
                let n = integer("priority")?;
                if n > u8::MAX as u64 {
                    return Err(format!(
                        "priority must be <= {} (got {n})", u8::MAX));
                }
                p.priority = n as u8;
            }
            "deadline_ms" => p.deadline_ms = Some(integer("deadline_ms")?),
            // Router-tier session affinity (DESIGN.md §16). Charset and
            // length are enforced by `GenerationParams::validate` at the
            // `Server::generate` boundary; only the type is checked
            // here.
            "session" => match v {
                Json::Str(id) => p.session = Some(id.clone()),
                _ => return Err("session must be a string".into()),
            },
            // Per-request speculative-decoding override (DESIGN.md
            // §18): `false` opts this stream out of the deployment's
            // draft lane. A pure perf knob — never changes tokens.
            "speculative" => match v.as_bool() {
                Some(b) => p.speculative = Some(b),
                None => {
                    return Err("speculative must be a boolean".into())
                }
            },
            other => return Err(format!("unknown params field {other:?}")),
        }
    }
    Ok(p)
}

fn parse_tokens(j: &Json, what: &str) -> Result<Vec<u32>, String> {
    let arr = j.as_arr()
        .ok_or_else(|| format!("{what} must be an array of token ids"))?;
    let mut out = Vec::with_capacity(arr.len());
    for v in arr {
        let n = v.as_f64()
            .ok_or_else(|| format!("{what} entries must be numbers"))?;
        if !(n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64) {
            return Err(format!(
                "{what} entries must be non-negative integer token ids"));
        }
        out.push(n as u32);
    }
    Ok(out)
}

pub(crate) fn write_frame(out: &mut TcpStream, frame: &Json)
                          -> anyhow::Result<()> {
    writeln!(out, "{}", frame.to_string())?;
    Ok(())
}

/// Protocol-level error frame (no request admitted, so usually no id).
pub(crate) fn error_frame(id: Option<u64>, msg: &str) -> Json {
    let mut fields = vec![("event", s("error")), ("error", s(msg))];
    if let Some(id) = id {
        fields.push(("id", num(id as f64)));
    }
    obj(fields)
}

fn summary_fields(resp: &Response) -> Vec<(&'static str, Json)> {
    vec![
        ("id", num(resp.id as f64)),
        ("prompt_len", num(resp.prompt_len as f64)),
        ("ttft_ms", num(resp.ttft.as_secs_f64() * 1e3)),
        ("latency_ms", num(resp.latency.as_secs_f64() * 1e3)),
        ("finish", s(resp.finish.as_str())),
        ("tokens", Json::Arr(
            resp.tokens.iter().map(|&t| num(t as f64)).collect())),
    ]
}

/// v1 single-shot reply: the seed shape plus `finish`.
pub(crate) fn v1_frame(resp: &Response) -> Json {
    let mut fields = summary_fields(resp);
    if let Some(e) = &resp.error {
        fields.push(("error", s(e)));
    }
    obj(fields)
}
