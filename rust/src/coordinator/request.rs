//! Request/response types of the serving layer.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Optional stop token (EOS).
    pub stop_token: Option<u32>,
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> Self {
        Request {
            id,
            prompt,
            max_new,
            stop_token: None,
            submitted: Instant::now(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time to first token (prefill complete → first logit sampled).
    pub ttft: Duration,
    /// Total latency from submission to completion.
    pub latency: Duration,
    pub prompt_len: usize,
    /// Per-request failure description (e.g. a typed engine error such as
    /// KV-cache overflow); `None` on success. Failed requests still get a
    /// response — failures never kill the scheduler worker.
    pub error: Option<String>,
}

impl Response {
    pub fn decode_tokens_per_sec(&self) -> f64 {
        let decode_time = self.latency.saturating_sub(self.ttft);
        if decode_time.is_zero() || self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / decode_time.as_secs_f64()
    }
}
