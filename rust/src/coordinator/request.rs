//! Request/response/event types of the serving layer — the generation
//! API v2 contract (DESIGN.md §11).
//!
//! A request is a prompt plus [`GenerationParams`] (sampling knobs, stop
//! tokens, token budget). Its lifecycle is reported as a stream of
//! [`Event`]s: one `Token` per generated token, then exactly one terminal
//! frame — `Done` on normal completion (including cancellation) or
//! `Error` on a per-request failure. Admission failures never enter the
//! stream at all: they surface synchronously as [`SubmitError`].

use std::time::{Duration, Instant};

use crate::engine::Sampler;

/// Per-request generation parameters — the serving contract's sampling
/// surface. `temperature == 0` is the greedy special case and reproduces
/// the seed argmax token streams bitwise; any other temperature engages
/// the seeded top-k/top-p sampler (deterministic for a fixed `seed`
/// regardless of thread count or scheduling, DESIGN.md §11).
#[derive(Clone, Debug, PartialEq)]
pub struct GenerationParams {
    /// Token budget (includes the first token sampled at prefill).
    pub max_new: usize,
    /// Softmax temperature; `0.0` ⇒ greedy argmax (seed-identical).
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens (`0` ⇒ no cut).
    pub top_k: usize,
    /// Nucleus cut: smallest prefix of the sorted distribution with
    /// cumulative probability ≥ `top_p` (`1.0` ⇒ no cut).
    pub top_p: f32,
    /// Seed of the per-request counter-based RNG (draw *t* depends only
    /// on `(seed, t)`, never on scheduling).
    pub seed: u64,
    /// Generation stops after emitting any of these tokens.
    pub stop_tokens: Vec<u32>,
    /// Priority class (DESIGN.md §15): higher is more important. Classes
    /// share admission weighted-fair (weight `class + 1`), and a request
    /// under block pressure may transparently preempt active lanes of a
    /// *strictly lower* class. Default `0` — uniform traffic degrades to
    /// plain FIFO admission and the pre-§15 CacheFull behaviour, bitwise.
    pub priority: u8,
    /// Optional end-to-end latency target in milliseconds. Purely
    /// observational: a completion whose latency exceeds it increments
    /// the `slo_violations` counter (never alters token streams).
    pub deadline_ms: Option<u64>,
    /// Optional session id (DESIGN.md §16): requests sharing a session
    /// are pinned by the router tier to the replica holding that
    /// session's prefix-cache state, so multi-turn re-submissions hit
    /// warm KV blocks. Placement metadata only — a standalone server
    /// accepts and ignores it, and it never alters token streams.
    pub session: Option<String>,
    /// Per-request speculative-decoding override (DESIGN.md §18):
    /// `Some(false)` opts this request's decode lane out of the
    /// scheduler's draft engine, `None`/`Some(true)` follow the
    /// deployment's `speculative` config. A pure perf knob — token
    /// streams are bitwise identical either way, only the number of
    /// target forwards spent on the stream changes.
    pub speculative: Option<bool>,
}

impl Default for GenerationParams {
    fn default() -> Self {
        GenerationParams {
            max_new: 16,
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            seed: 0,
            stop_tokens: Vec::new(),
            priority: 0,
            deadline_ms: None,
            session: None,
            speculative: None,
        }
    }
}

/// Charset/length rules for wire session ids: 1–64 chars drawn from
/// `[A-Za-z0-9._:-]`. Checked by [`GenerationParams::validate`] (and
/// therefore for every TCP frame) — a malformed id is an admission
/// error, never a silent affinity miss.
pub fn validate_session(id: &str) -> Result<(), String> {
    if id.is_empty() || id.len() > 64 {
        return Err(format!(
            "session id must be 1-64 characters (got {})", id.len()));
    }
    if !id.chars().all(|c| {
        c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | ':')
    }) {
        return Err(
            "session id may only contain [A-Za-z0-9._:-]".into());
    }
    Ok(())
}

impl GenerationParams {
    /// Greedy decoding with a token budget — the v1 `submit` semantics.
    pub fn greedy(max_new: usize) -> Self {
        GenerationParams { max_new, ..Self::default() }
    }

    /// Reject parameter combinations the sampler cannot honour. Checked
    /// at the `Server::generate` boundary (and therefore for every TCP
    /// frame) so bad requests fail synchronously, not mid-stream.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_new == 0 {
            return Err("max_new must be >= 1".into());
        }
        if !self.temperature.is_finite() || self.temperature < 0.0 {
            return Err(format!(
                "temperature must be finite and >= 0 (got {})",
                self.temperature
            ));
        }
        // The comparison form also rejects NaN.
        if !(self.top_p > 0.0 && self.top_p <= 1.0) {
            return Err(format!(
                "top_p must be in (0, 1] (got {})", self.top_p
            ));
        }
        if let Some(id) = &self.session {
            validate_session(id)?;
        }
        Ok(())
    }

    /// The engine-side sampler these parameters describe.
    pub fn sampler(&self) -> Sampler {
        Sampler::new(self.temperature, self.top_k, self.top_p, self.seed)
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub params: GenerationParams,
    pub submitted: Instant,
}

impl Request {
    /// Greedy request with a token budget (v1-compatible constructor).
    pub fn new(id: u64, prompt: Vec<u32>, max_new: usize) -> Self {
        Self::with_params(id, prompt, GenerationParams::greedy(max_new))
    }

    /// Request with explicit generation parameters.
    pub fn with_params(id: u64, prompt: Vec<u32>, params: GenerationParams)
                       -> Self {
        Request { id, prompt, params, submitted: Instant::now() }
    }
}

/// Why a sequence left the continuous batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit the `max_new` token budget.
    Length,
    /// Emitted one of the request's stop tokens.
    Stop,
    /// Its KV capacity (logical `max_seq` or the block pool) filled
    /// before the budget was reached.
    CacheFull,
    /// Torn out of the batch by `cancel()` (or a vanished client).
    Cancelled,
    /// Terminated by a typed engine error (carried in `Response::error`).
    Error,
}

impl FinishReason {
    /// Wire name used by the v2 NDJSON protocol.
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::CacheFull => "cache_full",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Error => "error",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time to first token (prefill complete → first logit sampled).
    pub ttft: Duration,
    /// Total latency from submission to completion.
    pub latency: Duration,
    pub prompt_len: usize,
    /// Why the sequence finished.
    pub finish: FinishReason,
    /// Per-request failure description (e.g. a typed engine error such as
    /// KV-cache overflow); `None` on success. Failed requests still get a
    /// terminal event — failures never kill the scheduler worker.
    pub error: Option<String>,
}

impl Response {
    /// Terminal summary for a request that never produced tokens
    /// (admission failure, dead worker, cancelled while pending).
    pub fn failed(id: u64, prompt_len: usize, latency: Duration,
                  error: String) -> Self {
        Response {
            id,
            tokens: Vec::new(),
            ttft: Duration::ZERO,
            latency,
            prompt_len,
            finish: FinishReason::Error,
            error: Some(error),
        }
    }

    pub fn decode_tokens_per_sec(&self) -> f64 {
        let decode_time = self.latency.saturating_sub(self.ttft);
        if decode_time.is_zero() || self.tokens.len() <= 1 {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / decode_time.as_secs_f64()
    }
}

/// One frame of a request's event stream. `Token` frames arrive in token
/// order; the stream ends with exactly one `Done` or `Error` frame.
#[derive(Clone, Debug)]
pub enum Event {
    /// Token `token` is the `index`-th generated token of request `id`.
    Token { id: u64, index: usize, token: u32 },
    /// Normal completion (including cancellation — see
    /// [`Response::finish`]); carries the full summary.
    Done { response: Response },
    /// Per-request failure; `response.error` holds the message and
    /// `response.tokens` whatever was generated before the failure.
    Error { response: Response },
}

impl Event {
    /// Request this frame belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Event::Token { id, .. } => *id,
            Event::Done { response } | Event::Error { response } => {
                response.id
            }
        }
    }

    /// `true` for `Done`/`Error` — the last frame of a stream.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, Event::Token { .. })
    }
}

/// Typed admission failures of [`super::Server::generate`] — surfaced to
/// the caller (and as v2 `error` frames on the TCP gateway) instead of
/// the seed behaviour of panicking on a dead worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The scheduler's pending queue is at `queue_cap` (backpressure).
    QueueFull { cap: usize },
    /// The scheduler worker thread has exited (shutdown or crash).
    WorkerGone,
    /// The request's [`GenerationParams`] failed validation.
    InvalidParams(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { cap } => {
                write!(f, "queue full (cap {cap})")
            }
            SubmitError::WorkerGone => write!(f, "server worker gone"),
            SubmitError::InvalidParams(msg) => {
                write!(f, "invalid generation params: {msg}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_params_are_greedy() {
        let p = GenerationParams::default();
        assert_eq!(p.temperature, 0.0);
        assert!(p.sampler().is_greedy());
        assert!(p.validate().is_ok());
        assert_eq!(p.priority, 0);
        assert_eq!(p.deadline_ms, None);
    }

    #[test]
    fn validate_rejects_bad_params() {
        let mut p = GenerationParams::greedy(8);
        p.temperature = -1.0;
        assert!(p.validate().is_err());
        p.temperature = f32::NAN;
        assert!(p.validate().is_err());
        p.temperature = 0.7;
        p.top_p = 0.0;
        assert!(p.validate().is_err());
        p.top_p = 1.5;
        assert!(p.validate().is_err());
        p.top_p = 0.9;
        assert!(p.validate().is_ok());
        p.max_new = 0;
        assert!(p.validate().is_err());
    }

    #[test]
    fn session_ids_are_validated() {
        let mut p = GenerationParams::greedy(4);
        assert_eq!(p.session, None);
        for ok in ["u1", "chat-7", "a.b:c_d", &"x".repeat(64)] {
            p.session = Some(ok.into());
            assert!(p.validate().is_ok(), "{ok:?} must be accepted");
        }
        for bad in ["", "has space", "emoji\u{1F600}", &"x".repeat(65)] {
            p.session = Some(bad.into());
            assert!(p.validate().is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn event_ids_and_terminality() {
        let resp = Response::failed(7, 3, Duration::ZERO, "x".into());
        assert_eq!(Event::Token { id: 7, index: 0, token: 1 }.id(), 7);
        assert!(!Event::Token { id: 7, index: 0, token: 1 }.is_terminal());
        assert!(Event::Error { response: resp.clone() }.is_terminal());
        assert!(Event::Done { response: resp }.is_terminal());
    }

    #[test]
    fn submit_error_display() {
        assert_eq!(SubmitError::QueueFull { cap: 4 }.to_string(),
                   "queue full (cap 4)");
        assert_eq!(SubmitError::WorkerGone.to_string(),
                   "server worker gone");
    }
}
