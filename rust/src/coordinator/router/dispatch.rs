//! Dispatch policy of the router tier (DESIGN.md §16): least-loaded
//! placement over live [`ReplicaStats`] snapshots, plus the session
//! affinity table that pins multi-turn sessions to the replica holding
//! their prefix-cache state.
//!
//! The policy is a plain synchronous struct — no threads, no I/O — so
//! the deterministic bench/replay harnesses can drive it directly over
//! synchronously-stepped schedulers, while [`super::Router`] drives the
//! identical code over threaded [`crate::coordinator::Server`] replicas.

use std::collections::HashMap;

use crate::coordinator::metrics::ReplicaStats;

/// One live replica offered to [`Dispatcher::choose`]. `stats.replica`
/// carries the fleet index; `generation` counts respawns of that slot,
/// so a pin taken before a drain/respawn cycle never silently lands a
/// session on the cold re-spawned replica.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Respawn generation of the slot (bumped by every drain teardown).
    pub generation: u64,
    /// Live load snapshot, with `stats.replica` set to the slot index.
    pub stats: ReplicaStats,
}

/// Where a placement decision came from — the router's affinity
/// accounting keys off this.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// No session id (or affinity disabled): plain least-loaded.
    LeastLoaded,
    /// The session's pinned replica is live: routed to its warm prefix
    /// cache.
    AffinityHit,
    /// First sighting of this session: pinned to the least-loaded
    /// replica.
    Pinned,
    /// The session's pin pointed at a draining, respawned, or excluded
    /// replica: re-pinned to a live one (the re-route path).
    Repinned,
}

struct Pin {
    replica: usize,
    generation: u64,
}

/// Least-loaded dispatch + session affinity table.
pub struct Dispatcher {
    affinity: bool,
    sessions: HashMap<String, Pin>,
}

impl Dispatcher {
    /// `affinity: false` ignores session ids entirely (the "no-affinity
    /// shuffle" baseline the benches compare against).
    pub fn new(affinity: bool) -> Self {
        Dispatcher { affinity, sessions: HashMap::new() }
    }

    /// Pick a replica for a request among `candidates` (live replicas
    /// only). Returns the chosen fleet index and how the choice was
    /// made; `None` when no candidate was offered. Ties on load break
    /// to the lowest index, so placement on an idle fleet is
    /// deterministic.
    pub fn choose(&mut self, session: Option<&str>,
                  candidates: &[Candidate])
                  -> Option<(usize, Placement)> {
        let least = candidates
            .iter()
            .min_by_key(|c| c.stats.load_key())?;
        let (least_idx, least_gen) =
            (least.stats.replica, least.generation);
        let sid = match session {
            Some(sid) if self.affinity => sid,
            _ => return Some((least_idx, Placement::LeastLoaded)),
        };
        if let Some(pin) = self.sessions.get(sid) {
            let live = candidates.iter().any(|c| {
                c.stats.replica == pin.replica
                    && c.generation == pin.generation
            });
            if live {
                return Some((pin.replica, Placement::AffinityHit));
            }
            self.sessions.insert(
                sid.to_string(),
                Pin { replica: least_idx, generation: least_gen });
            return Some((least_idx, Placement::Repinned));
        }
        self.sessions.insert(
            sid.to_string(),
            Pin { replica: least_idx, generation: least_gen });
        Some((least_idx, Placement::Pinned))
    }

    /// Replica a session is currently pinned to (observability).
    pub fn session_replica(&self, session: &str) -> Option<usize> {
        self.sessions.get(session).map(|p| p.replica)
    }

    /// Number of pinned sessions (observability).
    pub fn sessions_pinned(&self) -> usize {
        self.sessions.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(replica: usize, generation: u64, depth: usize,
            kv_used: usize) -> Candidate {
        Candidate {
            generation,
            stats: ReplicaStats {
                replica,
                active: depth,
                kv_capacity: 16,
                kv_available: 16 - kv_used,
                ..ReplicaStats::default()
            },
        }
    }

    #[test]
    fn least_loaded_prefers_depth_then_blocks_then_index() {
        let mut d = Dispatcher::new(true);
        // Equal depth: fewer blocks held wins.
        let c = [cand(0, 0, 1, 8), cand(1, 0, 1, 2)];
        assert_eq!(d.choose(None, &c),
                   Some((1, Placement::LeastLoaded)));
        // Depth dominates blocks.
        let c = [cand(0, 0, 2, 0), cand(1, 0, 1, 12)];
        assert_eq!(d.choose(None, &c),
                   Some((1, Placement::LeastLoaded)));
        // Full tie: lowest index (deterministic idle-fleet placement).
        let c = [cand(0, 0, 0, 0), cand(1, 0, 0, 0)];
        assert_eq!(d.choose(None, &c),
                   Some((0, Placement::LeastLoaded)));
        assert_eq!(d.choose(None, &[]), None);
    }

    #[test]
    fn sessions_pin_and_stick_under_load() {
        let mut d = Dispatcher::new(true);
        let c = [cand(0, 0, 0, 0), cand(1, 0, 0, 0)];
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((0, Placement::Pinned)));
        assert_eq!(d.session_replica("u1"), Some(0));
        // Replica 0 now busier — the pin still wins.
        let c = [cand(0, 0, 5, 10), cand(1, 0, 0, 0)];
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((0, Placement::AffinityHit)));
        // A different session takes the least-loaded replica.
        assert_eq!(d.choose(Some("u2"), &c),
                   Some((1, Placement::Pinned)));
        assert_eq!(d.sessions_pinned(), 2);
    }

    #[test]
    fn draining_and_respawned_pins_are_rerouted() {
        let mut d = Dispatcher::new(true);
        let c = [cand(0, 0, 0, 0), cand(1, 0, 1, 0)];
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((0, Placement::Pinned)));
        // Replica 0 drains: it is no longer offered as a candidate, so
        // the session re-pins to a live replica instead of erroring.
        let c = [cand(1, 0, 1, 0)];
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((1, Placement::Repinned)));
        assert_eq!(d.session_replica("u1"), Some(1));
        // Respawn bumps the generation: a pin taken against the old
        // incarnation must not read the cold replica as warm.
        let c = [cand(1, 1, 0, 0), cand(0, 1, 5, 0)];
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((1, Placement::Repinned)));
        // Same generation next time: a genuine hit.
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((1, Placement::AffinityHit)));
    }

    #[test]
    fn affinity_off_ignores_sessions() {
        let mut d = Dispatcher::new(false);
        let c = [cand(0, 0, 0, 0), cand(1, 0, 0, 0)];
        assert_eq!(d.choose(Some("u1"), &c),
                   Some((0, Placement::LeastLoaded)));
        assert_eq!(d.sessions_pinned(), 0);
        assert_eq!(d.session_replica("u1"), None);
    }
}
