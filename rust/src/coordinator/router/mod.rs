//! Replica-sharded serving front door (DESIGN.md §16).
//!
//! A [`Router`] owns N engine replicas — each a full
//! [`crate::coordinator::Server`] with its own worker thread, engine
//! thread-pool, `BlockPool`, prefix cache, and pending queue — and
//! places incoming requests across them:
//!
//!   * **least-loaded dispatch** over live [`ReplicaStats`] snapshots
//!     (queue depth, then KV blocks held, then index — deterministic on
//!     an idle fleet);
//!   * **session affinity**: requests carrying
//!     `GenerationParams::session` are pinned to the replica holding
//!     that session's prefix-cache state, so multi-turn re-submissions
//!     hit warm KV blocks instead of re-prefilling cold;
//!   * **graceful drain**: [`Router::drain`] stops new admissions to a
//!     replica, in-flight streams run to completion, then the replica
//!     is torn down (its final metrics report kept) and re-spawned
//!     fresh — the fleet keeps serving throughout.
//!
//! Determinism is per-replica: every replica is a standalone server, so
//! a request's token stream is bitwise identical to running it on a
//! single-replica server with the same seed. Routing decides placement,
//! never stream content (`tests/router.rs` pins this).

pub mod dispatch;
pub mod gateway;

use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::{ReplicaStats, RouterMetrics};
use crate::coordinator::request::{GenerationParams, SubmitError};
use crate::coordinator::scheduler::SchedulerConfig;
use crate::coordinator::server::{RequestHandle, Server};
use crate::engine::Engine;

pub use dispatch::{Candidate, Dispatcher, Placement};
pub use gateway::RouterGateway;

/// Fleet-level configuration: how many replicas, whether session
/// affinity is honoured, and the whole-box scheduler settings the
/// per-replica arenas are split from.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Engine replicas to spawn (min 1).
    pub replicas: usize,
    /// Honour `GenerationParams::session` pins (on by default; the
    /// benches turn it off for the no-affinity shuffle baseline).
    pub affinity: bool,
    /// Whole-box scheduler settings; `per_replica` splits the KV arena.
    pub scheduler: SchedulerConfig,
}

impl RouterConfig {
    pub fn new(replicas: usize, scheduler: SchedulerConfig) -> Self {
        RouterConfig { replicas: replicas.max(1), affinity: true,
                       scheduler }
    }

    /// Per-replica scheduler settings: the whole-box arena is split
    /// evenly, with a floor of one `max_seq` sequence per replica so a
    /// mis-sized fleet degrades to smaller arenas, never to replicas
    /// that can admit nothing.
    pub fn per_replica(&self) -> SchedulerConfig {
        let mut cfg = self.scheduler.clone();
        let n = self.replicas.max(1);
        let floor = cfg
            .max_seq
            .max(1)
            .div_ceil(cfg.block_tokens());
        cfg.kv_blocks = (cfg.total_blocks() / n).max(floor);
        // The split is expressed in blocks from here on; the slab
        // back-compat sizing must not re-inflate it.
        cfg.kv_slabs = 0;
        cfg
    }
}

enum ReplicaState {
    Live,
    Draining,
}

/// One replica slot: the live server, its drain state, and the respawn
/// generation (bumped on every teardown, so stale session pins are
/// detected instead of landing on a cold re-spawned replica).
struct Replica {
    server: Arc<Server>,
    state: ReplicaState,
    generation: u64,
}

struct Inner {
    replicas: Vec<Replica>,
    dispatcher: Dispatcher,
    metrics: RouterMetrics,
    /// Final metrics reports of replicas torn down by drain — surfaced
    /// by [`Router::shutdown`].
    drained_reports: Vec<String>,
}

/// The front-door process state: replica slots behind one mutex, plus
/// the engine factory drains re-spawn from.
pub struct Router {
    inner: Mutex<Inner>,
    factory: Box<dyn Fn(usize) -> Engine + Send + Sync>,
    cfg: SchedulerConfig,
}

impl Router {
    /// Spawn `cfg.replicas` servers, each on an engine built by
    /// `factory(i)`. The factory is retained: a drained replica is
    /// re-spawned from it.
    pub fn start<F>(cfg: RouterConfig, factory: F) -> Self
    where
        F: Fn(usize) -> Engine + Send + Sync + 'static,
    {
        let per_replica = cfg.per_replica();
        let replicas = (0..cfg.replicas.max(1))
            .map(|i| Replica {
                server: Arc::new(Server::start(factory(i),
                                               per_replica.clone())),
                state: ReplicaState::Live,
                generation: 0,
            })
            .collect::<Vec<_>>();
        let mut metrics = RouterMetrics::default();
        metrics.ensure_replicas(replicas.len());
        Router {
            inner: Mutex::new(Inner {
                replicas,
                dispatcher: Dispatcher::new(cfg.affinity),
                metrics,
                drained_reports: Vec::new(),
            }),
            factory: Box::new(factory),
            cfg: per_replica,
        }
    }

    /// Fleet width (live + draining slots).
    pub fn replicas(&self) -> usize {
        self.lock().replicas.len()
    }

    /// Dispatch a request to a replica and return its stream handle.
    /// Placement: session pin if live, else least-loaded; a queue-full
    /// replica fails over to the next-least-loaded one. The stream
    /// itself is the chosen replica's — bitwise identical to a
    /// standalone server (routing never alters content).
    pub fn generate(&self, prompt: Vec<u32>, params: GenerationParams)
                    -> Result<RequestHandle, SubmitError> {
        // Validate before placement so malformed requests never perturb
        // session pins or dispatch counters.
        params.validate().map_err(SubmitError::InvalidParams)?;
        let mut inner = self.lock();
        self.poll_drains_locked(&mut inner);
        let mut excluded: Vec<usize> = Vec::new();
        let mut last_err = SubmitError::WorkerGone;
        loop {
            let candidates = candidates(&inner, &excluded);
            let chosen = inner
                .dispatcher
                .choose(params.session.as_deref(), &candidates);
            let Some((idx, placement)) = chosen else {
                return Err(last_err);
            };
            let server = inner.replicas[idx].server.clone();
            match server.generate(prompt.clone(), params.clone()) {
                Ok(handle) => {
                    let n = inner.replicas.len();
                    let m = &mut inner.metrics;
                    m.ensure_replicas(n);
                    m.dispatched[idx] += 1;
                    match placement {
                        Placement::LeastLoaded => {}
                        Placement::AffinityHit => m.affinity_hits += 1,
                        Placement::Pinned => m.affinity_misses += 1,
                        Placement::Repinned => {
                            m.affinity_misses += 1;
                            m.rerouted += 1;
                        }
                    }
                    return Ok(handle);
                }
                Err(e @ SubmitError::QueueFull { .. }) => {
                    // Backpressure is per-replica: offer the request to
                    // the next-least-loaded one before giving up.
                    inner.metrics.failovers += 1;
                    last_err = e;
                    excluded.push(idx);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Stop new admissions to `replica`. In-flight streams finish
    /// normally; once the replica runs idle it is torn down (final
    /// report kept) and re-spawned fresh — progressed lazily by every
    /// router operation and explicitly by [`Router::poll_drains`].
    /// Refuses to drain the last live replica: the fleet keeps serving
    /// throughout a drain, by contract.
    pub fn drain(&self, replica: usize) -> Result<(), String> {
        let mut inner = self.lock();
        if replica >= inner.replicas.len() {
            return Err(format!(
                "no replica {replica} (fleet of {})",
                inner.replicas.len()));
        }
        let live = inner
            .replicas
            .iter()
            .filter(|r| matches!(r.state, ReplicaState::Live))
            .count();
        match inner.replicas[replica].state {
            ReplicaState::Draining => {
                return Err(format!(
                    "replica {replica} is already draining"));
            }
            ReplicaState::Live if live <= 1 => {
                return Err(
                    "cannot drain the last live replica".into());
            }
            ReplicaState::Live => {}
        }
        inner.replicas[replica].state = ReplicaState::Draining;
        inner.metrics.drains += 1;
        // An already-idle replica tears down immediately.
        self.poll_drains_locked(&mut inner);
        Ok(())
    }

    /// Advance drain teardowns whose replicas have run idle; returns
    /// how many replicas are still draining.
    pub fn poll_drains(&self) -> usize {
        let mut inner = self.lock();
        self.poll_drains_locked(&mut inner);
        inner
            .replicas
            .iter()
            .filter(|r| matches!(r.state, ReplicaState::Draining))
            .count()
    }

    /// Per-replica load snapshots, `replica`/`draining` filled in.
    pub fn stats(&self) -> Vec<ReplicaStats> {
        let mut inner = self.lock();
        self.poll_drains_locked(&mut inner);
        snapshot(&inner)
    }

    /// Replica a session is currently pinned to (observability).
    pub fn session_replica(&self, session: &str) -> Option<usize> {
        self.lock().dispatcher.session_replica(session)
    }

    /// Router-tier placement counters (dispatch counts, affinity
    /// hits/misses, drains, respawns, failovers).
    pub fn metrics(&self) -> RouterMetrics {
        self.lock().metrics.clone()
    }

    /// One-line router-aggregate report: dispatch counts, affinity hit
    /// rate, drain/respawn history, live per-replica kv_util and queue
    /// depth. Greppable, like `Metrics::report`.
    pub fn report(&self) -> String {
        let mut inner = self.lock();
        self.poll_drains_locked(&mut inner);
        let stats = snapshot(&inner);
        inner.metrics.report(&stats)
    }

    /// Stop every replica (each finishes its in-flight work first) and
    /// return the router report plus per-replica final reports —
    /// including those of replicas torn down by earlier drains.
    pub fn shutdown(&self) -> String {
        let mut inner = self.lock();
        let stats = snapshot(&inner);
        let mut lines = vec![inner.metrics.report(&stats)];
        lines.append(&mut inner.drained_reports);
        for (i, r) in inner.replicas.iter().enumerate() {
            lines.push(format!("replica[{i}]: {}", r.server.shutdown()));
        }
        lines.join("\n")
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("router state poisoned")
    }

    /// Tear down and re-spawn every draining replica whose work has
    /// drained. Teardown joins the worker, which is immediate once the
    /// replica reports idle (no pending, prefilling, or active work —
    /// every stream has delivered its terminal frame).
    fn poll_drains_locked(&self, inner: &mut Inner) {
        for i in 0..inner.replicas.len() {
            if !matches!(inner.replicas[i].state, ReplicaState::Draining)
            {
                continue;
            }
            let idle = inner.replicas[i]
                .server
                .stats()
                .map(|s| s.is_idle())
                // A dead worker has no work left by definition.
                .unwrap_or(true);
            if !idle {
                continue;
            }
            let report = inner.replicas[i].server.shutdown();
            inner
                .drained_reports
                .push(format!("replica[{i}] drained: {report}"));
            let generation = inner.replicas[i].generation + 1;
            inner.replicas[i] = Replica {
                server: Arc::new(Server::start((self.factory)(i),
                                               self.cfg.clone())),
                state: ReplicaState::Live,
                generation,
            };
            inner.metrics.respawns += 1;
        }
    }
}

/// Live (non-draining, non-excluded) candidates with fresh stats.
/// Replicas whose worker died are skipped — they can't admit.
fn candidates(inner: &Inner, excluded: &[usize]) -> Vec<Candidate> {
    inner
        .replicas
        .iter()
        .enumerate()
        .filter(|(i, r)| {
            matches!(r.state, ReplicaState::Live) && !excluded.contains(i)
        })
        .filter_map(|(i, r)| {
            r.server.stats().ok().map(|mut s| {
                s.replica = i;
                Candidate { generation: r.generation, stats: s }
            })
        })
        .collect()
}

/// Per-replica snapshots for reports and the stats control frame.
fn snapshot(inner: &Inner) -> Vec<ReplicaStats> {
    inner
        .replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut s = r.server.stats().unwrap_or_default();
            s.replica = i;
            s.draining = matches!(r.state, ReplicaState::Draining);
            s
        })
        .collect()
}
