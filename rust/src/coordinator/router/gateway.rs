//! TCP front door of the router tier (DESIGN.md §16): the same NDJSON
//! protocol the single-replica gateway speaks (v1 single-shot + v2
//! streaming request frames, identical reply frames — clients cannot
//! tell a router from a standalone server), plus fleet control frames:
//!
//! ```text
//!   {"cmd":"stats"}                → {"event":"stats","replicas":[..]}
//!   {"cmd":"drain","replica":i}    → {"event":"drain","replica":i,
//!                                     "status":"draining"}
//! ```
//!
//! A line is a control frame iff it carries a `cmd` key. Unknown
//! fields, unknown commands, and malformed `replica` values are
//! protocol errors — the same strictness as request frames.

use std::io::{BufRead, BufReader};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::server::{
    error_frame, parse_request, stream_events, v1_frame, write_frame,
};
use crate::util::json::{num, obj, s, Json};

use super::Router;

pub struct RouterGateway {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl RouterGateway {
    /// Serve `router` on 127.0.0.1:<port> (0 = ephemeral).
    pub fn start(router: Arc<Router>, port: u16)
                 -> anyhow::Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            // Handlers are detached for the same reason as the
            // single-replica gateway's: they block in read_line until
            // their client hangs up.
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let r = router.clone();
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, r);
                        });
                    }
                    Err(ref e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(
                            std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(RouterGateway { addr, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>)
               -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let j = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                write_frame(&mut out, &error_frame(None, &e))?;
                continue;
            }
        };
        if j.get("cmd").is_some() {
            write_frame(&mut out, &control_frame(&router, &j))?;
            continue;
        }
        let (prompt, params, streaming) = match parse_request(&j) {
            Ok(parsed) => parsed,
            Err(msg) => {
                write_frame(&mut out, &error_frame(None, &msg))?;
                continue;
            }
        };
        match router.generate(prompt, params) {
            Err(e) => {
                write_frame(&mut out,
                            &error_frame(None, &e.to_string()))?;
            }
            Ok(handle) => {
                if streaming {
                    if let Err(e) = stream_events(&mut out, &handle) {
                        handle.cancel();
                        return Err(e);
                    }
                } else {
                    let resp = handle.wait();
                    write_frame(&mut out, &v1_frame(&resp))?;
                }
            }
        }
    }
}

/// Execute one control frame and build its reply (errors included —
/// control failures never tear down the connection).
fn control_frame(router: &Router, j: &Json) -> Json {
    match parse_control(j) {
        Err(msg) => error_frame(None, &msg),
        Ok(Control::Stats) => obj(vec![
            ("event", s("stats")),
            ("replicas", Json::Arr(
                router.stats().iter().map(|r| r.to_json()).collect())),
        ]),
        Ok(Control::Drain(replica)) => match router.drain(replica) {
            Ok(()) => obj(vec![
                ("event", s("drain")),
                ("replica", num(replica as f64)),
                ("status", s("draining")),
            ]),
            Err(msg) => error_frame(None, &msg),
        },
    }
}

enum Control {
    Stats,
    Drain(usize),
}

/// Decode a control frame. Strict like `parse_request`: every key must
/// be expected for the command, and `replica` must be a non-negative
/// integer.
fn parse_control(j: &Json) -> Result<Control, String> {
    let Json::Obj(fields) = j else {
        return Err("control frame must be a JSON object".into());
    };
    let cmd = j
        .get("cmd")
        .and_then(Json::as_str)
        .ok_or_else(|| "cmd must be a string".to_string())?;
    let allowed: &[&str] = match cmd {
        "stats" => &["cmd"],
        "drain" => &["cmd", "replica"],
        other => {
            return Err(format!(
                "unknown cmd {other:?} (expected drain or stats)"));
        }
    };
    for k in fields.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(format!(
                "unknown field {k:?} for cmd {cmd:?}"));
        }
    }
    match cmd {
        "stats" => Ok(Control::Stats),
        _ => {
            let n = j
                .get("replica")
                .ok_or_else(|| "drain requires replica".to_string())?
                .as_f64()
                .ok_or_else(|| "replica must be a number".to_string())?;
            if !(n >= 0.0 && n.fract() == 0.0 && n <= 9.0e15) {
                return Err(format!(
                    "replica must be a non-negative integer (got {n})"));
            }
            Ok(Control::Drain(n as usize))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(text: &str) -> Result<Control, String> {
        parse_control(&Json::parse(text).unwrap())
    }

    #[test]
    fn control_frames_parse_strictly() {
        assert!(matches!(parse(r#"{"cmd":"stats"}"#),
                         Ok(Control::Stats)));
        assert!(matches!(parse(r#"{"cmd":"drain","replica":2}"#),
                         Ok(Control::Drain(2))));
        // Unknown fields are protocol errors.
        assert!(parse(r#"{"cmd":"stats","bogus":1}"#).is_err());
        assert!(parse(r#"{"cmd":"drain","replica":0,"force":true}"#)
            .is_err());
        // Missing/malformed replica.
        assert!(parse(r#"{"cmd":"drain"}"#).is_err());
        assert!(parse(r#"{"cmd":"drain","replica":-1}"#).is_err());
        assert!(parse(r#"{"cmd":"drain","replica":1.5}"#).is_err());
        assert!(parse(r#"{"cmd":"drain","replica":"0"}"#).is_err());
        // Unknown command / malformed cmd value.
        assert!(parse(r#"{"cmd":"restart"}"#).is_err());
        assert!(parse(r#"{"cmd":7}"#).is_err());
    }
}
