//! Serving metrics: counters, latency/TTFT recorders, ragged-batch
//! composition (rows per engine call, prefill-vs-decode row split, batch
//! occupancy — DESIGN.md §12), paged-KV packing (utilization +
//! block-allocation churn — DESIGN.md §13), and traffic shaping
//! (preemptions, SLO accounting, per-priority-class TTFT/TPOT
//! percentiles — DESIGN.md §15).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Duration;

use crate::util::json::{num, obj, Json};
use crate::util::stats::{summarize, Summary};

/// Machine-readable snapshot of one replica's live load — the signal
/// the router tier dispatches on (DESIGN.md §16). Produced by
/// `Scheduler::stats` (and `Server::stats` over the worker mailbox);
/// serialized onto the wire by the router gateway's `stats` control
/// frame.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReplicaStats {
    /// Replica index within the router's fleet (0 standalone).
    pub replica: usize,
    /// `true` while the router has stopped admissions to this replica
    /// (set by the router, never by the scheduler).
    pub draining: bool,
    /// Requests queued but not yet admitted.
    pub pending: usize,
    /// Requests mid-prefill.
    pub prefilling: usize,
    /// Active decode lanes.
    pub active: usize,
    /// Free KV blocks in this replica's arena.
    pub kv_available: usize,
    /// Total KV blocks in this replica's arena.
    pub kv_capacity: usize,
    /// Blocks pinned by this replica's radix prefix index.
    pub prefix_cached_blocks: usize,
    /// Cumulative completions (monotonic).
    pub requests_completed: u64,
    /// Cumulative generated tokens (monotonic).
    pub generated_tokens: u64,
    /// Cumulative prefix-cache lookups (monotonic).
    pub prefix_lookups: u64,
    /// Cumulative prefix-cache hits (monotonic).
    pub prefix_hits: u64,
    /// Active SIMD microkernel on this replica (`scalar`/`avx2`/
    /// `vnni`/`neon`) — surfaces per-host dispatch through the
    /// gateway's `stats` frame so a mixed fleet is debuggable without
    /// shelling into each box.
    pub kernel: String,
    /// Quantization mode of the replica's loaded bundle
    /// (`static`/`channel_static`/…, `fp` for an unquantized model).
    pub quant_mode: String,
}

impl ReplicaStats {
    /// Queue depth: everything submitted but not finished.
    pub fn depth(&self) -> usize {
        self.pending + self.prefilling + self.active
    }

    /// Blocks currently held (live sequences + prefix-pinned).
    pub fn kv_used(&self) -> usize {
        self.kv_capacity.saturating_sub(self.kv_available)
    }

    /// Current arena occupancy in [0, 1].
    pub fn kv_util(&self) -> f64 {
        if self.kv_capacity == 0 {
            0.0
        } else {
            self.kv_used() as f64 / self.kv_capacity as f64
        }
    }

    /// No live or queued work (drain-teardown condition).
    pub fn is_idle(&self) -> bool {
        self.depth() == 0
    }

    /// Fraction of admissions that matched a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Least-loaded dispatch key: lexicographic (queue depth, blocks
    /// held, replica index) — the index tie-break makes placement
    /// deterministic on an idle fleet.
    pub fn load_key(&self) -> (usize, usize, usize) {
        (self.depth(), self.kv_used(), self.replica)
    }

    /// Wire shape of the router gateway's `stats` control frame.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("replica", num(self.replica as f64)),
            ("draining", Json::Bool(self.draining)),
            ("pending", num(self.pending as f64)),
            ("prefilling", num(self.prefilling as f64)),
            ("active", num(self.active as f64)),
            ("kv_available", num(self.kv_available as f64)),
            ("kv_capacity", num(self.kv_capacity as f64)),
            ("kv_util", num(self.kv_util())),
            ("prefix_cached_blocks",
             num(self.prefix_cached_blocks as f64)),
            ("requests_completed",
             num(self.requests_completed as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("prefix_hit_rate", num(self.prefix_hit_rate())),
            ("kernel", Json::Str(self.kernel.clone())),
            ("quant_mode", Json::Str(self.quant_mode.clone())),
        ])
    }
}

/// Router-tier counters (DESIGN.md §16): where requests went, how often
/// session affinity found its pinned replica, and the drain/respawn
/// history. Per-replica serving metrics stay inside each replica's own
/// [`Metrics`]; this struct only accounts for placement.
#[derive(Clone, Debug, Default)]
pub struct RouterMetrics {
    /// Requests dispatched per replica index.
    pub dispatched: Vec<u64>,
    /// Session-carrying requests that landed on their pinned replica.
    pub affinity_hits: u64,
    /// Session-carrying requests that had no live pin (first turn, or
    /// pin invalidated by drain/respawn) and were (re)pinned.
    pub affinity_misses: u64,
    /// Sessions whose pin pointed at a draining or respawned replica
    /// and was moved to a live one (the re-route path).
    pub rerouted: u64,
    /// Drain commands accepted.
    pub drains: u64,
    /// Replicas torn down and re-spawned after draining idle.
    pub respawns: u64,
    /// Dispatches retried on the next-least-loaded replica because the
    /// chosen one answered queue-full.
    pub failovers: u64,
}

impl RouterMetrics {
    /// Grow the per-replica dispatch table to `n` replicas.
    pub fn ensure_replicas(&mut self, n: usize) {
        if self.dispatched.len() < n {
            self.dispatched.resize(n, 0);
        }
    }

    /// Fraction of session-carrying dispatches that hit their pin.
    pub fn affinity_hit_rate(&self) -> f64 {
        let total = self.affinity_hits + self.affinity_misses;
        if total == 0 {
            0.0
        } else {
            self.affinity_hits as f64 / total as f64
        }
    }

    /// One-line router-aggregate report; `stats` contributes the live
    /// per-replica kv_util tail. Greppable like `Metrics::report`.
    pub fn report(&self, stats: &[ReplicaStats]) -> String {
        let join = |it: &mut dyn Iterator<Item = String>| {
            it.collect::<Vec<_>>().join(",")
        };
        let dispatch =
            join(&mut self.dispatched.iter().map(|d| d.to_string()));
        let util = join(&mut stats
            .iter()
            .map(|r| format!("{:.2}", r.kv_util())));
        let depth =
            join(&mut stats.iter().map(|r| r.depth().to_string()));
        format!(
            "router: replicas={} dispatch=[{}] affinity_hits={} \
             affinity_misses={} affinity_hit_rate={:.3} rerouted={} \
             drains={} respawns={} failovers={} kv_util=[{}] \
             depth=[{}]",
            self.dispatched.len(),
            dispatch,
            self.affinity_hits,
            self.affinity_misses,
            self.affinity_hit_rate(),
            self.rerouted,
            self.drains,
            self.respawns,
            self.failovers,
            util,
            depth,
        )
    }
}

#[derive(Default)]
pub struct Metrics {
    pub requests_completed: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub decode_iterations: u64,
    /// Prefill spans executed (one per whole-prompt admission; one per
    /// chunk under chunked prefill).
    pub prefill_calls: u64,
    /// Unified ragged engine calls (`Engine::forward_batch`) — exactly
    /// one per scheduler iteration that had any work.
    pub forward_calls: u64,
    /// Total prefill rows stacked into ragged batches.
    pub prefill_rows: u64,
    /// Total decode rows (one per decode lane per iteration).
    pub decode_rows: u64,
    pub peak_active: usize,
    pub rejected: u64,
    /// Requests terminated by a typed engine error (per-request failure
    /// path — e.g. KV-cache overflow) rather than normal completion.
    pub failed: u64,
    /// Requests torn out of the batch (or out of the pending queue) by
    /// cancellation — client-initiated, so they count neither as
    /// completions nor as failures.
    pub cancelled: u64,
    /// Cumulative KV blocks handed to sequences (paged-allocation churn;
    /// mirrored from the `BlockPool` each iteration — DESIGN.md §13).
    pub blocks_alloc: u64,
    /// Cumulative KV blocks reclaimed from finished/cancelled sequences.
    pub blocks_freed: u64,
    /// Prefills pushed back to the pending queue by pool-exhaustion
    /// stall resolution (transient backpressure, not failures).
    pub kv_requeues: u64,
    /// Decode lanes transparently preempted by a strictly-higher-class
    /// demander under block pressure (DESIGN.md §15): blocks released,
    /// generation state requeued, stream resumed bitwise later — never
    /// a failure, never visible in the event stream.
    pub preemptions: u64,
    /// Completions whose end-to-end latency exceeded their request's
    /// `deadline_ms` (observational SLO accounting).
    pub slo_violations: u64,
    /// Iterations whose admissions were deferred because the last
    /// decode-bearing engine call ran over `max_decode_latency`.
    pub slo_deferrals: u64,
    /// Prefix-cache admissions examined (one per admitted request while
    /// `prefix_cache` is on — DESIGN.md §14).
    pub prefix_lookups: u64,
    /// Admissions that matched a cached prefix (≥ 1 token skipped).
    pub prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped by attaching cached
    /// blocks instead of recomputing them.
    pub prefix_matched_tokens: u64,
    /// Blocks currently pinned by the radix index (gauge, mirrored each
    /// iteration).
    pub prefix_cached_blocks: u64,
    /// Cumulative blocks dropped from the radix index (capacity LRU +
    /// pool-pressure eviction).
    pub prefix_evicted_blocks: u64,
    /// Peak distinct physical blocks referenced by ≥ 2 live block
    /// tables at once.
    pub prefix_shared_blocks: u64,
    /// Live-lane block-table entries backed by unshared blocks at the
    /// sharing peak.
    pub prefix_private_blocks: u64,
    /// Peak KV bytes saved by sharing: table entries beyond the
    /// distinct physical blocks behind them, times block bytes.
    pub prefix_bytes_saved: u64,
    /// Draft-engine forward calls (one per proposed token; DESIGN.md
    /// §18). Zero whenever speculation is off — the gate for the
    /// speculative report tail.
    pub draft_forwards: u64,
    /// Target-engine verify spans carrying a non-empty draft (each
    /// rides the iteration's single ragged forward call).
    pub verify_forwards: u64,
    /// Draft tokens proposed for verification.
    pub draft_proposed: u64,
    /// Draft tokens the target's sampled stream confirmed.
    pub draft_accepted: u64,
    /// Tokens emitted by decode spans (speculative spans emit up to
    /// `draft_k + 1` each; plain decodes exactly 1). Excludes the
    /// first token of each stream, which prefill emits.
    pub decode_tokens: u64,
    latencies_s: Vec<f64>,
    ttfts_s: Vec<f64>,
    /// Per-priority-class TTFT samples (seconds) — the per-class
    /// latency story preemption exists to shape.
    class_ttfts_s: BTreeMap<u8, Vec<f64>>,
    /// Per-priority-class TPOT samples (seconds per generated token
    /// after the first; requests with one token contribute none).
    class_tpots_s: BTreeMap<u8, Vec<f64>>,
    batch_sizes: Vec<f64>,
    rows_per_iter: Vec<f64>,
    occupancy: Vec<f64>,
    /// Per-iteration KV utilization samples: used tokens over allocated
    /// block tokens (1.0 = perfectly packed arena).
    kv_util: Vec<f64>,
    kv_util_peak: f64,
}

impl Metrics {
    pub fn record_completion(&mut self, latency: Duration, ttft: Duration,
                             prompt_len: usize, generated: usize,
                             class: u8, deadline_ms: Option<u64>) {
        self.requests_completed += 1;
        self.prompt_tokens += prompt_len as u64;
        self.generated_tokens += generated as u64;
        self.latencies_s.push(latency.as_secs_f64());
        self.ttfts_s.push(ttft.as_secs_f64());
        self.class_ttfts_s
            .entry(class)
            .or_default()
            .push(ttft.as_secs_f64());
        if generated > 1 {
            let tpot = latency.saturating_sub(ttft).as_secs_f64()
                / (generated - 1) as f64;
            self.class_tpots_s.entry(class).or_default().push(tpot);
        }
        if let Some(d) = deadline_ms {
            if latency.as_secs_f64() * 1e3 > d as f64 {
                self.slo_violations += 1;
            }
        }
    }

    /// Per-class TTFT summary (`None` when the class saw no
    /// completions).
    pub fn class_ttft_summary(&self, class: u8) -> Option<Summary> {
        self.class_ttfts_s.get(&class).map(|v| summarize(v))
    }

    /// Per-class TPOT summary (seconds per post-first token).
    pub fn class_tpot_summary(&self, class: u8) -> Option<Summary> {
        self.class_tpots_s.get(&class).map(|v| summarize(v))
    }

    pub fn record_decode_iter(&mut self, batch: usize) {
        self.decode_iterations += 1;
        self.batch_sizes.push(batch as f64);
        self.peak_active = self.peak_active.max(batch);
    }

    /// Record one ragged engine call: total stacked rows, the
    /// prefill/decode row split, and batch occupancy (lanes riding the
    /// call over `max_batch` capacity).
    pub fn record_forward(&mut self, rows: usize, prefill_rows: usize,
                          decode_rows: usize, lanes: usize,
                          max_batch: usize) {
        self.forward_calls += 1;
        self.prefill_rows += prefill_rows as u64;
        self.decode_rows += decode_rows as u64;
        self.rows_per_iter.push(rows as f64);
        if max_batch > 0 {
            self.occupancy.push(lanes as f64 / max_batch as f64);
        }
    }

    /// Record one iteration's KV packing: `used` tokens actually cached
    /// over `allocated` tokens of reserved block storage. Iterations
    /// with nothing allocated are skipped (no sequences, no packing to
    /// measure).
    pub fn record_kv(&mut self, used: usize, allocated: usize) {
        if allocated == 0 {
            return;
        }
        let util = used as f64 / allocated as f64;
        self.kv_util.push(util);
        self.kv_util_peak = self.kv_util_peak.max(util);
    }

    /// Record one iteration's sharing snapshot (peaks are kept: the
    /// high-water mark is the capacity story).
    pub fn record_prefix_sharing(&mut self, shared: u64, private: u64,
                                 bytes_saved: u64) {
        if bytes_saved >= self.prefix_bytes_saved {
            self.prefix_bytes_saved = bytes_saved;
            self.prefix_private_blocks = private;
        }
        self.prefix_shared_blocks = self.prefix_shared_blocks.max(shared);
    }

    /// Fraction of admissions that matched a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookups == 0 {
            0.0
        } else {
            self.prefix_hits as f64 / self.prefix_lookups as f64
        }
    }

    /// Fraction of proposed draft tokens the target stream confirmed
    /// (DESIGN.md §18). 1.0 for a full-depth greedy self-draft.
    pub fn acceptance_rate(&self) -> f64 {
        if self.draft_proposed == 0 {
            0.0
        } else {
            self.draft_accepted as f64 / self.draft_proposed as f64
        }
    }

    /// Mean tokens emitted per decode-bearing target forward — the
    /// speculative speedup headline (1.0 without speculation; up to
    /// `draft_k + 1` at full acceptance).
    pub fn tokens_per_forward(&self) -> f64 {
        if self.decode_iterations == 0 {
            0.0
        } else {
            self.decode_tokens as f64 / self.decode_iterations as f64
        }
    }

    /// Mean per-iteration KV utilization (used/allocated block tokens).
    pub fn kv_util_mean(&self) -> f64 {
        summarize(&self.kv_util).mean
    }

    /// Peak per-iteration KV utilization.
    pub fn kv_util_peak(&self) -> f64 {
        self.kv_util_peak
    }

    pub fn latency_summary(&self) -> Summary {
        summarize(&self.latencies_s)
    }

    pub fn ttft_summary(&self) -> Summary {
        summarize(&self.ttfts_s)
    }

    pub fn mean_batch_size(&self) -> f64 {
        summarize(&self.batch_sizes).mean
    }

    /// Mean stacked rows per ragged engine call.
    pub fn mean_rows_per_iter(&self) -> f64 {
        summarize(&self.rows_per_iter).mean
    }

    /// Mean fraction of `max_batch` lanes riding each engine call.
    pub fn mean_occupancy(&self) -> f64 {
        summarize(&self.occupancy).mean
    }

    pub fn report(&self) -> String {
        let lat = self.latency_summary();
        let ttft = self.ttft_summary();
        let mut s = format!(
            "requests={} prompt_toks={} gen_toks={} decode_iters={} \
             mean_batch={:.2} peak_batch={} failed={} cancelled={} \
             lat_p50={:.1}ms lat_p99={:.1}ms ttft_p50={:.1}ms \
             fwd_calls={} rows/iter={:.1} prefill_rows={} decode_rows={} \
             occupancy={:.2} kv_util={:.2} kv_util_peak={:.2} \
             blocks_alloc={} blocks_freed={} kv_requeues={} \
             preemptions={} slo_violations={} slo_deferrals={} \
             prefix_hit_rate={:.3} prefix_hits={} prefix_lookups={} \
             prefix_matched_toks={} prefix_cached_blocks={} \
             prefix_shared_blocks={} prefix_evicted_blocks={} \
             prefix_bytes_saved={}",
            self.requests_completed,
            self.prompt_tokens,
            self.generated_tokens,
            self.decode_iterations,
            self.mean_batch_size(),
            self.peak_active,
            self.failed,
            self.cancelled,
            lat.p50 * 1e3,
            lat.p99 * 1e3,
            ttft.p50 * 1e3,
            self.forward_calls,
            self.mean_rows_per_iter(),
            self.prefill_rows,
            self.decode_rows,
            self.mean_occupancy(),
            self.kv_util_mean(),
            self.kv_util_peak(),
            self.blocks_alloc,
            self.blocks_freed,
            self.kv_requeues,
            self.preemptions,
            self.slo_violations,
            self.slo_deferrals,
            self.prefix_hit_rate(),
            self.prefix_hits,
            self.prefix_lookups,
            self.prefix_matched_tokens,
            self.prefix_cached_blocks,
            self.prefix_shared_blocks,
            self.prefix_evicted_blocks,
            self.prefix_bytes_saved,
        );
        // Speculative tail only when a draft engine actually ran —
        // non-speculative deployments keep the pre-§18 report shape.
        if self.draft_forwards > 0 {
            let _ = write!(
                s,
                " draft_forwards={} verify_forwards={} \
                 draft_proposed={} draft_accepted={} \
                 acceptance_rate={:.3} tokens_per_forward={:.2}",
                self.draft_forwards,
                self.verify_forwards,
                self.draft_proposed,
                self.draft_accepted,
                self.acceptance_rate(),
                self.tokens_per_forward(),
            );
        }
        // Per-class latency tail only when classes are actually in
        // play (>1 class, or any non-default class) — uniform default
        // traffic keeps the pre-§15 report shape.
        let classed = self.class_ttfts_s.len() > 1
            || self.class_ttfts_s.keys().any(|&c| c != 0);
        if classed {
            for (c, v) in &self.class_ttfts_s {
                let t = summarize(v);
                let _ = write!(
                    s,
                    " c{}_n={} c{}_ttft_p50={:.1}ms c{}_ttft_p95={:.1}ms",
                    c, t.n, c, t.p50 * 1e3, c, t.p95 * 1e3,
                );
            }
            for (c, v) in &self.class_tpots_s {
                let t = summarize(v);
                let _ = write!(
                    s,
                    " c{}_tpot_p50={:.2}ms c{}_tpot_p95={:.2}ms",
                    c, t.p50 * 1e3, c, t.p95 * 1e3,
                );
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::default();
        m.record_completion(Duration::from_millis(100),
                            Duration::from_millis(10), 8, 4, 0, None);
        m.record_completion(Duration::from_millis(200),
                            Duration::from_millis(20), 16, 8, 0, None);
        m.record_decode_iter(2);
        assert_eq!(m.requests_completed, 2);
        assert_eq!(m.prompt_tokens, 24);
        assert_eq!(m.generated_tokens, 12);
        assert_eq!(m.peak_active, 2);
        assert!((m.latency_summary().mean - 0.15).abs() < 1e-9);
        assert!(!m.report().is_empty());
        // Uniform class-0 traffic keeps the pre-§15 report shape: no
        // per-class tail.
        assert!(!m.report().contains("c0_ttft_p50"), "{}", m.report());
    }

    #[test]
    fn slo_and_class_percentiles_accumulate() {
        let mut m = Metrics::default();
        // Class 0, deadline met (latency 100ms <= 500ms).
        m.record_completion(Duration::from_millis(100),
                            Duration::from_millis(10), 8, 4, 0, Some(500));
        // Class 0, deadline missed (an impossible 0ms target).
        m.record_completion(Duration::from_millis(100),
                            Duration::from_millis(10), 8, 4, 0, Some(0));
        // Class 2: 30ms TTFT, 90ms of decode over 9 post-first tokens
        // = 10ms TPOT.
        m.record_completion(Duration::from_millis(120),
                            Duration::from_millis(30), 8, 10, 2, None);
        // One-token completion contributes a TTFT sample but no TPOT.
        m.record_completion(Duration::from_millis(40),
                            Duration::from_millis(40), 8, 1, 2, None);
        assert_eq!(m.slo_violations, 1);
        let t0 = m.class_ttft_summary(0).unwrap();
        assert_eq!(t0.n, 2);
        assert!((t0.p50 - 0.010).abs() < 1e-9);
        let t2 = m.class_tpot_summary(2).unwrap();
        assert_eq!(t2.n, 1);
        assert!((t2.p50 - 0.010).abs() < 1e-9);
        assert!(m.class_ttft_summary(1).is_none());
        m.preemptions = 3;
        m.slo_deferrals = 2;
        let r = m.report();
        assert!(r.contains("preemptions=3"), "{r}");
        assert!(r.contains("slo_violations=1"), "{r}");
        assert!(r.contains("slo_deferrals=2"), "{r}");
        assert!(r.contains("c0_n=2"), "{r}");
        assert!(r.contains("c0_ttft_p50=10.0ms"), "{r}");
        assert!(r.contains("c2_ttft_p95=40.0ms"), "{r}");
        assert!(r.contains("c2_tpot_p50=10.00ms"), "{r}");
    }

    #[test]
    fn batch_composition_accumulates() {
        let mut m = Metrics::default();
        // Tick 1: one 8-row prefill span + 3 decode lanes, 4 of 8 slots.
        m.record_forward(11, 8, 3, 4, 8);
        // Tick 2: pure decode, 4 lanes.
        m.record_forward(4, 0, 4, 4, 8);
        assert_eq!(m.forward_calls, 2);
        assert_eq!(m.prefill_rows, 8);
        assert_eq!(m.decode_rows, 7);
        assert!((m.mean_rows_per_iter() - 7.5).abs() < 1e-9);
        assert!((m.mean_occupancy() - 0.5).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("fwd_calls=2"), "{r}");
        assert!(r.contains("prefill_rows=8"), "{r}");
    }

    #[test]
    fn kv_utilization_accumulates() {
        let mut m = Metrics::default();
        // Iteration 1: 24 tokens cached in 64 allocated (0.375); then a
        // better-packed iteration (48/64 = 0.75); an idle iteration with
        // nothing allocated must not skew the mean.
        m.record_kv(24, 64);
        m.record_kv(48, 64);
        m.record_kv(0, 0);
        assert!((m.kv_util_mean() - 0.5625).abs() < 1e-9);
        assert!((m.kv_util_peak() - 0.75).abs() < 1e-9);
        m.blocks_alloc = 7;
        m.blocks_freed = 5;
        let r = m.report();
        assert!(r.contains("kv_util=0.56"), "{r}");
        assert!(r.contains("kv_util_peak=0.75"), "{r}");
        assert!(r.contains("blocks_alloc=7"), "{r}");
        assert!(r.contains("blocks_freed=5"), "{r}");
    }

    #[test]
    fn replica_stats_derived_fields() {
        let r = ReplicaStats {
            replica: 1,
            pending: 2,
            prefilling: 1,
            active: 3,
            kv_available: 6,
            kv_capacity: 24,
            prefix_cached_blocks: 4,
            prefix_lookups: 8,
            prefix_hits: 6,
            ..ReplicaStats::default()
        };
        assert_eq!(r.depth(), 6);
        assert_eq!(r.kv_used(), 18);
        assert!((r.kv_util() - 0.75).abs() < 1e-9);
        assert!((r.prefix_hit_rate() - 0.75).abs() < 1e-9);
        assert!(!r.is_idle());
        assert_eq!(r.load_key(), (6, 18, 1));
        let idle = ReplicaStats { kv_capacity: 8, kv_available: 8,
                                  ..ReplicaStats::default() };
        assert!(idle.is_idle());
        assert_eq!(idle.kv_util(), 0.0);
        let j = r.to_json();
        assert_eq!(j.get("replica").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("draining").and_then(Json::as_bool),
                   Some(false));
        assert_eq!(j.get("kv_util").and_then(Json::as_f64), Some(0.75));
    }

    #[test]
    fn router_metrics_report_shape() {
        let mut m = RouterMetrics::default();
        m.ensure_replicas(2);
        m.dispatched[0] = 5;
        m.dispatched[1] = 3;
        m.affinity_hits = 4;
        m.affinity_misses = 2;
        m.rerouted = 1;
        m.drains = 1;
        m.respawns = 1;
        assert!((m.affinity_hit_rate() - 4.0 / 6.0).abs() < 1e-9);
        let stats = vec![
            ReplicaStats { replica: 0, kv_capacity: 8, kv_available: 6,
                           ..ReplicaStats::default() },
            ReplicaStats { replica: 1, kv_capacity: 8, kv_available: 8,
                           active: 1, ..ReplicaStats::default() },
        ];
        let r = m.report(&stats);
        assert!(r.contains("replicas=2"), "{r}");
        assert!(r.contains("dispatch=[5,3]"), "{r}");
        assert!(r.contains("affinity_hit_rate=0.667"), "{r}");
        assert!(r.contains("drains=1"), "{r}");
        assert!(r.contains("kv_util=[0.25,0.00]"), "{r}");
        assert!(r.contains("depth=[0,1]"), "{r}");
        // Hit rate with no session traffic reads 0, not NaN.
        assert_eq!(RouterMetrics::default().affinity_hit_rate(), 0.0);
    }

    #[test]
    fn prefix_sharing_accumulates_and_reports() {
        let mut m = Metrics::default();
        m.prefix_lookups = 8;
        m.prefix_hits = 6;
        m.prefix_matched_tokens = 96;
        m.prefix_cached_blocks = 4;
        m.record_prefix_sharing(2, 5, 4096);
        m.record_prefix_sharing(3, 1, 2048); // lower peak: bytes kept
        assert!((m.prefix_hit_rate() - 0.75).abs() < 1e-9);
        assert_eq!(m.prefix_bytes_saved, 4096);
        assert_eq!(m.prefix_shared_blocks, 3);
        assert_eq!(m.prefix_private_blocks, 5);
        let r = m.report();
        assert!(r.contains("prefix_hit_rate=0.750"), "{r}");
        assert!(r.contains("prefix_matched_toks=96"), "{r}");
        assert!(r.contains("prefix_bytes_saved=4096"), "{r}");
    }

    #[test]
    fn speculative_tail_gated_and_derived() {
        let mut m = Metrics::default();
        // No draft forwards ⇒ the pre-§18 report shape, tail absent.
        assert!(!m.report().contains("acceptance_rate="), "{}",
                m.report());
        assert_eq!(m.acceptance_rate(), 0.0);
        assert_eq!(m.tokens_per_forward(), 0.0);
        // 3 verify iterations emitting 10 tokens off 12 proposals of
        // which 8 verified: acceptance 0.667, 3.33 tokens/forward.
        m.draft_forwards = 12;
        m.verify_forwards = 3;
        m.draft_proposed = 12;
        m.draft_accepted = 8;
        m.decode_tokens = 10;
        m.decode_iterations = 3;
        assert!((m.acceptance_rate() - 8.0 / 12.0).abs() < 1e-9);
        assert!((m.tokens_per_forward() - 10.0 / 3.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("draft_forwards=12"), "{r}");
        assert!(r.contains("verify_forwards=3"), "{r}");
        assert!(r.contains("acceptance_rate=0.667"), "{r}");
        assert!(r.contains("tokens_per_forward=3.33"), "{r}");
    }

    #[test]
    fn replica_stats_carry_kernel_and_quant_mode() {
        let r = ReplicaStats {
            kernel: "avx2".into(),
            quant_mode: "channel_static".into(),
            ..ReplicaStats::default()
        };
        let j = r.to_json();
        assert_eq!(j.get("kernel").and_then(Json::as_str),
                   Some("avx2"));
        assert_eq!(j.get("quant_mode").and_then(Json::as_str),
                   Some("channel_static"));
    }
}
