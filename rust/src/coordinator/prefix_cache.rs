//! Radix prefix index over frozen KV blocks (DESIGN.md §14).
//!
//! The trie is keyed on token ids in runs of exactly `block_tokens`
//! (B): every edge carries a B-token segment plus an `Arc` handle to
//! the physical [`KvBlock`] holding those tokens' K/V rows, so a path
//! from the root spells out a cached prompt prefix block by block.
//! Edges are never split — a prompt that diverges *inside* a block
//! matches that edge partially (the longest common prefix `r`, `0 < r
//! < B`) and borrows the edge's **full** block as its partially-filled
//! boundary block; the scheduler copies-on-write the `r` frozen rows
//! before the lane's first write. Because cached KV rows are bitwise
//! identical across batch compositions (the repo's standing
//! invariant), attaching them instead of recomputing prefill changes
//! no output bit.
//!
//! A lookup never matches a whole prompt: the match is capped at
//! `prompt.len() - 1` so the final prompt token is always computed —
//! its forward row produces the first-token logits, making TTFT on a
//! full hit ≈ one decode step.
//!
//! Eviction is LRU over *leaf* edges only (interior edges are pinned
//! by their children, keeping cached prefixes contiguous), driven by
//! an internal deterministic clock — no wall time, so traces replay
//! exactly. Evicted handles flow back through
//! [`BlockPool::reclaim`](crate::coordinator::BlockPool::reclaim),
//! which returns a block to the free list only when the trie held its
//! last reference.

use std::sync::Arc;

use crate::engine::{KvBlock, KvCache};

struct Edge {
    tokens: Vec<u32>,
    block: Arc<KvBlock>,
    last_used: u64,
    child: Node,
}

#[derive(Default)]
struct Node {
    edges: Vec<Edge>,
}

pub struct PrefixCache {
    root: Node,
    block_tokens: usize,
    /// Edge-count cap; 0 means unbounded (pressure-driven eviction
    /// only).
    capacity_blocks: usize,
    /// Deterministic LRU clock, bumped once per lookup/insert.
    clock: u64,
    cached_blocks: usize,
}

/// Longest common prefix of two token runs.
fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

impl PrefixCache {
    pub fn new(block_tokens: usize, capacity_blocks: usize) -> Self {
        PrefixCache {
            root: Node::default(),
            block_tokens: block_tokens.max(1),
            capacity_blocks,
            clock: 0,
            cached_blocks: 0,
        }
    }

    /// Edges (= blocks) currently held by the trie. Some may also be
    /// held by live sequences; distinct physical storage either way.
    pub fn cached_blocks(&self) -> usize {
        self.cached_blocks
    }

    pub fn is_empty(&self) -> bool {
        self.cached_blocks == 0
    }

    /// Match `prompt` against the cached prefixes. Returns the matched
    /// token count `p` (capped at `prompt.len() - 1`) and the
    /// `ceil(p / B)` block handles covering it, in table order; when
    /// `p % B != 0` the final handle is the *full* block whose first
    /// `p % B` rows matched (the borrower's boundary block, CoW'd
    /// before its first write). Deterministic: full-segment matches are
    /// unique by construction, and partial ties break to the
    /// oldest-inserted edge.
    pub fn lookup(&mut self, prompt: &[u32])
                  -> (usize, Vec<Arc<KvBlock>>) {
        let limit = prompt.len().saturating_sub(1);
        let mut matched = 0usize;
        let mut arcs = Vec::new();
        if limit == 0 {
            return (matched, arcs);
        }
        self.clock += 1;
        let clock = self.clock;
        let mut node = &mut self.root;
        loop {
            let rest = &prompt[matched..limit];
            let mut full: Option<usize> = None;
            let mut best: Option<(usize, usize)> = None; // (idx, r)
            for (i, e) in node.edges.iter().enumerate() {
                let l = lcp(&e.tokens, rest);
                if l == e.tokens.len() {
                    full = Some(i);
                    break;
                }
                if l > 0 && best.is_none_or(|(_, br)| l > br) {
                    best = Some((i, l));
                }
            }
            if let Some(i) = full {
                let e = &mut node.edges[i];
                e.last_used = clock;
                arcs.push(Arc::clone(&e.block));
                matched += e.tokens.len();
                let here = node;
                node = &mut here.edges[i].child;
                continue;
            }
            if let Some((i, r)) = best {
                let e = &mut node.edges[i];
                e.last_used = clock;
                arcs.push(Arc::clone(&e.block));
                matched += r;
            }
            break;
        }
        (matched, arcs)
    }

    /// Record `key`'s frozen full blocks (the first `B·⌊key.len()/B⌋`
    /// positions of `cache`) under the trie. Idempotent: existing edges
    /// are reused (and LRU-touched), so re-inserting a growing sequence
    /// every iteration costs one walk. Returns any handles evicted to
    /// respect `capacity_blocks` — the caller must hand them to
    /// [`BlockPool::reclaim`](crate::coordinator::BlockPool::reclaim).
    #[must_use]
    pub fn insert(&mut self, key: &[u32], cache: &KvCache)
                  -> Vec<Arc<KvBlock>> {
        let bt = self.block_tokens;
        debug_assert_eq!(cache.block_tokens(), bt,
                         "cache from a different pool");
        let full = key.len() / bt;
        if full > 0 {
            self.clock += 1;
            let clock = self.clock;
            let mut node = &mut self.root;
            for b in 0..full {
                let seg = &key[b * bt..(b + 1) * bt];
                let idx = match node.edges.iter()
                                         .position(|e| e.tokens == seg) {
                    Some(i) => {
                        node.edges[i].last_used = clock;
                        i
                    }
                    None => {
                        node.edges.push(Edge {
                            tokens: seg.to_vec(),
                            block: cache.block_arc(b),
                            last_used: clock,
                            child: Node::default(),
                        });
                        self.cached_blocks += 1;
                        node.edges.len() - 1
                    }
                };
                let here = node;
                node = &mut here.edges[idx].child;
            }
        }
        let mut evicted = Vec::new();
        if self.capacity_blocks > 0 {
            while self.cached_blocks > self.capacity_blocks {
                match self.evict_lru_leaf() {
                    Some(a) => evicted.push(a),
                    None => break,
                }
            }
        }
        evicted
    }

    /// Evict the least-recently-used *leaf* edge and return its block
    /// handle for pool reclamation. Interior edges are pinned by their
    /// children; ties break to the first edge in depth-first order.
    pub fn evict_lru_leaf(&mut self) -> Option<Arc<KvBlock>> {
        fn min_leaf(node: &Node) -> Option<u64> {
            let mut m: Option<u64> = None;
            for e in &node.edges {
                let c = if e.child.edges.is_empty() {
                    Some(e.last_used)
                } else {
                    min_leaf(&e.child)
                };
                m = match (m, c) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
            }
            m
        }
        fn remove_leaf(node: &mut Node, target: u64)
                       -> Option<Arc<KvBlock>> {
            for i in 0..node.edges.len() {
                if node.edges[i].child.edges.is_empty() {
                    if node.edges[i].last_used == target {
                        return Some(node.edges.remove(i).block);
                    }
                } else if let Some(a) =
                    remove_leaf(&mut node.edges[i].child, target)
                {
                    return Some(a);
                }
            }
            None
        }
        let target = min_leaf(&self.root)?;
        let block = remove_leaf(&mut self.root, target)
            .expect("leaf with the minimal clock exists");
        self.cached_blocks -= 1;
        Some(block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BlockPool;
    use crate::engine::KvDtype;

    fn pool() -> BlockPool {
        // 16 blocks × 4 tokens, max_seq 32, 1 layer, d 8
        BlockPool::with_dtype(KvDtype::F32, 16, 4, 1, 32, 8)
    }

    fn seq(p: &mut BlockPool, tokens: usize) -> crate::engine::KvCache {
        let mut c = p.new_sequence();
        p.reserve(&mut c, tokens).unwrap();
        c.len = tokens;
        c
    }

    #[test]
    fn full_and_partial_matches_are_block_granular() {
        let mut p = pool();
        let mut pc = PrefixCache::new(4, 0);
        let key: Vec<u32> = (0..10).collect();
        let c = seq(&mut p, 10);
        assert!(pc.insert(&key, &c).is_empty());
        assert_eq!(pc.cached_blocks(), 2, "only the 2 full blocks");

        // exact continuation: both full blocks match, 3rd token run
        // diverges inside the (uncached) tail
        let (m, arcs) = pc.lookup(&[0, 1, 2, 3, 4, 5, 6, 7, 99, 98]);
        assert_eq!(m, 8);
        assert_eq!(arcs.len(), 2);
        assert_eq!(Arc::as_ptr(&arcs[0]), c.block_ptr(0));
        assert_eq!(Arc::as_ptr(&arcs[1]), c.block_ptr(1));

        // divergence inside the second block: partial borrow of its
        // full block
        let (m, arcs) = pc.lookup(&[0, 1, 2, 3, 4, 5, 77, 76, 75]);
        assert_eq!(m, 6);
        assert_eq!(arcs.len(), 2);
        assert_eq!(Arc::as_ptr(&arcs[1]), c.block_ptr(1));

        // no shared first block: miss
        let (m, arcs) = pc.lookup(&[9, 9, 9, 9, 9]);
        assert_eq!(m, 0);
        assert!(arcs.is_empty());
    }

    #[test]
    fn match_never_covers_the_final_prompt_token() {
        let mut p = pool();
        let mut pc = PrefixCache::new(4, 0);
        let key: Vec<u32> = (0..8).collect();
        let c = seq(&mut p, 8);
        let _ = pc.insert(&key, &c);
        // identical prompt: cap at len-1 = 7 → one full block + 3 rows
        // of the second, borrowed as a partial boundary
        let (m, arcs) = pc.lookup(&key);
        assert_eq!(m, 7);
        assert_eq!(arcs.len(), 2);
        // single-token prompts can never match
        let (m, arcs) = pc.lookup(&[0]);
        assert_eq!(m, 0);
        assert!(arcs.is_empty());
    }

    #[test]
    fn insert_is_idempotent_and_dedups_against_existing_edges() {
        let mut p = pool();
        let mut pc = PrefixCache::new(4, 0);
        let key: Vec<u32> = (0..8).collect();
        let a = seq(&mut p, 8);
        let _ = pc.insert(&key, &a);
        let _ = pc.insert(&key, &a);
        assert_eq!(pc.cached_blocks(), 2);
        // a second sequence with the same history reuses a's blocks
        let b = seq(&mut p, 8);
        let _ = pc.insert(&key, &b);
        assert_eq!(pc.cached_blocks(), 2);
        let (_, arcs) = pc.lookup(&[0, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(Arc::as_ptr(&arcs[0]), a.block_ptr(0));
    }

    #[test]
    fn lru_eviction_takes_leaves_first_and_respects_capacity() {
        let mut p = pool();
        let mut pc = PrefixCache::new(4, 3);
        let shared: Vec<u32> = (0..4).collect();
        let mut key_a = shared.clone();
        key_a.extend([100, 101, 102, 103]);
        let mut key_b = shared.clone();
        key_b.extend([200, 201, 202, 203]);
        let a = seq(&mut p, 8);
        let b = seq(&mut p, 8);
        assert!(pc.insert(&key_a, &a).is_empty());
        assert!(pc.insert(&key_b, &b).is_empty()); // 3 edges: at cap
        // touch a's leaf so b's leaf is LRU
        let (m, _) = pc.lookup(&[&key_a[..], &[1]].concat());
        assert_eq!(m, 8);
        let mut key_c = shared.clone();
        key_c.extend([300, 301, 302, 303]);
        let c = seq(&mut p, 8);
        let evicted = pc.insert(&key_c, &c);
        assert_eq!(evicted.len(), 1, "capacity 3: one leaf evicted");
        assert_eq!(Arc::as_ptr(&evicted[0]), b.block_ptr(1),
                   "b's leaf was least recently used");
        assert_eq!(pc.cached_blocks(), 3);
        // the shared interior edge is pinned while leaves exist
        let (m, _) = pc.lookup(&[&key_a[..], &[1]].concat());
        assert_eq!(m, 8, "a's path survived");
    }

    #[test]
    fn evicted_blocks_flow_back_to_the_pool() {
        let mut p = pool();
        let mut pc = PrefixCache::new(4, 0);
        let key: Vec<u32> = (0..8).collect();
        let mut c = seq(&mut p, 8);
        let _ = pc.insert(&key, &c);
        p.release(&mut c);
        assert_eq!(p.free_blocks(), 14, "trie still pins both blocks");
        while let Some(a) = pc.evict_lru_leaf() {
            p.reclaim(a);
        }
        assert!(pc.is_empty());
        assert_eq!(p.free_blocks(), 16);
        assert_eq!(p.blocks_alloc(), p.blocks_freed());
    }
}
