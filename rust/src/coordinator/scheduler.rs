//! Iteration-level (continuous-batching) scheduler.
//!
//! Owns the engine, a KV pool and the pending queue. Each call to
//! [`Scheduler::step`] performs one scheduling iteration:
//!
//! 1. **Admission (router):** pop pending requests FIFO while there is
//!    batch room and a free KV slab, capped at `max_prefills_per_iter`
//!    per iteration to bound decode stalls; run their prefill and sample
//!    their first token (TTFT point).
//! 2. **Decode:** one batched decode step across all active sequences.
//! 3. **Completion:** sequences that hit `max_new` / stop token / cache
//!    capacity are finalized, their slabs returned to the pool.
//!
//! **Threading model:** the scheduling loop itself is synchronous — one
//! iteration at a time, driven by [`super::server::Server`]'s worker
//! thread — but the engine underneath executes every forward call on its
//! intra-op worker pool ([`crate::quant::parallel`]): tiled multi-threaded
//! GEMM, prefill attention over query-row blocks, decode attention across
//! batch lanes. [`SchedulerConfig::threads`] sizes that pool (plumbed from
//! the JSON config / `--threads`; DESIGN.md §7). Token streams are bitwise
//! identical for every thread count, so scheduling invariants and goldens
//! are unaffected by the parallelism.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::{model::argmax, Engine, EngineError, KvDtype, Workspace};

use super::kv_pool::KvPool;
use super::metrics::Metrics;
use super::request::{Request, Response};

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently active sequences (decode batch cap).
    pub max_batch: usize,
    /// KV slabs (≥ max_batch; extra slabs buffer admissions).
    pub kv_slabs: usize,
    /// Per-sequence KV capacity.
    pub max_seq: usize,
    /// New prefills admitted per iteration.
    pub max_prefills_per_iter: usize,
    /// Pending-queue bound (backpressure: submit fails beyond it).
    pub queue_cap: usize,
    /// Chunked prefill: prompts longer than this are prefilled
    /// `prefill_chunk` tokens per iteration so long prompts cannot stall
    /// the decode batch (0 ⇒ disabled, whole prompt in one call).
    pub prefill_chunk: usize,
    /// Engine intra-op compute threads (`quant::parallel` pool): 1 ⇒
    /// serial kernels (the deterministic baseline — though every count
    /// is bitwise identical), 0 ⇒ all available cores.
    pub threads: usize,
    /// KV-slab storage dtype: `F32` (paper-parity default) or `Int8`
    /// (statically-quantized cache, 4× more servable KV per box;
    /// DESIGN.md §10). Plumbed from JSON `scheduler.kv_cache` /
    /// `--kv-cache`.
    pub kv_dtype: KvDtype,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            max_seq: 512,
            max_prefills_per_iter: 2,
            queue_cap: 1024,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
        }
    }
}

struct Active {
    req: Request,
    slab: usize,
    tokens: Vec<u32>,
    next: u32,
    ttft: Duration,
    done: bool,
    /// Set when a typed engine error terminated this sequence; carried
    /// into the Response so the failure is per-request, not fatal.
    error: Option<String>,
}

/// One request mid-way through a chunked prefill (at most one in flight;
/// that alone bounds per-iteration prefill work by `prefill_chunk`).
struct Prefilling {
    req: Request,
    slab: usize,
    consumed: usize,
}

pub struct Scheduler {
    engine: Engine,
    cfg: SchedulerConfig,
    pool: KvPool,
    pending: VecDeque<Request>,
    prefilling: Option<Prefilling>,
    active: Vec<Active>,
    ws: Workspace,
    pub metrics: Metrics,
    completed: Vec<Response>,
}

impl Scheduler {
    pub fn new(mut engine: Engine, cfg: SchedulerConfig) -> Self {
        // The scheduler owns engine threading: config is the single
        // source of truth for the deployment (DESIGN.md §7).
        engine.set_threads(cfg.threads);
        // Int8 slabs need per-layer KV scales; bundles predating the
        // format-2 schema (and fp16 baselines) get probe-calibrated
        // fallback scales so `kv_cache=int8` serves everywhere.
        if cfg.kv_dtype == KvDtype::Int8 {
            engine.ensure_kv_scales().expect("probe KV calibration");
        }
        let mc = engine.config();
        let pool = KvPool::with_dtype(cfg.kv_dtype, cfg.kv_slabs,
                                      mc.n_layers, cfg.max_seq, mc.d_model);
        Scheduler {
            engine,
            cfg,
            pool,
            pending: VecDeque::new(),
            prefilling: None,
            active: Vec::new(),
            ws: Workspace::new(),
            metrics: Metrics::default(),
            completed: Vec::new(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request; `Err` when the queue is full (backpressure).
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        if self.pending.len() >= self.cfg.queue_cap {
            self.metrics.rejected += 1;
            return Err(req);
        }
        self.pending.push_back(req);
        Ok(())
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
            || self.prefilling.is_some()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Drain finished responses accumulated since the last call.
    pub fn take_completed(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.completed)
    }

    /// One scheduling iteration. Returns number of sequences advanced.
    pub fn step(&mut self) -> usize {
        self.admit();
        self.decode();
        self.finalize();
        self.active.len()
    }

    /// Fail a not-yet-active request with a typed engine error: free its
    /// slab, answer it (empty tokens + error), keep the worker alive.
    fn fail_request(&mut self, req: Request, slab: usize, err: &EngineError) {
        self.pool.dealloc(slab);
        self.metrics.failed += 1;
        self.completed.push(Response {
            id: req.id,
            tokens: Vec::new(),
            ttft: Duration::ZERO,
            latency: req.submitted.elapsed(),
            prompt_len: req.prompt.len(),
            error: Some(err.to_string()),
        });
    }

    /// Advance the in-flight chunked prefill by one chunk; returns true
    /// if it consumed this iteration's prefill budget.
    fn advance_chunked(&mut self) -> bool {
        let Some(mut pf) = self.prefilling.take() else { return false };
        let chunk = self.cfg.prefill_chunk.max(1);
        let end = (pf.consumed + chunk).min(pf.req.prompt.len());
        let toks: Vec<u32> = pf.req.prompt[pf.consumed..end].to_vec();
        let cache = self.pool.get_mut(pf.slab);
        if let Err(e) = self.engine.prefill(&toks, cache, &mut self.ws) {
            self.fail_request(pf.req, pf.slab, &e);
            return true;
        }
        self.metrics.prefill_calls += 1;
        pf.consumed = end;
        if pf.consumed == pf.req.prompt.len() {
            let vocab = self.engine.config().vocab;
            let first = argmax(
                &self.ws.logits[(toks.len() - 1) * vocab..toks.len() * vocab],
            ) as u32;
            let ttft = pf.req.submitted.elapsed();
            self.active.push(Active {
                req: pf.req,
                slab: pf.slab,
                tokens: vec![first],
                next: first,
                ttft,
                done: false,
                error: None,
            });
        } else {
            self.prefilling = Some(pf);
        }
        true
    }

    fn admit(&mut self) {
        let mut admitted = usize::from(self.advance_chunked());
        while admitted < self.cfg.max_prefills_per_iter
            && self.prefilling.is_none()
            && self.active.len() < self.cfg.max_batch
            && !self.pending.is_empty()
        {
            let Some(slab) = self.pool.alloc() else { break };
            let req = self.pending.pop_front().unwrap();
            // Long prompts go through the chunked path so one admission
            // cannot stall the whole decode batch.
            if self.cfg.prefill_chunk > 0
                && req.prompt.len() > self.cfg.prefill_chunk
            {
                self.prefilling = Some(Prefilling { req, slab, consumed: 0 });
                admitted += usize::from(self.advance_chunked());
                continue;
            }
            let vocab = self.engine.config().vocab;
            let cache = self.pool.get_mut(slab);
            // Oversized prompts (and any other engine-side failure)
            // surface as the typed error → per-request failure; the
            // worker thread never dies on them.
            if let Err(e) = self.engine.prefill(&req.prompt, cache,
                                                &mut self.ws) {
                self.fail_request(req, slab, &e);
                admitted += 1;
                continue;
            }
            self.metrics.prefill_calls += 1;
            let last = &self.ws.logits
                [(req.prompt.len() - 1) * vocab..req.prompt.len() * vocab];
            let first = argmax(last) as u32;
            let ttft = req.submitted.elapsed();
            self.active.push(Active {
                req,
                slab,
                tokens: vec![first],
                next: first,
                ttft,
                done: false,
                error: None,
            });
            admitted += 1;
        }
    }

    fn decode(&mut self) {
        if self.active.is_empty() {
            return;
        }
        // Sequences that already reached their budget skip the step.
        let run_idx: Vec<usize> = (0..self.active.len())
            .filter(|&i| !self.active[i].done
                && self.active[i].tokens.len() < self.active[i].req.max_new)
            .collect();
        if run_idx.is_empty() {
            for a in &mut self.active {
                a.done = true;
            }
            return;
        }
        let tokens: Vec<u32> =
            run_idx.iter().map(|&i| self.active[i].next).collect();
        let slabs: Vec<usize> =
            run_idx.iter().map(|&i| self.active[i].slab).collect();
        let mut caches = self.pool.get_many_mut(&slabs);
        if let Err(e) = self.engine.decode_batch(&tokens, &mut caches,
                                                 &mut self.ws) {
            // The engine validates before computing, so nothing advanced:
            // terminate only the offending lane (its partial tokens ship
            // with the error) and let the rest retry next iteration.
            match e {
                EngineError::KvOverflow { lane, .. } => {
                    let idx = run_idx[lane];
                    self.active[idx].error = Some(e.to_string());
                    self.active[idx].done = true;
                    self.metrics.failed += 1;
                }
                _ => {
                    // No lane attribution — fail the whole run set rather
                    // than livelock on a persistent error.
                    for &idx in &run_idx {
                        self.active[idx].error = Some(e.to_string());
                        self.active[idx].done = true;
                        self.metrics.failed += 1;
                    }
                }
            }
            return;
        }
        self.metrics.record_decode_iter(run_idx.len());
        let vocab = self.engine.config().vocab;
        for (bi, &i) in run_idx.iter().enumerate() {
            let row = &self.ws.logits[bi * vocab..(bi + 1) * vocab];
            let tok = argmax(row) as u32;
            let a = &mut self.active[i];
            a.tokens.push(tok);
            a.next = tok;
            let cache_full = {
                let c = self.pool.get_mut(a.slab);
                c.len + 1 >= c.cap
            };
            if a.tokens.len() >= a.req.max_new
                || Some(tok) == a.req.stop_token
                || cache_full
            {
                a.done = true;
            }
        }
    }

    fn finalize(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done {
                let a = self.active.swap_remove(i);
                self.pool.dealloc(a.slab);
                let latency = a.req.submitted.elapsed();
                // Failed sequences count only in `failed` (set at the
                // failure site) — mirroring fail_request(), so completion
                // counts and latency percentiles describe successes only.
                if a.error.is_none() {
                    self.metrics.record_completion(latency, a.ttft,
                                                   a.req.prompt.len(),
                                                   a.tokens.len());
                }
                self.completed.push(Response {
                    id: a.req.id,
                    tokens: a.tokens,
                    ttft: a.ttft,
                    latency,
                    prompt_len: a.req.prompt.len(),
                    error: a.error,
                });
            } else {
                i += 1;
            }
        }
    }

    /// Run until all submitted work completes; returns all responses.
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let start = Instant::now();
        while self.has_work() {
            self.step();
            out.extend(self.take_completed());
            assert!(start.elapsed() < Duration::from_secs(600),
                    "scheduler livelock");
        }
        out
    }
}
