//! Iteration-level (continuous-batching) scheduler — **one engine call
//! per iteration** (DESIGN.md §12) over **block-granular KV**
//! (DESIGN.md §13).
//!
//! Owns the engine, the shared KV [`BlockPool`] and the pending queue.
//! Each call to [`Scheduler::step`] performs one scheduling iteration:
//!
//! 1. **Cancellation:** tear cancelled sequences out of the batch —
//!    pending requests are answered immediately, active/prefilling ones
//!    are finalized this iteration and their KV blocks returned.
//! 2. **Admission (router):** pop pending requests FIFO into the
//!    prefilling set while there is batch room and the pool has **enough
//!    blocks for the first prefill chunk** — not a whole `max_seq` slab,
//!    so admission capacity tracks the tokens actually in flight. The
//!    blocks committed work needs this iteration (decode lanes crossing
//!    a block boundary, in-flight prefills' next chunks) are held back
//!    from admissions. (Oversized prompts are answered with the typed
//!    overflow error up front, before holding any block.)
//! 3. **Block reservation:** committed decode lanes reserve their next
//!    block first (FIFO by lane index, which finalize keeps equal to
//!    arrival order — a lane that cannot get one finishes `CacheFull`
//!    deterministically, oldest lanes last, instead of failing the
//!    batch); then the oldest `max_prefills_per_iter` prefills reserve
//!    their next chunk, FIFO-strict (when one stalls, younger prefills
//!    wait too, so pressure cannot invert first-token order).
//! 4. **One ragged batch:** build a single [`BatchPlan`] — the reserved
//!    prefill spans plus one decode span per reserved lane — and run
//!    **one** [`Engine::forward_batch`] call over the stacked rows.
//! 5. **Sampling:** completed prefills are promoted to the active set
//!    (first token — the TTFT point, in FIFO order); every decode lane
//!    samples its next token from its span's logits row.
//! 6. **Completion:** sequences that hit `max_new` / a stop token /
//!    cache capacity are finalized, their blocks returned to the pool.
//!    If every live sequence is a prefill that cannot reserve and
//!    nothing freed a block this iteration, the **newest** prefilling
//!    sequence is requeued to the head of the pending queue
//!    (deterministic: LIFO victim, blocks released, `kv_requeues`
//!    metric) so the oldest can always finish — the arena is asserted
//!    to cover at least one `max_seq` sequence.
//!
//! Progress is reported as an **event stream** ([`Event`], drained via
//! [`Scheduler::take_events`]): one `Token` frame per sampled token and
//! exactly one terminal `Done`/`Error` frame per request — the per-token
//! cadence the serving layer streams to clients (DESIGN.md §11).
//!
//! **Priorities, preemption, and the SLO gate (DESIGN.md §15):**
//! admission is weighted-fair across priority classes
//! ([`super::pending::PendingQueues`] — stride scheduling, higher class
//! ⇒ more admissions, no starvation), and block pressure is resolved by
//! **transparent preemption** before anyone is cut `CacheFull`: when a
//! demander (an admission, a prefill chunk, or a decode lane needing its
//! next block) cannot be covered, active lanes of a *strictly lower*
//! class are preempted — lowest class first, youngest (highest lane
//! index — finalize keeps lane index equal to arrival order) within a
//! class — their blocks released and their generation state requeued to
//! the front of their class queue. A preempted stream emits **no**
//! frame: on re-admission its KV is recomputed (its own prompt is a warm
//! prefix-cache hit) with a logits-free final span, and the pure
//! `(seed, step)` sampler continues at step `tokens.len()`, so the
//! resumed stream is bitwise the uninterrupted one
//! (`tests/preemption.rs`). Same-class pressure keeps the pre-§15
//! deterministic CacheFull cut (youngest first), so uniform-priority
//! traffic is bitwise unchanged. `max_decode_latency` (ms, 0 = off)
//! defers admissions for a tick whenever the last decode-bearing engine
//! call ran over the target — wall-clock gates only *when* work is
//! admitted, never what any stream contains.
//!
//! Token selection goes through each request's seeded
//! [`Sampler`](crate::engine::Sampler) (`GenerationParams::sampler`):
//! greedy requests run the seed argmax path bitwise unchanged, sampled
//! requests draw from a counter-based per-request RNG. The unified pass
//! is bitwise identical to the sequential seed paths for every batch
//! composition and block size (`tests/ragged_batch.rs`), so token
//! streams are deterministic for every thread count, chunking choice,
//! block size, and batch composition.
//!
//! **Threading model:** the scheduling loop itself is synchronous — one
//! iteration at a time, driven by [`super::server::Server`]'s worker
//! thread — but the engine underneath executes every forward call on its
//! intra-op worker pool ([`crate::quant::parallel`]): tiled multi-threaded
//! GEMM and ragged attention over row blocks. [`SchedulerConfig::threads`]
//! sizes that pool (plumbed from the JSON config / `--threads`;
//! DESIGN.md §7). Token streams are bitwise identical for every thread
//! count, so scheduling invariants and goldens are unaffected by the
//! parallelism.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{
    BatchPlan, Engine, EngineError, KvBlock, KvCache, KvDtype, Sampler,
    SpanLogits, Workspace,
};

use super::kv_pool::BlockPool;
use super::metrics::{Metrics, ReplicaStats};
use super::pending::{PendingEntry, PendingQueues, ResumeState};
use super::prefix_cache::PrefixCache;
use super::request::{Event, FinishReason, Request, Response};

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently live sequences (active decode lanes plus
    /// in-flight prefills — every lane of the per-iteration ragged
    /// batch).
    pub max_batch: usize,
    /// Back-compat arena sizing (pre-paging `kv_slabs`): when
    /// `kv_blocks == 0` the arena holds `kv_slabs × ⌈max_seq/kv_block⌉`
    /// blocks — the same KV bytes the old slab pool pre-allocated.
    pub kv_slabs: usize,
    /// Tokens per KV block (the paging granularity). `0` ⇒ `max_seq`
    /// (one block per sequence — exactly the old slab behaviour).
    pub kv_block: usize,
    /// Total blocks in the shared arena. `0` ⇒ derive from `kv_slabs`
    /// (back-compat: equal arena bytes to the old slab pool).
    pub kv_blocks: usize,
    /// Per-sequence logical KV capacity (tokens).
    pub max_seq: usize,
    /// Prefill spans per ragged batch: bounds per-iteration prefill work
    /// (and therefore decode stalls). Several chunked prefills may be in
    /// flight; each iteration advances the oldest `max_prefills_per_iter`
    /// of them by one span.
    pub max_prefills_per_iter: usize,
    /// Pending-queue bound (backpressure: submit fails beyond it).
    pub queue_cap: usize,
    /// Chunked prefill: prompts are prefilled at most `prefill_chunk`
    /// tokens per iteration so long prompts cannot stall the decode
    /// batch (0 ⇒ disabled, whole prompt in one span).
    pub prefill_chunk: usize,
    /// Engine intra-op compute threads (`quant::parallel` pool): 1 ⇒
    /// serial kernels (the deterministic baseline — though every count
    /// is bitwise identical), 0 ⇒ all available cores.
    pub threads: usize,
    /// KV-block storage dtype: `F32` (paper-parity default) or `Int8`
    /// (statically-quantized cache, 4× more servable KV per box;
    /// DESIGN.md §10). Plumbed from JSON `scheduler.kv_cache` /
    /// `--kv-cache`.
    pub kv_dtype: KvDtype,
    /// Prefix sharing (DESIGN.md §14): keep finished sequences' frozen
    /// KV blocks in a radix index and map admissions with a matching
    /// prompt prefix onto them — prefill is skipped for the matched
    /// region and admission is charged only the unshared blocks. Off by
    /// default: the index deliberately retains blocks past request
    /// completion, so `kv_available == kv_capacity` no longer holds at
    /// drain. Token streams are bitwise identical either way.
    pub prefix_cache: bool,
    /// Prefix-index capacity in blocks (LRU-evicted beyond it); 0 ⇒
    /// unbounded — blocks are then reclaimed only under pool pressure.
    pub prefix_cache_blocks: usize,
    /// Decode-latency SLO in milliseconds (DESIGN.md §15): when the
    /// last decode-bearing engine call exceeded this, admission is
    /// deferred for the iteration (`slo_deferrals` metric) so live
    /// decode lanes get the next call without new prefill rows stacked
    /// under them. `0` ⇒ off (the default — and what every determinism
    /// suite uses, keeping scheduling wall-clock independent; token
    /// streams are bitwise identical either way).
    pub max_decode_latency: u64,
    /// Self-speculative decoding (DESIGN.md §18): a draft engine —
    /// the same bundle, optionally layer-truncated — proposes
    /// `draft_k` tokens per decode lane per iteration and the target
    /// verifies them all in one ragged span, emitting up to
    /// `draft_k + 1` tokens per target forward. Token streams are
    /// bitwise identical either way (the emitted stream *is* the
    /// target sampler stream); the knob only changes how many target
    /// forwards they cost. Off by default.
    pub speculative: bool,
    /// Tokens the draft lane proposes per iteration (≥ 1 when
    /// `speculative`; 0 falls back to 1). Plumbed from JSON
    /// `scheduler.draft_k` / `--draft-k`.
    pub draft_k: usize,
    /// Draft-model depth in layers: the draft engine runs only the
    /// first `draft_layers` transformer layers of the bundle. `0` ⇒
    /// full depth (a pure self-draft — greedy proposals always
    /// verify, useful for measuring the span mechanics). Plumbed from
    /// JSON `scheduler.draft_layers` / `--draft-layers`.
    pub draft_layers: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            kv_block: 32,
            kv_blocks: 0,
            max_seq: 512,
            max_prefills_per_iter: 2,
            queue_cap: 1024,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        }
    }
}

impl SchedulerConfig {
    /// Resolved paging granularity (tokens per block).
    pub fn block_tokens(&self) -> usize {
        if self.kv_block == 0 {
            self.max_seq.max(1)
        } else {
            self.kv_block.min(self.max_seq.max(1))
        }
    }

    /// Resolved arena size in blocks (`kv_blocks`, or the `kv_slabs`
    /// byte-equivalent when unset).
    pub fn total_blocks(&self) -> usize {
        if self.kv_blocks > 0 {
            self.kv_blocks
        } else {
            self.kv_slabs * self.max_seq.max(1).div_ceil(self.block_tokens())
        }
    }
}

struct Active {
    req: Request,
    /// This sequence's KV block table — owned here, blocks borrowed from
    /// the shared [`BlockPool`] until finalize/cancel returns them.
    cache: KvCache,
    tokens: Vec<u32>,
    next: u32,
    ttft: Duration,
    /// Per-request seeded sampler (greedy for `temperature == 0`).
    sampler: Sampler,
    done: bool,
    finish: FinishReason,
    /// Set when a typed engine error terminated this sequence; carried
    /// into the terminal event so the failure is per-request, not fatal.
    error: Option<String>,
    /// Preempted this iteration by a strictly-higher-class demander
    /// (DESIGN.md §15): blocks already released, lane skipped for the
    /// rest of the iteration, swept into the pending queue (with its
    /// generation state, no event) by `collect_preempted`.
    preempted: bool,
    /// Per-lane draft KV cache for speculative decoding (DESIGN.md
    /// §18): auto-grow paged with the *draft* engine's layer count,
    /// never pool-backed — draft KV is private working memory, not
    /// arena-accounted serving state. Lazily built (and rebuilt after
    /// preemption) by a catch-up span on the draft engine; `None`
    /// until the lane first speculates.
    draft_cache: Option<KvCache>,
}

/// A request whose prompt is not yet fully in its KV cache. Any number
/// may be in flight concurrently; each iteration the oldest
/// `max_prefills_per_iter` of them contribute one span to the ragged
/// batch (whole remaining prompt when chunking is off).
struct Prefilling {
    req: Request,
    cache: KvCache,
    consumed: usize,
    /// Present when this is a preempted lane recomputing its KV: the
    /// prefill runs over `resume.work` (prompt plus already-streamed
    /// tokens) instead of the prompt, its final span requests **no**
    /// logits, and completion resumes decoding instead of activating.
    resume: Option<ResumeState>,
}

impl Prefilling {
    /// The token sequence this prefill is writing into KV.
    fn work(&self) -> &[u32] {
        match &self.resume {
            Some(rs) => &rs.work,
            None => &self.req.prompt,
        }
    }
}

/// What a span of the per-iteration [`BatchPlan`] stands for — used to
/// route logits rows and to attribute typed engine errors back to the
/// owning request.
enum SpanRole {
    /// Span advances `prefilling[pf]` to `consumed == end`.
    Prefill { pf: usize, end: usize },
    /// Span decodes for `active[idx]`: one committed token plus the
    /// speculatively drafted continuation (empty ⇒ a plain one-token
    /// decode — the pre-§18 behaviour, bit for bit).
    Decode { idx: usize, draft: Vec<u32> },
}

pub struct Scheduler {
    engine: Engine,
    /// Draft engine for self-speculative decoding
    /// (`SchedulerConfig::speculative`; DESIGN.md §18): the same
    /// bundle, layer-truncated to `draft_layers`. `None` when
    /// speculation is off — or permanently dropped after a draft-lane
    /// engine error (the scheduler then serves non-speculatively;
    /// token streams are identical either way).
    draft: Option<Engine>,
    /// Scratch for draft-lane forwards — the target `ws` holds the
    /// verify logits between plan build and consumption, so the draft
    /// lane needs its own.
    draft_ws: Workspace,
    cfg: SchedulerConfig,
    pool: BlockPool,
    /// Radix prefix index over frozen KV blocks
    /// (`SchedulerConfig::prefix_cache`; DESIGN.md §14).
    prefix: Option<PrefixCache>,
    /// Per-class weighted-fair pending queues (DESIGN.md §15).
    pending: PendingQueues,
    prefilling: Vec<Prefilling>,
    active: Vec<Active>,
    ws: Workspace,
    pub metrics: Metrics,
    /// Ids whose cancellation was requested but not yet applied; drained
    /// at the start of every iteration (unknown ids are dropped — the
    /// request already finished).
    cancel_requests: Vec<u64>,
    events: Vec<Event>,
    /// Wall time of the last decode-bearing engine call (ms) — the
    /// signal `max_decode_latency` gates admission on.
    last_decode_ms: f64,
    /// Request ids preempted, in preemption order — observability for
    /// the victim-selection determinism tests and diagnostics.
    preempt_log: Vec<u64>,
}

impl Scheduler {
    pub fn new(mut engine: Engine, cfg: SchedulerConfig) -> Self {
        // The scheduler owns engine threading: config is the single
        // source of truth for the deployment (DESIGN.md §7).
        engine.set_threads(cfg.threads);
        // Int8 blocks need per-layer KV scales; bundles predating the
        // format-2 schema (and fp16 baselines) get probe-calibrated
        // fallback scales so `kv_cache=int8` serves everywhere.
        if cfg.kv_dtype == KvDtype::Int8 {
            engine.ensure_kv_scales().expect("probe KV calibration");
        }
        let mc = engine.config();
        let pool = BlockPool::with_dtype(cfg.kv_dtype, cfg.total_blocks(),
                                         cfg.block_tokens(), mc.n_layers,
                                         cfg.max_seq, mc.d_model);
        let prefix = cfg.prefix_cache.then(|| {
            PrefixCache::new(cfg.block_tokens(), cfg.prefix_cache_blocks)
        });
        // Built after ensure_kv_scales so an int8 deployment's draft
        // clone carries the same calibrated (or probe-fallback) scales
        // as the target.
        let draft = cfg
            .speculative
            .then(|| engine.draft(cfg.draft_layers, cfg.threads));
        Scheduler {
            engine,
            draft,
            draft_ws: Workspace::new(),
            cfg,
            pool,
            prefix,
            pending: PendingQueues::default(),
            prefilling: Vec::new(),
            active: Vec::new(),
            ws: Workspace::new(),
            metrics: Metrics::default(),
            cancel_requests: Vec::new(),
            events: Vec::new(),
            last_decode_ms: 0.0,
            preempt_log: Vec::new(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request; `Err` when the queue is full (backpressure).
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        if self.pending.len() >= self.cfg.queue_cap {
            self.metrics.rejected += 1;
            return Err(req);
        }
        self.pending.push_back(PendingEntry::fresh(req));
        Ok(())
    }

    /// Request cancellation of `id`. Applied at the start of the next
    /// iteration: a pending request is answered immediately (`Done`,
    /// finish `Cancelled`), an active or prefilling one is torn out of
    /// the continuous batch and its KV blocks returned to the pool. Ids
    /// that match nothing (already finished, never existed) are ignored.
    pub fn cancel(&mut self, id: u64) {
        self.cancel_requests.push(id);
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
            || !self.prefilling.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Requests currently mid-prefill (concurrent chunked prefills are
    /// allowed; observability for tests and diagnostics).
    pub fn prefilling_len(&self) -> usize {
        self.prefilling.len()
    }

    /// Free KV blocks (arena capacity minus blocks held by live
    /// sequences) — observability for tests and admission diagnostics.
    pub fn kv_available(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Total KV blocks in the arena.
    pub fn kv_capacity(&self) -> usize {
        self.pool.total_blocks()
    }

    /// Paging granularity (tokens per block).
    pub fn kv_block_tokens(&self) -> usize {
        self.pool.block_tokens()
    }

    /// Blocks currently pinned by the radix prefix index (0 when
    /// `prefix_cache` is off). At drain,
    /// `kv_available + prefix_cached_blocks == kv_capacity`.
    pub fn prefix_cached_blocks(&self) -> usize {
        self.prefix.as_ref().map_or(0, PrefixCache::cached_blocks)
    }

    /// Request ids preempted so far, in preemption order — the victim
    /// sequence is part of the deterministic scheduling contract
    /// (DESIGN.md §15) and is pinned by `tests/preemption.rs`.
    pub fn preemption_log(&self) -> &[u64] {
        &self.preempt_log
    }

    /// Machine-readable load snapshot (DESIGN.md §16): queue depths,
    /// arena occupancy, and the cumulative counters the router tier
    /// dispatches on — plus the replica's active SIMD microkernel and
    /// the bundle's quant mode, so a mixed fleet is debuggable from
    /// the gateway's `{"cmd":"stats"}` frame alone. `replica`/
    /// `draining` are left at their defaults — fleet position is the
    /// router's to fill in.
    pub fn stats(&self) -> ReplicaStats {
        ReplicaStats {
            replica: 0,
            draining: false,
            pending: self.pending.len(),
            prefilling: self.prefilling.len(),
            active: self.active.len(),
            kv_available: self.pool.free_blocks(),
            kv_capacity: self.pool.total_blocks(),
            prefix_cached_blocks: self.prefix_cached_blocks(),
            requests_completed: self.metrics.requests_completed,
            generated_tokens: self.metrics.generated_tokens,
            prefix_lookups: self.metrics.prefix_lookups,
            prefix_hits: self.metrics.prefix_hits,
            kernel: crate::quant::simd::active().kind().name().into(),
            quant_mode: self.engine.model.quant_mode_name().into(),
        }
    }

    /// Distinct physical KV blocks referenced by live lanes (prefilling
    /// and active block tables; a CoW-shared block counts once).
    /// Observability for the §15 accounting invariant: with the prefix
    /// cache off, `kv_available + kv_live_blocks == kv_capacity` holds
    /// after every iteration, preemption churn included.
    pub fn kv_live_blocks(&self) -> usize {
        let mut seen: Vec<*const KvBlock> = Vec::new();
        let tables = self
            .prefilling
            .iter()
            .map(|p| &p.cache)
            .chain(self.active.iter().map(|a| &a.cache));
        for cache in tables {
            for b in 0..cache.n_blocks() {
                let p = cache.block_ptr(b);
                if !seen.contains(&p) {
                    seen.push(p);
                }
            }
        }
        seen.len()
    }

    /// Drain the event stream accumulated since the last call: `Token`
    /// frames in generation order, one terminal `Done`/`Error` frame per
    /// finished request.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// One scheduling iteration: cancellations, admissions, block
    /// reservations, then **one** `forward_batch` ragged engine call
    /// carrying every prefill span and decode lane, then sampling and
    /// completion. Returns the number of active sequences.
    pub fn step(&mut self) -> usize {
        let freed_before = self.pool.blocks_freed();
        self.apply_cancellations();
        self.admit();
        let ran = self.run_batch();
        // Sweep lanes preempted this iteration (by admission, a prefill
        // chunk, or a decode lane of a higher class) back into their
        // class queues — blocks already released, no event emitted.
        self.collect_preempted();
        // KV utilization snapshot while sequences hold their blocks:
        // used tokens over allocated block tokens (the packing win paged
        // allocation exists to maximize — DESIGN.md §13).
        let used: usize =
            self.prefilling.iter().map(|p| p.cache.len).sum::<usize>()
                + self.active.iter().map(|a| a.cache.len).sum::<usize>();
        self.metrics.record_kv(used, self.pool.allocated_tokens());
        // Publish frozen full blocks into the radix index *before*
        // finalize, so finished sequences' prefixes stay cached and
        // staggered admissions can share in-flight prefixes.
        self.update_prefix_index();
        self.finalize();
        // Stall resolution: every live sequence is a prefill that could
        // not reserve its next chunk and nothing freed a block this
        // iteration — no future iteration can differ, so the newest
        // prefilling sequence (deterministic LIFO victim) releases its
        // blocks and returns to the head of the pending queue. The
        // arena covers ≥ one max_seq sequence, so the oldest always
        // completes eventually.
        if !ran && self.active.is_empty() && !self.prefilling.is_empty()
            && self.pool.blocks_freed() == freed_before
        {
            self.requeue_stalled_prefill();
        }
        self.metrics.blocks_alloc = self.pool.blocks_alloc();
        self.metrics.blocks_freed = self.pool.blocks_freed();
        if self.prefix.is_some() {
            self.record_sharing_snapshot();
        }
        self.active.len()
    }

    /// Sharing snapshot for metrics: count the live lanes' block-table
    /// entries against the distinct physical blocks behind them — the
    /// difference, in bytes, is the KV capacity prefix sharing is
    /// currently saving.
    fn record_sharing_snapshot(&mut self) {
        let mut refs: HashMap<*const KvBlock, usize> = HashMap::new();
        let tables = self
            .prefilling
            .iter()
            .map(|p| &p.cache)
            .chain(self.active.iter().map(|a| &a.cache));
        for cache in tables {
            for b in 0..cache.n_blocks() {
                *refs.entry(cache.block_ptr(b)).or_insert(0) += 1;
            }
        }
        let entries: usize = refs.values().sum();
        let shared = refs.values().filter(|&&n| n > 1).count();
        let saved = (entries - refs.len()) * self.pool.block_bytes();
        self.metrics.record_prefix_sharing(shared as u64,
                                           (refs.len() - shared) as u64,
                                           saved as u64);
        if let Some(pc) = &self.prefix {
            self.metrics.prefix_cached_blocks = pc.cached_blocks() as u64;
        }
    }

    /// Apply queued `cancel()` calls: answer pending requests outright,
    /// mark active/prefilling sequences done with finish `Cancelled` so
    /// this iteration's finalize returns their blocks.
    fn apply_cancellations(&mut self) {
        for id in std::mem::take(&mut self.cancel_requests) {
            if let Some(entry) = self.pending.take(id) {
                let (tokens, ttft) = match entry.resume {
                    Some(rs) => (rs.tokens, rs.ttft),
                    None => (Vec::new(), Duration::ZERO),
                };
                self.answer_cancelled(&entry.req, tokens, ttft);
                continue;
            }
            if let Some(pos) =
                self.prefilling.iter().position(|p| p.req.id == id)
            {
                let mut pf = self.prefilling.remove(pos);
                self.pool.release(&mut pf.cache);
                let (tokens, ttft) = match pf.resume {
                    Some(rs) => (rs.tokens, rs.ttft),
                    None => (Vec::new(), Duration::ZERO),
                };
                self.answer_cancelled(&pf.req, tokens, ttft);
                continue;
            }
            if let Some(a) =
                self.active.iter_mut().find(|a| a.req.id == id && !a.done)
            {
                a.done = true;
                a.finish = FinishReason::Cancelled;
                self.metrics.cancelled += 1;
            }
        }
    }

    /// Terminal event for a request cancelled outside the active set
    /// (pending / mid-prefill). A preempted-and-requeued request carries
    /// its already-streamed tokens and original TTFT into the summary;
    /// a fresh one reports none.
    fn answer_cancelled(&mut self, req: &Request, tokens: Vec<u32>,
                        ttft: Duration) {
        self.metrics.cancelled += 1;
        self.events.push(Event::Done {
            response: Response {
                id: req.id,
                tokens,
                ttft,
                latency: req.submitted.elapsed(),
                prompt_len: req.prompt.len(),
                finish: FinishReason::Cancelled,
                error: None,
            },
        });
    }

    /// Fail a not-yet-active request with a typed per-request error
    /// (blocks already returned by the caller), keeping the worker
    /// alive.
    fn fail_request(&mut self, req: Request, error: String) {
        self.metrics.failed += 1;
        self.events.push(Event::Error {
            response: Response::failed(req.id, req.prompt.len(),
                                       req.submitted.elapsed(), error),
        });
    }

    /// Draft tokens this lane would speculate next decode: `draft_k`
    /// when the scheduler holds a draft engine and the request didn't
    /// opt out (`params.speculative == Some(false)`), else 0. A pure
    /// admission/reservation hint — the actual proposal re-clamps to
    /// the lane's remaining budget and logical KV room.
    fn lane_draft_k(&self, a: &Active) -> usize {
        if self.draft.is_some() && a.req.params.speculative != Some(false)
        {
            self.cfg.draft_k.max(1)
        } else {
            0
        }
    }

    /// Blocks the oldest `budget` in-flight prefills need for their
    /// next chunk — the prefill share of admission headroom, and part
    /// of the committed work a speculative reservation must never
    /// displace.
    fn prefill_chunk_need(&self, budget: usize) -> usize {
        self.prefilling
            .iter()
            .take(budget)
            .map(|pf| {
                let remaining = pf.work().len() - pf.consumed;
                let chunk = if self.cfg.prefill_chunk == 0 {
                    remaining
                } else {
                    self.cfg.prefill_chunk.min(remaining)
                };
                self.pool.blocks_needed(&pf.cache, pf.consumed + chunk)
            })
            .sum()
    }

    /// Admission (router): pending → prefilling, FIFO, while there is
    /// batch room (active + in-flight prefills), an unused prefill-span
    /// slot this iteration, and **enough free blocks for the first
    /// prefill chunk** — the paged admission gate (DESIGN.md §13). The
    /// blocks this iteration's committed decode lanes are about to
    /// claim are held back, so an admission can never starve a running
    /// lane into `CacheFull`. Prompts that can never run — empty (no
    /// logits row to sample a first token from), or longer than
    /// `max_seq` — are answered with a per-request failure up front: no
    /// block held, no engine call burned. (The server layer already
    /// rejects empty prompts synchronously; this guards direct
    /// `Scheduler::submit` users, where the seed panicked instead.)
    fn admit(&mut self) {
        // SLO gate (`max_decode_latency`, DESIGN.md §15): the last
        // decode-bearing engine call ran over target while decode lanes
        // are still live — defer admissions one iteration so those
        // lanes get the next call without new prefill rows stacked
        // under them. Wall clock gates only *when* work is admitted;
        // every token stream is bitwise unchanged.
        if self.cfg.max_decode_latency > 0
            && self.last_decode_ms > self.cfg.max_decode_latency as f64
            && self.active.iter().any(|a| !a.done && !a.preempted)
        {
            if !self.pending.is_empty() {
                self.metrics.slo_deferrals += 1;
            }
            return;
        }
        let budget = self.cfg.max_prefills_per_iter.max(1);
        // Headroom admissions may not take: one block per committed
        // decode lane about to cross a block boundary, plus the
        // uncovered part of each in-flight prefill's next chunk — an
        // admission must never steal the blocks already-admitted work
        // needs this iteration (else a backlog could starve an older
        // prefill through repeated admit-then-stall cycles).
        let decode_need: usize = self
            .active
            .iter()
            .filter(|a| !a.done && a.tokens.len() < a.req.params.max_new)
            .map(|a| {
                // Speculative lanes hold back room for the whole
                // verify span so admissions can't squeeze speculation
                // out of a lane that was already running it.
                self.pool.blocks_needed(
                    &a.cache, a.cache.len + 1 + self.lane_draft_k(a))
            })
            .sum();
        let headroom = decode_need + self.prefill_chunk_need(budget);
        loop {
            // Preempted lanes are dead weight awaiting the sweep, not
            // batch occupants.
            let live = self.active.iter().filter(|a| !a.preempted).count();
            if self.prefilling.len() >= budget
                || live + self.prefilling.len() >= self.cfg.max_batch
            {
                break;
            }
            // Weighted-fair selection across priority classes; `pop`
            // below returns the same entry (nothing else touches the
            // queues in between).
            let Some(entry) = self.pending.peek() else { break };
            let plen = entry.work().len();
            let class = entry.req.params.priority;
            if plen == 0 {
                let e = self.pending.pop().unwrap();
                self.fail_request(e.req, "empty prompt".into());
                continue;
            }
            if plen > self.cfg.max_seq {
                let e = self.pending.pop().unwrap();
                let err = EngineError::KvOverflow {
                    lane: 0,
                    pos: plen - 1,
                    cap: self.cfg.max_seq,
                };
                self.fail_request(e.req, err.to_string());
                continue;
            }
            // Prefix match (DESIGN.md §14): attach the cached frozen
            // blocks covering the matched tokens and start the prefill
            // *after* them — the matched region is never recomputed,
            // and admission is charged only the unshared blocks the
            // request actually needs (a CoW boundary block plus table
            // growth). On a full hit the remaining prefill is the final
            // prompt token, so TTFT ≈ one decode step. A preempted
            // lane's recompute work starts with its own prompt, whose
            // frozen blocks usually still sit in the index — resume
            // compounds with sharing into a warm hit.
            let (matched, shared) = match self.prefix.as_mut() {
                Some(pc) => pc.lookup(self.pending.peek().unwrap().work()),
                None => (0, Vec::new()),
            };
            let first = if self.cfg.prefill_chunk == 0 {
                plen
            } else {
                (matched + self.cfg.prefill_chunk).min(plen)
            };
            let mut cache = self.pool.new_sequence();
            for block in shared {
                cache.push_block(block);
            }
            cache.len = matched;
            let need = self.pool.blocks_needed(&cache, first);
            if need > self.pool.free_blocks().saturating_sub(headroom) {
                // Prefix eviction first (reclaims idle blocks), then
                // preemption of strictly-lower-class active lanes —
                // same-class pressure stays plain backpressure, so
                // uniform-priority traffic admits exactly as before.
                let covered = Self::evict_until(&mut self.prefix,
                                                &mut self.pool,
                                                &mut self.metrics,
                                                need + headroom)
                    || self.preempt_for(class, need + headroom);
                if !covered {
                    break; // backpressure: not enough blocks to start
                }
            }
            self.pool
                .reserve_writable(&mut cache, first)
                .expect("free blocks checked above");
            let entry = self.pending.pop().unwrap();
            if self.prefix.is_some() {
                self.metrics.prefix_lookups += 1;
                if matched > 0 {
                    self.metrics.prefix_hits += 1;
                    self.metrics.prefix_matched_tokens += matched as u64;
                }
            }
            self.prefilling.push(Prefilling {
                req: entry.req,
                cache,
                consumed: matched,
                resume: entry.resume,
            });
        }
    }

    /// Preempt active lanes of a class **strictly below** `class` —
    /// lowest class first, youngest (highest lane index = latest
    /// arrival) within a class — releasing each victim's blocks, until
    /// the pool has `want` free blocks; returns whether the target was
    /// met. Victims are only marked here (`preempted`) so lane indices
    /// stay stable through the iteration; `collect_preempted` requeues
    /// them after the batch. A victim sharing blocks with the prefix
    /// index may free less than its table length, so the loop keeps
    /// going until the target is met or no eligible victim remains.
    fn preempt_for(&mut self, class: u8, want: usize) -> bool {
        while self.pool.free_blocks() < want {
            let victim = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| {
                    !a.done && !a.preempted && a.req.params.priority < class
                })
                .min_by_key(|&(i, a)| {
                    (a.req.params.priority, std::cmp::Reverse(i))
                })
                .map(|(i, _)| i);
            let Some(v) = victim else { return false };
            let a = &mut self.active[v];
            self.pool.release(&mut a.cache);
            a.preempted = true;
            self.metrics.preemptions += 1;
            self.preempt_log.push(a.req.id);
        }
        true
    }

    /// Move lanes preempted this iteration out of the active set and
    /// back into their class queues, carrying their generation state
    /// ([`ResumeState`]) so re-admission recomputes
    /// `prompt ++ tokens[..len-1]` and continues sampling at the next
    /// counter step — the resumed stream is bitwise the uninterrupted
    /// one. No event is emitted: to the client, preemption is invisible
    /// backpressure, never a `cache_full` finish. Victims are requeued
    /// in reverse arrival order so the oldest one ends up frontmost in
    /// its class queue (`push_front` also refunds the stride charge).
    fn collect_preempted(&mut self) {
        if !self.active.iter().any(|a| a.preempted) {
            return;
        }
        let mut victims: Vec<Active> = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].preempted {
                victims.push(self.active.remove(i));
            } else {
                i += 1;
            }
        }
        for a in victims.into_iter().rev() {
            let k = a.tokens.len();
            debug_assert!(k > 0, "active lanes always hold >= 1 token");
            let mut work =
                Vec::with_capacity(a.req.prompt.len() + k - 1);
            work.extend_from_slice(&a.req.prompt);
            work.extend_from_slice(&a.tokens[..k - 1]);
            self.pending.push_front(PendingEntry {
                req: a.req,
                resume: Some(ResumeState {
                    tokens: a.tokens,
                    work,
                    ttft: a.ttft,
                }),
            });
        }
    }

    /// Evict prefix-index LRU leaves until the pool has at least `want`
    /// free blocks; returns whether the target was reached. A handle
    /// still shared with a live lane reclaims nothing (the lane returns
    /// the block later), so eviction keeps draining leaves until the
    /// target is met or the index is empty. Associated fn (not a
    /// method) so callers can hold disjoint borrows of other fields.
    fn evict_until(prefix: &mut Option<PrefixCache>, pool: &mut BlockPool,
                   metrics: &mut Metrics, want: usize) -> bool {
        let Some(pc) = prefix.as_mut() else { return false };
        while pool.free_blocks() < want {
            match pc.evict_lru_leaf() {
                Some(block) => {
                    pool.reclaim(block);
                    metrics.prefix_evicted_blocks += 1;
                }
                None => return false,
            }
        }
        true
    }

    /// Publish every live lane's frozen *full* blocks (prompt plus the
    /// generated tokens whose KV is already written) into the radix
    /// index. Runs each iteration before finalize: finished sequences'
    /// prefixes stay cached after their blocks' lane handles are
    /// released, and staggered admissions share in-flight prefixes.
    /// Insertion is idempotent (edge reuse), so the steady-state cost
    /// is one trie walk per lane; capacity-evicted handles flow back
    /// through the pool.
    fn update_prefix_index(&mut self) {
        let Some(pc) = self.prefix.as_mut() else { return };
        let mut evicted: Vec<Arc<KvBlock>> = Vec::new();
        for pf in &self.prefilling {
            evicted.extend(pc.insert(&pf.work()[..pf.consumed],
                                     &pf.cache));
        }
        let mut key: Vec<u32> = Vec::new();
        for a in &self.active {
            let written = a.cache.len.saturating_sub(a.req.prompt.len());
            key.clear();
            key.extend_from_slice(&a.req.prompt);
            key.extend_from_slice(&a.tokens[..written]);
            evicted.extend(pc.insert(&key, &a.cache));
        }
        self.metrics.prefix_evicted_blocks += evicted.len() as u64;
        for block in evicted {
            self.pool.reclaim(block);
        }
    }

    /// Draft-lane proposal (DESIGN.md §18): autoregressively sample
    /// `k` tokens for lane `a` on the draft engine, keeping the lane's
    /// private auto-grow draft KV in sync with the target's committed
    /// history. The first span folds in a catch-up feed — whatever
    /// committed positions the draft cache is missing (all of them on
    /// a fresh or preempt-rebuilt cache, none in steady state) plus
    /// the lane's committed next token — then each subsequent forward
    /// feeds the previous proposal. Sampling uses the lane's own
    /// counter-based sampler at exactly the steps the target verify
    /// walk will use, so a full-depth draft (`draft_layers == 0`)
    /// reproduces the target stream bitwise and verifies at
    /// acceptance 1.0.
    ///
    /// Associated fn so the caller can hold `self.draft` and a lane
    /// borrow simultaneously.
    fn propose(draft: &Engine, ws: &mut Workspace, a: &mut Active,
               k: usize) -> Result<Vec<u32>, EngineError> {
        let base = a.cache.len;
        let (dtype, cap, bt) =
            (a.cache.dtype(), a.cache.cap, a.cache.block_tokens());
        let dcfg = draft.config();
        let (n_layers, d_model, vocab) =
            (dcfg.n_layers, dcfg.d_model, dcfg.vocab);
        let dc = a.draft_cache.get_or_insert_with(|| {
            KvCache::paged(dtype, n_layers, cap, d_model, bt)
        });
        // Drop the stale speculative suffix a previous iteration's
        // rejected proposal left behind (surplus blocks are private
        // draft memory — nothing to reclaim into the pool).
        if dc.len > base {
            let _ = dc.truncate(base);
        }
        let mut feed: Vec<u32> = (dc.len..base)
            .map(|p| {
                if p < a.req.prompt.len() {
                    a.req.prompt[p]
                } else {
                    a.tokens[p - a.req.prompt.len()]
                }
            })
            .collect();
        feed.push(a.next);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let mut plan = BatchPlan::new();
            plan.push_span(0, &feed, SpanLogits::Last);
            {
                let mut caches = [&mut *dc];
                draft.forward_batch(&plan, &mut caches, ws)?;
            }
            let tok = a.sampler.sample(&ws.logits[..vocab],
                                       (a.tokens.len() + i) as u64);
            out.push(tok);
            feed.clear();
            feed.push(tok);
        }
        Ok(out)
    }

    /// Reserve blocks (decode lanes first — FIFO by lane index — then
    /// the oldest `max_prefills_per_iter` prefill chunks), build this
    /// iteration's [`BatchPlan`] and run **one** `forward_batch` over
    /// it. Returns whether any span ran.
    fn run_batch(&mut self) -> bool {
        let budget = self.cfg.max_prefills_per_iter.max(1);
        // Committed decode lanes reserve their next block first: a lane
        // that cannot get one finishes CacheFull deterministically
        // (FIFO by lane index) instead of failing the batch. Each
        // lane's entry carries its speculative draft (empty ⇒ plain
        // one-token decode).
        let mut decode_sel: Vec<(usize, Vec<u32>)> = Vec::new();
        // Blocks this iteration's committed work has yet to claim
        // (every candidate lane's base token plus the prefill
        // chunks): a speculative reservation is opportunistic and
        // must never eat into them. Each lane deducts its own base
        // share on reaching the front; overcounting (a lane preempted
        // later in the walk) only makes speculation more conservative.
        let mut later_need: usize = if self.draft.is_some() {
            self.active
                .iter()
                .filter(|a| {
                    !a.done && !a.preempted
                        && a.tokens.len() < a.req.params.max_new
                })
                .map(|a| {
                    self.pool.blocks_needed(&a.cache, a.cache.len + 1)
                })
                .sum::<usize>()
                + self.prefill_chunk_need(budget)
        } else {
            0
        };
        for idx in 0..self.active.len() {
            if self.active[idx].done || self.active[idx].preempted {
                continue;
            }
            if self.active[idx].tokens.len()
                >= self.active[idx].req.params.max_new
            {
                // Defensive: budget reached without the done flag —
                // finalize it rather than skipping it forever.
                self.active[idx].done = true;
                continue;
            }
            let need = self.active[idx].cache.len + 1;
            let class = self.active[idx].req.params.priority;
            let missing = self.pool.blocks_needed(&self.active[idx].cache,
                                                  need);
            later_need = later_need.saturating_sub(missing);
            // Speculate before reserving so the lane knows how much
            // room to ask for. The proposal runs entirely on the
            // draft engine and the lane's private draft cache —
            // target state is untouched until the verify span runs.
            let mut draft_toks: Vec<u32> = Vec::new();
            let k_goal = {
                let a = &self.active[idx];
                let remaining = a.req.params.max_new - a.tokens.len();
                let cap_room = a.cache.cap.saturating_sub(need);
                self.lane_draft_k(a)
                    .min(remaining.saturating_sub(1))
                    .min(cap_room)
            };
            if k_goal > 0 {
                match Self::propose(self.draft.as_ref().unwrap(),
                                    &mut self.draft_ws,
                                    &mut self.active[idx], k_goal) {
                    Ok(d) => {
                        self.metrics.draft_forwards += k_goal as u64;
                        self.metrics.draft_proposed += k_goal as u64;
                        draft_toks = d;
                    }
                    Err(_) => {
                        // A draft-lane failure must never touch a
                        // client stream: permanently drop the draft
                        // engine and serve plain decodes (bitwise
                        // identical output, just more forwards).
                        self.draft = None;
                        self.active[idx].draft_cache = None;
                    }
                }
            }
            if missing > self.pool.free_blocks() {
                Self::evict_until(&mut self.prefix, &mut self.pool,
                                  &mut self.metrics, missing);
            }
            if missing > self.pool.free_blocks() {
                // Pressure on a running lane: transparently preempt
                // strictly-lower-class lanes before cutting anyone
                // CacheFull. Same-class pressure falls through to the
                // deterministic youngest-first CacheFull cut below —
                // uniform-priority traffic is bitwise the pre-§15
                // behaviour.
                self.preempt_for(class, missing);
            }
            let a = &mut self.active[idx];
            if self.pool.reserve_writable(&mut a.cache, need).is_err() {
                a.done = true;
                a.finish = FinishReason::CacheFull;
                continue;
            }
            if !draft_toks.is_empty() {
                // Opportunistic speculative extension: the base token
                // is committed; the verify tail may take only blocks
                // nobody committed needs — prefix eviction is fine,
                // preemption is not (a draft is never worth killing a
                // lane over). On any shortfall the drafts are dropped
                // and the lane decodes plainly this iteration.
                let want = need + draft_toks.len();
                let extra = self.pool
                    .blocks_needed(&self.active[idx].cache, want);
                if self.pool.free_blocks() < extra + later_need {
                    Self::evict_until(&mut self.prefix, &mut self.pool,
                                      &mut self.metrics,
                                      extra + later_need);
                }
                let granted = self.pool.free_blocks()
                    >= extra + later_need
                    && self.pool
                        .reserve_writable(&mut self.active[idx].cache,
                                          want)
                        .is_ok();
                if !granted {
                    draft_toks.clear();
                }
            }
            decode_sel.push((idx, draft_toks));
        }
        // Prefill chunks, FIFO-strict over the oldest `budget` prefills:
        // when one cannot reserve, everything younger waits too (block
        // pressure must not let a younger prefill overtake a stalled
        // older one and invert the FIFO first-token order). Its blocks
        // may free later; a total stall is resolved by `step`'s requeue.
        let mut prefill_sel: Vec<(usize, usize)> = Vec::new(); // (pf, end)
        for pi in 0..self.prefilling.len().min(budget) {
            let pf = &self.prefilling[pi];
            let remaining = pf.work().len() - pf.consumed;
            let chunk = if self.cfg.prefill_chunk == 0 {
                remaining
            } else {
                self.cfg.prefill_chunk.min(remaining)
            };
            let end = pf.consumed + chunk;
            let class = pf.req.params.priority;
            let missing = self.pool.blocks_needed(&pf.cache, end);
            if missing > self.pool.free_blocks() {
                Self::evict_until(&mut self.prefix, &mut self.pool,
                                  &mut self.metrics, missing);
            }
            if missing > self.pool.free_blocks() {
                self.preempt_for(class, missing);
            }
            let pf = &mut self.prefilling[pi];
            if self.pool.reserve_writable(&mut pf.cache, end).is_err() {
                break;
            }
            prefill_sel.push((pi, end));
        }
        // A prefill (or later decode lane) may have preempted a lane
        // that had already reserved this iteration: its blocks are
        // gone, so it must not ride the plan.
        decode_sel.retain(|(i, _)| !self.active[*i].preempted);
        if decode_sel.is_empty() && prefill_sel.is_empty() {
            return false;
        }
        // Build the plan: prefill spans first, then decode lanes. Span
        // lane indices are positional — `caches` below is collected in
        // the same order.
        let mut plan = BatchPlan::new();
        let mut roles: Vec<SpanRole> = Vec::new();
        for &(pi, end) in &prefill_sel {
            let pf = &self.prefilling[pi];
            // A resumed lane's final chunk requests *no* logits: its
            // next token was sampled before preemption — recompute
            // rebuilds KV only, nothing is re-sampled or re-emitted.
            let logits = if end == pf.work().len() && pf.resume.is_none() {
                SpanLogits::Last
            } else {
                SpanLogits::None
            };
            plan.push_span(roles.len(), &pf.work()[pf.consumed..end],
                           logits);
            roles.push(SpanRole::Prefill { pf: pi, end });
        }
        let prefill_rows = plan.rows();
        for (idx, draft) in &decode_sel {
            // One verify span per lane: the committed next token plus
            // the draft tail, all rows emitting logits (degenerates to
            // the plain `SpanLogits::Last` decode span when the draft
            // is empty).
            plan.push_verify_span(roles.len(), self.active[*idx].next,
                                  draft);
            roles.push(SpanRole::Decode {
                idx: *idx,
                draft: draft.clone(),
            });
        }
        // Roles and plan spans must stay 1:1 — logits routing and error
        // attribution index one by the other. Guaranteed because every
        // span here is non-empty (admission rejects empty prompts, so a
        // prefilling entry always has ≥ 1 remaining token).
        debug_assert_eq!(plan.spans().len(), roles.len());
        // ONE ragged engine call. Cache references come straight from
        // the owning entries in span order: `iter_mut` hands out
        // disjoint `&mut`s, so — unlike the old slab pool's raw-pointer
        // `get_many_mut` — no `unsafe` is involved anywhere.
        let fwd_start = Instant::now();
        let result = {
            let mut caches: Vec<&mut KvCache> =
                Vec::with_capacity(roles.len());
            let mut ps = prefill_sel.iter().peekable();
            for (i, p) in self.prefilling.iter_mut().enumerate() {
                if ps.peek().is_some_and(|&&(pi, _)| pi == i) {
                    ps.next();
                    caches.push(&mut p.cache);
                }
            }
            let mut ds = decode_sel.iter().peekable();
            for (i, a) in self.active.iter_mut().enumerate() {
                if ds.peek().is_some_and(|e| e.0 == i) {
                    ds.next();
                    caches.push(&mut a.cache);
                }
            }
            self.engine.forward_batch(&plan, &mut caches, &mut self.ws)
        };
        match result {
            Ok(()) => {
                let prefill_spans = prefill_sel.len();
                let decode_spans = decode_sel.len();
                self.metrics.prefill_calls += prefill_spans as u64;
                self.metrics.record_forward(plan.rows(), prefill_rows,
                                            decode_spans, roles.len(),
                                            self.cfg.max_batch);
                if decode_spans > 0 {
                    self.metrics.record_decode_iter(decode_spans);
                    self.metrics.verify_forwards += decode_sel
                        .iter()
                        .filter(|(_, d)| !d.is_empty())
                        .count() as u64;
                    // The SLO-gate signal: wall time of this decode-
                    // bearing call (prefill rows riding it included —
                    // that contention is exactly what the gate sheds).
                    self.last_decode_ms =
                        fwd_start.elapsed().as_secs_f64() * 1e3;
                }
                self.consume_outputs(&plan, &roles);
            }
            Err(e) => self.attribute_error(&roles, &e),
        }
        true
    }

    /// Route the ragged batch's logits rows: promote completed prefills
    /// into the active set (first token, FIFO — the TTFT point) and
    /// sample one token per decode lane.
    fn consume_outputs(&mut self, plan: &BatchPlan, roles: &[SpanRole]) {
        // Prefill progress first; collect completions in FIFO order.
        let mut completed: Vec<(usize, usize)> = Vec::new(); // (span, pf)
        for (si, role) in roles.iter().enumerate() {
            if let SpanRole::Prefill { pf, end } = role {
                self.prefilling[*pf].consumed = *end;
                if *end == self.prefilling[*pf].work().len() {
                    completed.push((si, *pf));
                }
            }
        }
        let mut removed = 0usize;
        for (si, pi) in completed {
            let pf = self.prefilling.remove(pi - removed);
            removed += 1;
            match pf.resume {
                // Preempted lane: KV rebuilt, stream state restored —
                // re-enters decode with no sampling and no event (its
                // final span produced no logits row).
                Some(rs) => self.resume_lane(pf.req, pf.cache, rs),
                None => {
                    let row = plan.logits_rows(si).start;
                    self.activate(pf.req, pf.cache, row);
                }
            }
        }
        // Decode lanes: walk each verify span's logits rows in order,
        // sampling the lane's own stream draw by draw. (Activation only
        // pushed to the end of `active`, so the captured indices stay
        // valid.) Row i scores the position after the i-th span token,
        // so the walk emits the committed token's successor first, then
        // either confirms each draft token (sampled == drafted ⇒ its KV
        // is already right — keep walking) or emits the correction and
        // stops. Every emitted token is `sampler.sample(row, step)` at
        // the step a plain decode would have used on bitwise-identical
        // logits (batch-composition invariance, DESIGN.md §12), so
        // streams are identical with speculation on, off, or anywhere
        // in between — only the forward count changes.
        let vocab = self.engine.config().vocab;
        for (si, role) in roles.iter().enumerate() {
            let SpanRole::Decode { idx, draft } = role else { continue };
            let rows = plan.logits_rows(si);
            let span_len = draft.len() + 1;
            let (start, emitted, accepted);
            {
                let a = &mut self.active[*idx];
                // forward_batch advanced the cache over the whole
                // verify span; positions past the accepted prefix are
                // rolled back below.
                start = a.cache.len - span_len;
                let mut em = 0usize;
                let mut acc = 0u64;
                for (i, r) in rows.enumerate() {
                    let row = &self.ws.logits[r * vocab..(r + 1) * vocab];
                    // Counter step = number of tokens sampled so far,
                    // so the stream is a pure function of (seed, step)
                    // — identical for every thread count and batch
                    // composition.
                    let tok =
                        a.sampler.sample(row, a.tokens.len() as u64);
                    a.tokens.push(tok);
                    a.next = tok;
                    em += 1;
                    self.events.push(Event::Token {
                        id: a.req.id,
                        index: a.tokens.len() - 1,
                        token: tok,
                    });
                    // Logical capacity only — pool pressure is handled
                    // at the next iteration's reservation (CacheFull
                    // there too). `start + em` is the lane's committed
                    // KV length once the rollback below lands.
                    let cache_full = start + em + 1 >= a.cache.cap;
                    if a.req.params.stop_tokens.contains(&tok) {
                        a.done = true;
                        a.finish = FinishReason::Stop;
                    } else if a.tokens.len() >= a.req.params.max_new {
                        a.done = true;
                        a.finish = FinishReason::Length;
                    } else if cache_full {
                        a.done = true;
                        a.finish = FinishReason::CacheFull;
                    }
                    let matched = i < draft.len() && tok == draft[i];
                    if matched {
                        acc += 1;
                    }
                    if a.done || (i < draft.len() && !matched) {
                        break;
                    }
                }
                emitted = em;
                accepted = acc;
            }
            self.metrics.decode_tokens += emitted as u64;
            self.metrics.draft_accepted += accepted;
            if !draft.is_empty() {
                // Roll the target cache back to the accepted prefix:
                // rejected positions' KV is discarded and whole
                // surplus blocks return to the pool (restoring the
                // `len == prompt + tokens − 1` lane invariant).
                let surplus =
                    self.active[*idx].cache.truncate(start + emitted);
                for block in surplus {
                    self.pool.reclaim(block);
                }
                // The draft cache may hold proposal positions past the
                // accepted point; drop them so the next catch-up span
                // refeeds from the committed stream.
                if let Some(dc) = &mut self.active[*idx].draft_cache {
                    if start + emitted < dc.len {
                        let _ = dc.truncate(start + emitted);
                    }
                }
            }
        }
    }

    /// A typed engine error validated before any state mutation: nothing
    /// advanced. Terminate only the offending span's request when the
    /// error names one; otherwise fail every participant rather than
    /// livelock on a persistent error. Untouched lanes retry next
    /// iteration.
    fn attribute_error(&mut self, roles: &[SpanRole], e: &EngineError) {
        match e {
            EngineError::KvOverflow { lane, .. }
            | EngineError::KvExhausted { lane, .. } => match roles[*lane] {
                SpanRole::Decode { idx, .. } => {
                    let a = &mut self.active[idx];
                    a.error = Some(e.to_string());
                    a.finish = FinishReason::Error;
                    a.done = true;
                    self.metrics.failed += 1;
                }
                SpanRole::Prefill { pf, .. } => {
                    let mut p = self.prefilling.remove(pf);
                    self.pool.release(&mut p.cache);
                    self.fail_request(p.req, e.to_string());
                }
            },
            _ => {
                // No span attribution — fail the whole batch. Prefill
                // roles carry ascending indices; walk them back-to-front
                // so removal keeps the remaining indices valid.
                for role in roles.iter().rev() {
                    match *role {
                        SpanRole::Prefill { pf, .. } => {
                            let mut p = self.prefilling.remove(pf);
                            self.pool.release(&mut p.cache);
                            self.fail_request(p.req, e.to_string());
                        }
                        SpanRole::Decode { idx, .. } => {
                            let a = &mut self.active[idx];
                            a.error = Some(e.to_string());
                            a.finish = FinishReason::Error;
                            a.done = true;
                            self.metrics.failed += 1;
                        }
                    }
                }
            }
        }
    }

    /// Promote a fully-prefilled request into the active set: sample its
    /// first token (counter step 0 — the TTFT point) from logits row
    /// `first_logits_row` of the just-run batch and emit the first
    /// `Token` frame.
    fn activate(&mut self, req: Request, cache: KvCache,
                first_logits_row: usize) {
        let vocab = self.engine.config().vocab;
        let row = &self.ws.logits
            [first_logits_row * vocab..(first_logits_row + 1) * vocab];
        let sampler = req.params.sampler();
        let first = sampler.sample(row, 0);
        let ttft = req.submitted.elapsed();
        self.events.push(Event::Token { id: req.id, index: 0, token: first });
        // Same termination rules (and priority) as the decode step, so a
        // prompt that exactly fills `max_seq` ends gracefully with
        // `CacheFull` instead of tripping a KvOverflow next iteration.
        let cache_full = cache.len + 1 >= cache.cap;
        let (done, finish) = if req.params.stop_tokens.contains(&first) {
            (true, FinishReason::Stop)
        } else if req.params.max_new <= 1 {
            (true, FinishReason::Length)
        } else if cache_full {
            (true, FinishReason::CacheFull)
        } else {
            (false, FinishReason::Length)
        };
        self.active.push(Active {
            req,
            cache,
            tokens: vec![first],
            next: first,
            ttft,
            sampler,
            done,
            finish,
            error: None,
            preempted: false,
            draft_cache: None,
        });
    }

    /// Re-enter a preempted lane into the active set after its
    /// recompute prefill completed. Its KV again covers
    /// `prompt ++ tokens[..len-1]`, the last generated token is the
    /// next forward input, and the counter-based sampler continues at
    /// step `tokens.len()` — so the continuation is bitwise the
    /// uninterrupted stream (DESIGN.md §15). Nothing is sampled or
    /// emitted here: every token it holds already reached the client.
    /// Termination states are unreachable at this point: a lane is
    /// only preempted while live, i.e. below `max_new`, not stopped,
    /// and with logical KV room for its next position.
    fn resume_lane(&mut self, req: Request, cache: KvCache,
                   rs: ResumeState) {
        let sampler = req.params.sampler();
        let next = *rs.tokens.last().expect("preempted lane holds tokens");
        self.active.push(Active {
            req,
            cache,
            tokens: rs.tokens,
            next,
            ttft: rs.ttft,
            sampler,
            done: false,
            finish: FinishReason::Length,
            error: None,
            preempted: false,
            draft_cache: None,
        });
    }

    /// Deterministic stall resolution (see [`Scheduler::step`]): the
    /// newest prefilling sequence (LIFO victim) returns its blocks and
    /// goes back to the **front** of the pending queue — transient pool
    /// pressure is backpressure, not a request failure. Its consumed
    /// chunks are discarded; re-prefilling them later reproduces the
    /// same KV bitwise, so the eventual token stream is unchanged.
    /// Progress is guaranteed: admission headroom keeps new admissions
    /// from taking the older prefills' blocks, and the arena covers ≥
    /// one `max_seq` sequence, so the oldest always completes.
    fn requeue_stalled_prefill(&mut self) {
        let mut p = self.prefilling.pop().unwrap();
        self.pool.release(&mut p.cache);
        self.metrics.kv_requeues += 1;
        // A stalled resumed lane keeps its generation state: the next
        // admission recomputes the same work and continues the stream.
        self.pending.push_front(PendingEntry {
            req: p.req,
            resume: p.resume,
        });
    }

    fn finalize(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done {
                // Order-preserving removal: lane index stays arrival
                // order, so the decode-reservation priority (and the
                // CacheFull cut order under block pressure) is genuinely
                // oldest-first. `max_batch` lanes, so the shift is cheap.
                let mut a = self.active.remove(i);
                self.pool.release(&mut a.cache);
                let latency = a.req.submitted.elapsed();
                // Failed/cancelled sequences count only in their own
                // counters (set at the marking site) — completion counts
                // and latency percentiles describe normal successes only.
                if a.error.is_none() && a.finish != FinishReason::Cancelled {
                    self.metrics.record_completion(latency, a.ttft,
                                                   a.req.prompt.len(),
                                                   a.tokens.len(),
                                                   a.req.params.priority,
                                                   a.req.params.deadline_ms);
                }
                let response = Response {
                    id: a.req.id,
                    tokens: a.tokens,
                    ttft: a.ttft,
                    latency,
                    prompt_len: a.req.prompt.len(),
                    finish: if a.error.is_some() {
                        FinishReason::Error
                    } else {
                        a.finish
                    },
                    error: a.error,
                };
                self.events.push(if response.error.is_some() {
                    Event::Error { response }
                } else {
                    Event::Done { response }
                });
            } else {
                i += 1;
            }
        }
    }

    /// Run until all submitted work completes; returns the terminal
    /// response of every request (token frames are dropped — use
    /// [`Scheduler::take_events`] for the full stream).
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let start = Instant::now();
        while self.has_work() {
            self.step();
            for ev in self.take_events() {
                match ev {
                    Event::Done { response } | Event::Error { response } => {
                        out.push(response)
                    }
                    Event::Token { .. } => {}
                }
            }
            assert!(start.elapsed() < Duration::from_secs(600),
                    "scheduler livelock");
        }
        out
    }
}
