//! Iteration-level (continuous-batching) scheduler.
//!
//! Owns the engine, a KV pool and the pending queue. Each call to
//! [`Scheduler::step`] performs one scheduling iteration:
//!
//! 1. **Cancellation:** tear cancelled sequences out of the batch —
//!    pending requests are answered immediately, active/prefilling ones
//!    are finalized this iteration and their KV slabs returned.
//! 2. **Admission (router):** pop pending requests FIFO while there is
//!    batch room and a free KV slab, capped at `max_prefills_per_iter`
//!    per iteration to bound decode stalls; run their prefill and sample
//!    their first token (TTFT point).
//! 3. **Decode:** one batched decode step across all active sequences.
//! 4. **Completion:** sequences that hit `max_new` / a stop token /
//!    cache capacity are finalized, their slabs returned to the pool.
//!
//! Progress is reported as an **event stream** ([`Event`], drained via
//! [`Scheduler::take_events`]): one `Token` frame per sampled token and
//! exactly one terminal `Done`/`Error` frame per request — the per-token
//! cadence the serving layer streams to clients (DESIGN.md §11).
//!
//! Token selection goes through each request's seeded
//! [`Sampler`](crate::engine::Sampler) (`GenerationParams::sampler`):
//! greedy requests run the seed argmax path bitwise unchanged, sampled
//! requests draw from a counter-based per-request RNG, so streams are
//! deterministic for every thread count and batch composition.
//!
//! **Threading model:** the scheduling loop itself is synchronous — one
//! iteration at a time, driven by [`super::server::Server`]'s worker
//! thread — but the engine underneath executes every forward call on its
//! intra-op worker pool ([`crate::quant::parallel`]): tiled multi-threaded
//! GEMM, prefill attention over query-row blocks, decode attention across
//! batch lanes. [`SchedulerConfig::threads`] sizes that pool (plumbed from
//! the JSON config / `--threads`; DESIGN.md §7). Token streams are bitwise
//! identical for every thread count, so scheduling invariants and goldens
//! are unaffected by the parallelism.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::{Engine, EngineError, KvDtype, Sampler, Workspace};

use super::kv_pool::KvPool;
use super::metrics::Metrics;
use super::request::{Event, FinishReason, Request, Response};

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently active sequences (decode batch cap).
    pub max_batch: usize,
    /// KV slabs (≥ max_batch; extra slabs buffer admissions).
    pub kv_slabs: usize,
    /// Per-sequence KV capacity.
    pub max_seq: usize,
    /// New prefills admitted per iteration.
    pub max_prefills_per_iter: usize,
    /// Pending-queue bound (backpressure: submit fails beyond it).
    pub queue_cap: usize,
    /// Chunked prefill: prompts longer than this are prefilled
    /// `prefill_chunk` tokens per iteration so long prompts cannot stall
    /// the decode batch (0 ⇒ disabled, whole prompt in one call).
    pub prefill_chunk: usize,
    /// Engine intra-op compute threads (`quant::parallel` pool): 1 ⇒
    /// serial kernels (the deterministic baseline — though every count
    /// is bitwise identical), 0 ⇒ all available cores.
    pub threads: usize,
    /// KV-slab storage dtype: `F32` (paper-parity default) or `Int8`
    /// (statically-quantized cache, 4× more servable KV per box;
    /// DESIGN.md §10). Plumbed from JSON `scheduler.kv_cache` /
    /// `--kv-cache`.
    pub kv_dtype: KvDtype,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            max_seq: 512,
            max_prefills_per_iter: 2,
            queue_cap: 1024,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
        }
    }
}

struct Active {
    req: Request,
    slab: usize,
    tokens: Vec<u32>,
    next: u32,
    ttft: Duration,
    /// Per-request seeded sampler (greedy for `temperature == 0`).
    sampler: Sampler,
    done: bool,
    finish: FinishReason,
    /// Set when a typed engine error terminated this sequence; carried
    /// into the terminal event so the failure is per-request, not fatal.
    error: Option<String>,
}

/// One request mid-way through a chunked prefill (at most one in flight;
/// that alone bounds per-iteration prefill work by `prefill_chunk`).
struct Prefilling {
    req: Request,
    slab: usize,
    consumed: usize,
}

pub struct Scheduler {
    engine: Engine,
    cfg: SchedulerConfig,
    pool: KvPool,
    pending: VecDeque<Request>,
    prefilling: Option<Prefilling>,
    active: Vec<Active>,
    ws: Workspace,
    pub metrics: Metrics,
    /// Ids whose cancellation was requested but not yet applied; drained
    /// at the start of every iteration (unknown ids are dropped — the
    /// request already finished).
    cancel_requests: Vec<u64>,
    events: Vec<Event>,
}

impl Scheduler {
    pub fn new(mut engine: Engine, cfg: SchedulerConfig) -> Self {
        // The scheduler owns engine threading: config is the single
        // source of truth for the deployment (DESIGN.md §7).
        engine.set_threads(cfg.threads);
        // Int8 slabs need per-layer KV scales; bundles predating the
        // format-2 schema (and fp16 baselines) get probe-calibrated
        // fallback scales so `kv_cache=int8` serves everywhere.
        if cfg.kv_dtype == KvDtype::Int8 {
            engine.ensure_kv_scales().expect("probe KV calibration");
        }
        let mc = engine.config();
        let pool = KvPool::with_dtype(cfg.kv_dtype, cfg.kv_slabs,
                                      mc.n_layers, cfg.max_seq, mc.d_model);
        Scheduler {
            engine,
            cfg,
            pool,
            pending: VecDeque::new(),
            prefilling: None,
            active: Vec::new(),
            ws: Workspace::new(),
            metrics: Metrics::default(),
            cancel_requests: Vec::new(),
            events: Vec::new(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request; `Err` when the queue is full (backpressure).
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        if self.pending.len() >= self.cfg.queue_cap {
            self.metrics.rejected += 1;
            return Err(req);
        }
        self.pending.push_back(req);
        Ok(())
    }

    /// Request cancellation of `id`. Applied at the start of the next
    /// iteration: a pending request is answered immediately (`Done`,
    /// finish `Cancelled`), an active or prefilling one is torn out of
    /// the continuous batch and its KV slab returned to the pool. Ids
    /// that match nothing (already finished, never existed) are ignored.
    pub fn cancel(&mut self, id: u64) {
        self.cancel_requests.push(id);
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
            || self.prefilling.is_some()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Free KV slabs (capacity minus live sequences) — observability for
    /// tests and admission diagnostics.
    pub fn kv_available(&self) -> usize {
        self.pool.available()
    }

    pub fn kv_capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Drain the event stream accumulated since the last call: `Token`
    /// frames in generation order, one terminal `Done`/`Error` frame per
    /// finished request.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// One scheduling iteration. Returns number of sequences advanced.
    pub fn step(&mut self) -> usize {
        self.apply_cancellations();
        self.admit();
        self.decode();
        self.finalize();
        self.active.len()
    }

    /// Apply queued `cancel()` calls: answer pending requests outright,
    /// mark active/prefilling sequences done with finish `Cancelled` so
    /// this iteration's finalize returns their slabs.
    fn apply_cancellations(&mut self) {
        for id in std::mem::take(&mut self.cancel_requests) {
            if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
                let req = self.pending.remove(pos).unwrap();
                self.answer_cancelled(&req);
                continue;
            }
            if self.prefilling.as_ref().is_some_and(|p| p.req.id == id) {
                let pf = self.prefilling.take().unwrap();
                self.pool.dealloc(pf.slab);
                self.answer_cancelled(&pf.req);
                continue;
            }
            if let Some(a) =
                self.active.iter_mut().find(|a| a.req.id == id && !a.done)
            {
                a.done = true;
                a.finish = FinishReason::Cancelled;
                self.metrics.cancelled += 1;
            }
        }
    }

    /// Terminal event for a request cancelled before it produced any
    /// token (pending / mid-prefill).
    fn answer_cancelled(&mut self, req: &Request) {
        self.metrics.cancelled += 1;
        self.events.push(Event::Done {
            response: Response {
                id: req.id,
                tokens: Vec::new(),
                ttft: Duration::ZERO,
                latency: req.submitted.elapsed(),
                prompt_len: req.prompt.len(),
                finish: FinishReason::Cancelled,
                error: None,
            },
        });
    }

    /// Fail a not-yet-active request with a typed engine error: free its
    /// slab, answer it (empty tokens + error), keep the worker alive.
    fn fail_request(&mut self, req: Request, slab: usize, err: &EngineError) {
        self.pool.dealloc(slab);
        self.metrics.failed += 1;
        self.events.push(Event::Error {
            response: Response::failed(req.id, req.prompt.len(),
                                       req.submitted.elapsed(),
                                       err.to_string()),
        });
    }

    /// Promote a fully-prefilled request into the active set: sample its
    /// first token (counter step 0 — the TTFT point) and emit the first
    /// `Token` frame.
    fn activate(&mut self, req: Request, slab: usize, first_logits_row: usize) {
        let vocab = self.engine.config().vocab;
        let row = &self.ws.logits
            [first_logits_row * vocab..(first_logits_row + 1) * vocab];
        let sampler = req.params.sampler();
        let first = sampler.sample(row, 0);
        let ttft = req.submitted.elapsed();
        self.events.push(Event::Token { id: req.id, index: 0, token: first });
        // Same termination rules (and priority) as the decode step, so a
        // prompt that exactly fills its slab ends gracefully with
        // `CacheFull` instead of tripping a KvOverflow next iteration.
        let cache_full = {
            let c = self.pool.get_mut(slab);
            c.len + 1 >= c.cap
        };
        let (done, finish) = if req.params.stop_tokens.contains(&first) {
            (true, FinishReason::Stop)
        } else if req.params.max_new <= 1 {
            (true, FinishReason::Length)
        } else if cache_full {
            (true, FinishReason::CacheFull)
        } else {
            (false, FinishReason::Length)
        };
        self.active.push(Active {
            req,
            slab,
            tokens: vec![first],
            next: first,
            ttft,
            sampler,
            done,
            finish,
            error: None,
        });
    }

    /// Advance the in-flight chunked prefill by one chunk; returns true
    /// if it consumed this iteration's prefill budget.
    fn advance_chunked(&mut self) -> bool {
        let Some(mut pf) = self.prefilling.take() else { return false };
        let chunk = self.cfg.prefill_chunk.max(1);
        let end = (pf.consumed + chunk).min(pf.req.prompt.len());
        let toks: Vec<u32> = pf.req.prompt[pf.consumed..end].to_vec();
        let cache = self.pool.get_mut(pf.slab);
        if let Err(e) = self.engine.prefill(&toks, cache, &mut self.ws) {
            self.fail_request(pf.req, pf.slab, &e);
            return true;
        }
        self.metrics.prefill_calls += 1;
        pf.consumed = end;
        if pf.consumed == pf.req.prompt.len() {
            self.activate(pf.req, pf.slab, toks.len() - 1);
        } else {
            self.prefilling = Some(pf);
        }
        true
    }

    fn admit(&mut self) {
        let mut admitted = usize::from(self.advance_chunked());
        while admitted < self.cfg.max_prefills_per_iter
            && self.prefilling.is_none()
            && self.active.len() < self.cfg.max_batch
            && !self.pending.is_empty()
        {
            let Some(slab) = self.pool.alloc() else { break };
            let req = self.pending.pop_front().unwrap();
            // Long prompts go through the chunked path so one admission
            // cannot stall the whole decode batch.
            if self.cfg.prefill_chunk > 0
                && req.prompt.len() > self.cfg.prefill_chunk
            {
                self.prefilling = Some(Prefilling { req, slab, consumed: 0 });
                admitted += usize::from(self.advance_chunked());
                continue;
            }
            let cache = self.pool.get_mut(slab);
            // Oversized prompts (and any other engine-side failure)
            // surface as the typed error → per-request failure; the
            // worker thread never dies on them.
            if let Err(e) = self.engine.prefill(&req.prompt, cache,
                                                &mut self.ws) {
                self.fail_request(req, slab, &e);
                admitted += 1;
                continue;
            }
            self.metrics.prefill_calls += 1;
            let last_row = req.prompt.len() - 1;
            self.activate(req, slab, last_row);
            admitted += 1;
        }
    }

    fn decode(&mut self) {
        if self.active.is_empty() {
            return;
        }
        // Sequences that already reached their budget skip the step.
        let run_idx: Vec<usize> = (0..self.active.len())
            .filter(|&i| !self.active[i].done
                && self.active[i].tokens.len()
                    < self.active[i].req.params.max_new)
            .collect();
        if run_idx.is_empty() {
            for a in &mut self.active {
                a.done = true;
            }
            return;
        }
        let tokens: Vec<u32> =
            run_idx.iter().map(|&i| self.active[i].next).collect();
        let slabs: Vec<usize> =
            run_idx.iter().map(|&i| self.active[i].slab).collect();
        let mut caches = self.pool.get_many_mut(&slabs);
        if let Err(e) = self.engine.decode_batch(&tokens, &mut caches,
                                                 &mut self.ws) {
            // The engine validates before computing, so nothing advanced:
            // terminate only the offending lane (its partial tokens ship
            // with the error) and let the rest retry next iteration.
            match e {
                EngineError::KvOverflow { lane, .. } => {
                    let idx = run_idx[lane];
                    self.active[idx].error = Some(e.to_string());
                    self.active[idx].finish = FinishReason::Error;
                    self.active[idx].done = true;
                    self.metrics.failed += 1;
                }
                _ => {
                    // No lane attribution — fail the whole run set rather
                    // than livelock on a persistent error.
                    for &idx in &run_idx {
                        self.active[idx].error = Some(e.to_string());
                        self.active[idx].finish = FinishReason::Error;
                        self.active[idx].done = true;
                        self.metrics.failed += 1;
                    }
                }
            }
            return;
        }
        self.metrics.record_decode_iter(run_idx.len());
        let vocab = self.engine.config().vocab;
        for (bi, &i) in run_idx.iter().enumerate() {
            let row = &self.ws.logits[bi * vocab..(bi + 1) * vocab];
            let a = &mut self.active[i];
            // Counter step = number of tokens sampled so far, so the
            // stream is a pure function of (seed, step) — identical for
            // every thread count and batch composition.
            let tok = a.sampler.sample(row, a.tokens.len() as u64);
            a.tokens.push(tok);
            a.next = tok;
            self.events.push(Event::Token {
                id: a.req.id,
                index: a.tokens.len() - 1,
                token: tok,
            });
            let cache_full = {
                let c = self.pool.get_mut(a.slab);
                c.len + 1 >= c.cap
            };
            let a = &mut self.active[i];
            if a.req.params.stop_tokens.contains(&tok) {
                a.done = true;
                a.finish = FinishReason::Stop;
            } else if a.tokens.len() >= a.req.params.max_new {
                a.done = true;
                a.finish = FinishReason::Length;
            } else if cache_full {
                a.done = true;
                a.finish = FinishReason::CacheFull;
            }
        }
    }

    fn finalize(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done {
                let a = self.active.swap_remove(i);
                self.pool.dealloc(a.slab);
                let latency = a.req.submitted.elapsed();
                // Failed/cancelled sequences count only in their own
                // counters (set at the marking site) — completion counts
                // and latency percentiles describe normal successes only.
                if a.error.is_none() && a.finish != FinishReason::Cancelled {
                    self.metrics.record_completion(latency, a.ttft,
                                                   a.req.prompt.len(),
                                                   a.tokens.len());
                }
                let response = Response {
                    id: a.req.id,
                    tokens: a.tokens,
                    ttft: a.ttft,
                    latency,
                    prompt_len: a.req.prompt.len(),
                    finish: if a.error.is_some() {
                        FinishReason::Error
                    } else {
                        a.finish
                    },
                    error: a.error,
                };
                self.events.push(if response.error.is_some() {
                    Event::Error { response }
                } else {
                    Event::Done { response }
                });
            } else {
                i += 1;
            }
        }
    }

    /// Run until all submitted work completes; returns the terminal
    /// response of every request (token frames are dropped — use
    /// [`Scheduler::take_events`] for the full stream).
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let start = Instant::now();
        while self.has_work() {
            self.step();
            for ev in self.take_events() {
                match ev {
                    Event::Done { response } | Event::Error { response } => {
                        out.push(response)
                    }
                    Event::Token { .. } => {}
                }
            }
            assert!(start.elapsed() < Duration::from_secs(600),
                    "scheduler livelock");
        }
        out
    }
}
