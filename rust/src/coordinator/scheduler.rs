//! Iteration-level (continuous-batching) scheduler — **one engine call
//! per iteration** (DESIGN.md §12).
//!
//! Owns the engine, a KV pool and the pending queue. Each call to
//! [`Scheduler::step`] performs one scheduling iteration:
//!
//! 1. **Cancellation:** tear cancelled sequences out of the batch —
//!    pending requests are answered immediately, active/prefilling ones
//!    are finalized this iteration and their KV slabs returned.
//! 2. **Admission (router):** pop pending requests FIFO into the
//!    prefilling set while there is batch room and a free KV slab
//!    (oversized prompts are answered with the typed overflow error up
//!    front, before holding a slab).
//! 3. **One ragged batch:** build a single [`BatchPlan`] — up to
//!    `max_prefills_per_iter` prefill spans (whole prompts, or
//!    `prefill_chunk`-token chunks of the in-flight prefills; several
//!    chunked prefills ride concurrently) plus one decode span per
//!    active lane — and run **one** [`Engine::forward_batch`] call over
//!    the stacked rows.
//! 4. **Sampling:** completed prefills are promoted to the active set
//!    (first token — the TTFT point, in FIFO order); every decode lane
//!    samples its next token from its span's logits row.
//! 5. **Completion:** sequences that hit `max_new` / a stop token /
//!    cache capacity are finalized, their slabs returned to the pool.
//!
//! Progress is reported as an **event stream** ([`Event`], drained via
//! [`Scheduler::take_events`]): one `Token` frame per sampled token and
//! exactly one terminal `Done`/`Error` frame per request — the per-token
//! cadence the serving layer streams to clients (DESIGN.md §11).
//!
//! Token selection goes through each request's seeded
//! [`Sampler`](crate::engine::Sampler) (`GenerationParams::sampler`):
//! greedy requests run the seed argmax path bitwise unchanged, sampled
//! requests draw from a counter-based per-request RNG. The unified pass
//! is bitwise identical to the sequential seed paths for every batch
//! composition (`tests/ragged_batch.rs`), so token streams are
//! deterministic for every thread count, chunking choice, and batch
//! composition.
//!
//! **Threading model:** the scheduling loop itself is synchronous — one
//! iteration at a time, driven by [`super::server::Server`]'s worker
//! thread — but the engine underneath executes every forward call on its
//! intra-op worker pool ([`crate::quant::parallel`]): tiled multi-threaded
//! GEMM and ragged attention over row blocks. [`SchedulerConfig::threads`]
//! sizes that pool (plumbed from the JSON config / `--threads`;
//! DESIGN.md §7). Token streams are bitwise identical for every thread
//! count, so scheduling invariants and goldens are unaffected by the
//! parallelism.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::engine::{
    BatchPlan, Engine, EngineError, KvDtype, Sampler, SpanLogits, Workspace,
};

use super::kv_pool::KvPool;
use super::metrics::Metrics;
use super::request::{Event, FinishReason, Request, Response};

#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently live sequences (active decode lanes plus
    /// in-flight prefills — every lane of the per-iteration ragged
    /// batch).
    pub max_batch: usize,
    /// KV slabs (≥ max_batch; extra slabs buffer admissions).
    pub kv_slabs: usize,
    /// Per-sequence KV capacity.
    pub max_seq: usize,
    /// Prefill spans per ragged batch: bounds per-iteration prefill work
    /// (and therefore decode stalls). Several chunked prefills may be in
    /// flight; each iteration advances the oldest `max_prefills_per_iter`
    /// of them by one span.
    pub max_prefills_per_iter: usize,
    /// Pending-queue bound (backpressure: submit fails beyond it).
    pub queue_cap: usize,
    /// Chunked prefill: prompts are prefilled at most `prefill_chunk`
    /// tokens per iteration so long prompts cannot stall the decode
    /// batch (0 ⇒ disabled, whole prompt in one span).
    pub prefill_chunk: usize,
    /// Engine intra-op compute threads (`quant::parallel` pool): 1 ⇒
    /// serial kernels (the deterministic baseline — though every count
    /// is bitwise identical), 0 ⇒ all available cores.
    pub threads: usize,
    /// KV-slab storage dtype: `F32` (paper-parity default) or `Int8`
    /// (statically-quantized cache, 4× more servable KV per box;
    /// DESIGN.md §10). Plumbed from JSON `scheduler.kv_cache` /
    /// `--kv-cache`.
    pub kv_dtype: KvDtype,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            max_seq: 512,
            max_prefills_per_iter: 2,
            queue_cap: 1024,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
        }
    }
}

struct Active {
    req: Request,
    slab: usize,
    tokens: Vec<u32>,
    next: u32,
    ttft: Duration,
    /// Per-request seeded sampler (greedy for `temperature == 0`).
    sampler: Sampler,
    done: bool,
    finish: FinishReason,
    /// Set when a typed engine error terminated this sequence; carried
    /// into the terminal event so the failure is per-request, not fatal.
    error: Option<String>,
}

/// A request whose prompt is not yet fully in its KV slab. Any number
/// may be in flight concurrently; each iteration the oldest
/// `max_prefills_per_iter` of them contribute one span to the ragged
/// batch (whole remaining prompt when chunking is off).
struct Prefilling {
    req: Request,
    slab: usize,
    consumed: usize,
}

/// What a span of the per-iteration [`BatchPlan`] stands for — used to
/// route logits rows and to attribute typed engine errors back to the
/// owning request.
enum SpanRole {
    /// Span advances `prefilling[pf]` to `consumed == end`.
    Prefill { pf: usize, end: usize },
    /// Span decodes one token for `active[idx]`.
    Decode { idx: usize },
}

pub struct Scheduler {
    engine: Engine,
    cfg: SchedulerConfig,
    pool: KvPool,
    pending: VecDeque<Request>,
    prefilling: Vec<Prefilling>,
    active: Vec<Active>,
    ws: Workspace,
    pub metrics: Metrics,
    /// Ids whose cancellation was requested but not yet applied; drained
    /// at the start of every iteration (unknown ids are dropped — the
    /// request already finished).
    cancel_requests: Vec<u64>,
    events: Vec<Event>,
}

impl Scheduler {
    pub fn new(mut engine: Engine, cfg: SchedulerConfig) -> Self {
        // The scheduler owns engine threading: config is the single
        // source of truth for the deployment (DESIGN.md §7).
        engine.set_threads(cfg.threads);
        // Int8 slabs need per-layer KV scales; bundles predating the
        // format-2 schema (and fp16 baselines) get probe-calibrated
        // fallback scales so `kv_cache=int8` serves everywhere.
        if cfg.kv_dtype == KvDtype::Int8 {
            engine.ensure_kv_scales().expect("probe KV calibration");
        }
        let mc = engine.config();
        let pool = KvPool::with_dtype(cfg.kv_dtype, cfg.kv_slabs,
                                      mc.n_layers, cfg.max_seq, mc.d_model);
        Scheduler {
            engine,
            cfg,
            pool,
            pending: VecDeque::new(),
            prefilling: Vec::new(),
            active: Vec::new(),
            ws: Workspace::new(),
            metrics: Metrics::default(),
            cancel_requests: Vec::new(),
            events: Vec::new(),
        }
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Enqueue a request; `Err` when the queue is full (backpressure).
    pub fn submit(&mut self, req: Request) -> Result<(), Request> {
        if self.pending.len() >= self.cfg.queue_cap {
            self.metrics.rejected += 1;
            return Err(req);
        }
        self.pending.push_back(req);
        Ok(())
    }

    /// Request cancellation of `id`. Applied at the start of the next
    /// iteration: a pending request is answered immediately (`Done`,
    /// finish `Cancelled`), an active or prefilling one is torn out of
    /// the continuous batch and its KV slab returned to the pool. Ids
    /// that match nothing (already finished, never existed) are ignored.
    pub fn cancel(&mut self, id: u64) {
        self.cancel_requests.push(id);
    }

    pub fn has_work(&self) -> bool {
        !self.pending.is_empty() || !self.active.is_empty()
            || !self.prefilling.is_empty()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Requests currently mid-prefill (concurrent chunked prefills are
    /// allowed; observability for tests and diagnostics).
    pub fn prefilling_len(&self) -> usize {
        self.prefilling.len()
    }

    /// Free KV slabs (capacity minus live sequences) — observability for
    /// tests and admission diagnostics.
    pub fn kv_available(&self) -> usize {
        self.pool.available()
    }

    pub fn kv_capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Drain the event stream accumulated since the last call: `Token`
    /// frames in generation order, one terminal `Done`/`Error` frame per
    /// finished request.
    pub fn take_events(&mut self) -> Vec<Event> {
        std::mem::take(&mut self.events)
    }

    /// One scheduling iteration: cancellations, admissions, then **one**
    /// `forward_batch` ragged engine call carrying every prefill span
    /// and decode lane, then sampling and completion. Returns the number
    /// of active sequences.
    pub fn step(&mut self) -> usize {
        self.apply_cancellations();
        self.admit();
        self.run_batch();
        self.finalize();
        self.active.len()
    }

    /// Apply queued `cancel()` calls: answer pending requests outright,
    /// mark active/prefilling sequences done with finish `Cancelled` so
    /// this iteration's finalize returns their slabs.
    fn apply_cancellations(&mut self) {
        for id in std::mem::take(&mut self.cancel_requests) {
            if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
                let req = self.pending.remove(pos).unwrap();
                self.answer_cancelled(&req);
                continue;
            }
            if let Some(pos) =
                self.prefilling.iter().position(|p| p.req.id == id)
            {
                let pf = self.prefilling.remove(pos);
                self.pool.dealloc(pf.slab);
                self.answer_cancelled(&pf.req);
                continue;
            }
            if let Some(a) =
                self.active.iter_mut().find(|a| a.req.id == id && !a.done)
            {
                a.done = true;
                a.finish = FinishReason::Cancelled;
                self.metrics.cancelled += 1;
            }
        }
    }

    /// Terminal event for a request cancelled before it produced any
    /// token (pending / mid-prefill).
    fn answer_cancelled(&mut self, req: &Request) {
        self.metrics.cancelled += 1;
        self.events.push(Event::Done {
            response: Response {
                id: req.id,
                tokens: Vec::new(),
                ttft: Duration::ZERO,
                latency: req.submitted.elapsed(),
                prompt_len: req.prompt.len(),
                finish: FinishReason::Cancelled,
                error: None,
            },
        });
    }

    /// Fail a not-yet-active request with a typed engine error: free its
    /// slab, answer it (empty tokens + error), keep the worker alive.
    fn fail_request(&mut self, req: Request, slab: usize, err: &EngineError) {
        self.pool.dealloc(slab);
        self.metrics.failed += 1;
        self.events.push(Event::Error {
            response: Response::failed(req.id, req.prompt.len(),
                                       req.submitted.elapsed(),
                                       err.to_string()),
        });
    }

    /// Admission (router): pending → prefilling, FIFO, while there is
    /// batch room (active + in-flight prefills), a free slab, and an
    /// unused prefill-span slot this iteration. Prompts that can never
    /// run — empty (no logits row to sample a first token from), or
    /// longer than a slab — are answered with a per-request failure up
    /// front: no slab held, no engine call burned. (The server layer
    /// already rejects empty prompts synchronously; this guards direct
    /// `Scheduler::submit` users, where the seed panicked instead.)
    fn admit(&mut self) {
        let budget = self.cfg.max_prefills_per_iter.max(1);
        while self.prefilling.len() < budget
            && self.active.len() + self.prefilling.len() < self.cfg.max_batch
            && !self.pending.is_empty()
        {
            let plen = self.pending.front().map_or(0, |r| r.prompt.len());
            if plen == 0 {
                let req = self.pending.pop_front().unwrap();
                self.metrics.failed += 1;
                self.events.push(Event::Error {
                    response: Response::failed(
                        req.id, 0, req.submitted.elapsed(),
                        "empty prompt".into()),
                });
                continue;
            }
            if plen > self.cfg.max_seq {
                let req = self.pending.pop_front().unwrap();
                let err = EngineError::KvOverflow {
                    lane: 0,
                    pos: plen - 1,
                    cap: self.cfg.max_seq,
                };
                self.metrics.failed += 1;
                self.events.push(Event::Error {
                    response: Response::failed(req.id, plen,
                                               req.submitted.elapsed(),
                                               err.to_string()),
                });
                continue;
            }
            let Some(slab) = self.pool.alloc() else { break };
            let req = self.pending.pop_front().unwrap();
            self.prefilling.push(Prefilling { req, slab, consumed: 0 });
        }
    }

    /// Build this iteration's [`BatchPlan`] — prefill spans first (FIFO,
    /// bounded by `max_prefills_per_iter`), then one decode span per
    /// runnable active lane — and run **one** `forward_batch` over it.
    fn run_batch(&mut self) {
        let budget = self.cfg.max_prefills_per_iter.max(1);
        let mut plan = BatchPlan::new();
        let mut roles: Vec<SpanRole> = Vec::new();
        let mut slabs: Vec<usize> = Vec::new();
        for (pi, pf) in self.prefilling.iter().enumerate().take(budget) {
            let remaining = pf.req.prompt.len() - pf.consumed;
            let chunk = if self.cfg.prefill_chunk == 0 {
                remaining
            } else {
                self.cfg.prefill_chunk.min(remaining)
            };
            let end = pf.consumed + chunk;
            let logits = if end == pf.req.prompt.len() {
                SpanLogits::Last
            } else {
                SpanLogits::None
            };
            plan.push_span(roles.len(), &pf.req.prompt[pf.consumed..end],
                           logits);
            roles.push(SpanRole::Prefill { pf: pi, end });
            slabs.push(pf.slab);
        }
        let prefill_rows = plan.rows();
        for (idx, a) in self.active.iter_mut().enumerate() {
            if a.done {
                continue;
            }
            if a.tokens.len() >= a.req.params.max_new {
                // Defensive: budget reached without the done flag —
                // finalize it rather than skipping it forever.
                a.done = true;
                continue;
            }
            plan.push_span(roles.len(), &[a.next], SpanLogits::Last);
            roles.push(SpanRole::Decode { idx });
            slabs.push(a.slab);
        }
        if roles.is_empty() {
            return;
        }
        // Roles and plan spans must stay 1:1 — logits routing and error
        // attribution index one by the other. Guaranteed because every
        // span here is non-empty (admission rejects empty prompts, so a
        // prefilling entry always has ≥ 1 remaining token).
        debug_assert_eq!(plan.spans().len(), roles.len());
        let mut caches = self.pool.get_many_mut(&slabs);
        let result = self.engine.forward_batch(&plan, &mut caches,
                                               &mut self.ws);
        drop(caches);
        match result {
            Ok(()) => {
                let prefill_spans = roles
                    .iter()
                    .filter(|r| matches!(r, SpanRole::Prefill { .. }))
                    .count();
                let decode_spans = roles.len() - prefill_spans;
                self.metrics.prefill_calls += prefill_spans as u64;
                self.metrics.record_forward(plan.rows(), prefill_rows,
                                            decode_spans, roles.len(),
                                            self.cfg.max_batch);
                if decode_spans > 0 {
                    self.metrics.record_decode_iter(decode_spans);
                }
                self.consume_outputs(&plan, &roles);
            }
            Err(e) => self.attribute_error(&roles, &e),
        }
    }

    /// Route the ragged batch's logits rows: promote completed prefills
    /// into the active set (first token, FIFO — the TTFT point) and
    /// sample one token per decode lane.
    fn consume_outputs(&mut self, plan: &BatchPlan, roles: &[SpanRole]) {
        // Prefill progress first; collect completions in FIFO order.
        let mut completed: Vec<(usize, usize)> = Vec::new(); // (span, pf)
        for (si, role) in roles.iter().enumerate() {
            if let SpanRole::Prefill { pf, end } = role {
                self.prefilling[*pf].consumed = *end;
                if *end == self.prefilling[*pf].req.prompt.len() {
                    completed.push((si, *pf));
                }
            }
        }
        let mut removed = 0usize;
        for (si, pi) in completed {
            let pf = self.prefilling.remove(pi - removed);
            removed += 1;
            let row = plan.logits_rows(si).start;
            self.activate(pf.req, pf.slab, row);
        }
        // Decode lanes: one sampled token each. (Activation only pushed
        // to the end of `active`, so the captured indices stay valid.)
        let vocab = self.engine.config().vocab;
        for (si, role) in roles.iter().enumerate() {
            let SpanRole::Decode { idx } = role else { continue };
            let i = *idx;
            let r = plan.logits_rows(si).start;
            let row = &self.ws.logits[r * vocab..(r + 1) * vocab];
            let a = &mut self.active[i];
            // Counter step = number of tokens sampled so far, so the
            // stream is a pure function of (seed, step) — identical for
            // every thread count and batch composition.
            let tok = a.sampler.sample(row, a.tokens.len() as u64);
            a.tokens.push(tok);
            a.next = tok;
            self.events.push(Event::Token {
                id: a.req.id,
                index: a.tokens.len() - 1,
                token: tok,
            });
            let cache_full = {
                let c = self.pool.get_mut(a.slab);
                c.len + 1 >= c.cap
            };
            let a = &mut self.active[i];
            if a.req.params.stop_tokens.contains(&tok) {
                a.done = true;
                a.finish = FinishReason::Stop;
            } else if a.tokens.len() >= a.req.params.max_new {
                a.done = true;
                a.finish = FinishReason::Length;
            } else if cache_full {
                a.done = true;
                a.finish = FinishReason::CacheFull;
            }
        }
    }

    /// A typed engine error validated before any state mutation: nothing
    /// advanced. Terminate only the offending span's request when the
    /// error names one; otherwise fail every participant rather than
    /// livelock on a persistent error. Untouched lanes retry next
    /// iteration.
    fn attribute_error(&mut self, roles: &[SpanRole], e: &EngineError) {
        match e {
            EngineError::KvOverflow { lane, .. } => match roles[*lane] {
                SpanRole::Decode { idx } => {
                    let a = &mut self.active[idx];
                    a.error = Some(e.to_string());
                    a.finish = FinishReason::Error;
                    a.done = true;
                    self.metrics.failed += 1;
                }
                SpanRole::Prefill { pf, .. } => {
                    let p = self.prefilling.remove(pf);
                    self.fail_request(p.req, p.slab, e);
                }
            },
            _ => {
                // No span attribution — fail the whole batch. Prefill
                // roles carry ascending indices; walk them back-to-front
                // so removal keeps the remaining indices valid.
                for role in roles.iter().rev() {
                    match *role {
                        SpanRole::Prefill { pf, .. } => {
                            let p = self.prefilling.remove(pf);
                            self.fail_request(p.req, p.slab, e);
                        }
                        SpanRole::Decode { idx } => {
                            let a = &mut self.active[idx];
                            a.error = Some(e.to_string());
                            a.finish = FinishReason::Error;
                            a.done = true;
                            self.metrics.failed += 1;
                        }
                    }
                }
            }
        }
    }

    /// Promote a fully-prefilled request into the active set: sample its
    /// first token (counter step 0 — the TTFT point) from logits row
    /// `first_logits_row` of the just-run batch and emit the first
    /// `Token` frame.
    fn activate(&mut self, req: Request, slab: usize, first_logits_row: usize) {
        let vocab = self.engine.config().vocab;
        let row = &self.ws.logits
            [first_logits_row * vocab..(first_logits_row + 1) * vocab];
        let sampler = req.params.sampler();
        let first = sampler.sample(row, 0);
        let ttft = req.submitted.elapsed();
        self.events.push(Event::Token { id: req.id, index: 0, token: first });
        // Same termination rules (and priority) as the decode step, so a
        // prompt that exactly fills its slab ends gracefully with
        // `CacheFull` instead of tripping a KvOverflow next iteration.
        let cache_full = {
            let c = self.pool.get_mut(slab);
            c.len + 1 >= c.cap
        };
        let (done, finish) = if req.params.stop_tokens.contains(&first) {
            (true, FinishReason::Stop)
        } else if req.params.max_new <= 1 {
            (true, FinishReason::Length)
        } else if cache_full {
            (true, FinishReason::CacheFull)
        } else {
            (false, FinishReason::Length)
        };
        self.active.push(Active {
            req,
            slab,
            tokens: vec![first],
            next: first,
            ttft,
            sampler,
            done,
            finish,
            error: None,
        });
    }

    fn finalize(&mut self) {
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].done {
                let a = self.active.swap_remove(i);
                self.pool.dealloc(a.slab);
                let latency = a.req.submitted.elapsed();
                // Failed/cancelled sequences count only in their own
                // counters (set at the marking site) — completion counts
                // and latency percentiles describe normal successes only.
                if a.error.is_none() && a.finish != FinishReason::Cancelled {
                    self.metrics.record_completion(latency, a.ttft,
                                                   a.req.prompt.len(),
                                                   a.tokens.len());
                }
                let response = Response {
                    id: a.req.id,
                    tokens: a.tokens,
                    ttft: a.ttft,
                    latency,
                    prompt_len: a.req.prompt.len(),
                    finish: if a.error.is_some() {
                        FinishReason::Error
                    } else {
                        a.finish
                    },
                    error: a.error,
                };
                self.events.push(if response.error.is_some() {
                    Event::Error { response }
                } else {
                    Event::Done { response }
                });
            } else {
                i += 1;
            }
        }
    }

    /// Run until all submitted work completes; returns the terminal
    /// response of every request (token frames are dropped — use
    /// [`Scheduler::take_events`] for the full stream).
    pub fn run_to_completion(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        let start = Instant::now();
        while self.has_work() {
            self.step();
            for ev in self.take_events() {
                match ev {
                    Event::Done { response } | Event::Error { response } => {
                        out.push(response)
                    }
                    Event::Token { .. } => {}
                }
            }
            assert!(start.elapsed() < Duration::from_secs(600),
                    "scheduler livelock");
        }
        out
    }
}
