//! Weighted-fair pending queues (DESIGN.md §15).
//!
//! The scheduler's pending set is one FIFO queue *per priority class*
//! with deterministic **stride scheduling** between the non-empty
//! classes: class `c` has weight `c + 1` and a virtual `pass` counter
//! advanced by `STRIDE_SCALE / weight` per admission, so over time class
//! `c` receives `(c + 1)` admissions for every one a class-0 request
//! gets — weighted fairness without starvation (every class's pass keeps
//! growing, so every class keeps winning selections). Selection is pure
//! integer arithmetic over queue state: no clocks, no randomness — the
//! admission order is a deterministic function of the submission/requeue
//! sequence, which is what lets the preempt/resume replay suite pin
//! token streams bitwise.
//!
//! A single-class workload (all requests priority 0 — the pre-§15
//! default) collapses to exactly the old `VecDeque` FIFO: one queue,
//! selected every time, popped front-first.
//!
//! Entries carry an optional [`ResumeState`]: a preempted decode lane
//! re-enters here at the *front* of its class queue (it already earned
//! its admission — `push_front` refunds the stride charge) together
//! with everything needed to resume its stream byte-identically.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::time::Duration;

use super::request::Request;

/// Stride numerator: `pass += STRIDE_SCALE / (class + 1)` per admission.
/// Large enough that integer division keeps class weights well separated
/// for the full `u8` class range.
const STRIDE_SCALE: u64 = 1 << 20;

/// Generation state of a preempted decode lane, carried through the
/// pending queue so re-admission can resume the stream bitwise
/// (DESIGN.md §15): the KV for `work` is recomputed (or re-attached from
/// the prefix cache — the lane's own prompt is a warm hit), the last
/// generated token becomes the resume input, and sampling continues at
/// counter step `tokens.len()` — the pure `(seed, step)` sampler makes
/// the continuation identical to the uninterrupted run.
#[derive(Debug)]
pub(crate) struct ResumeState {
    /// Tokens generated (and already streamed) before preemption;
    /// never re-emitted.
    pub tokens: Vec<u32>,
    /// `prompt ++ tokens[..len-1]` — the sequence whose KV must be in
    /// cache before decoding continues (the final generated token is
    /// the next forward input, its KV not yet written).
    pub work: Vec<u32>,
    /// TTFT of the original activation (the first token already
    /// reached the client; preemption must not re-time it).
    pub ttft: Duration,
}

/// One queued request: fresh (`resume: None`) or preempted-and-requeued.
#[derive(Debug)]
pub(crate) struct PendingEntry {
    pub req: Request,
    pub resume: Option<ResumeState>,
}

impl PendingEntry {
    pub fn fresh(req: Request) -> Self {
        PendingEntry { req, resume: None }
    }

    /// The token sequence admission must prefill for this entry (the
    /// prompt, or the preempted lane's recompute work).
    pub fn work(&self) -> &[u32] {
        match &self.resume {
            Some(r) => &r.work,
            None => &self.req.prompt,
        }
    }
}

struct ClassQueue {
    q: VecDeque<PendingEntry>,
    /// Stride-scheduling virtual time of this class; the non-empty
    /// class with the smallest pass is admitted next.
    pass: u64,
}

/// Per-class FIFO queues with stride-scheduled selection.
#[derive(Default)]
pub(crate) struct PendingQueues {
    classes: BTreeMap<u8, ClassQueue>,
    len: usize,
    /// Global virtual time: the pass of the last admission. A class
    /// going from empty to non-empty joins at `max(own pass, vtime)` so
    /// an idle class cannot bank arbitrarily old credit and then
    /// monopolize admission.
    vtime: u64,
}

impl PendingQueues {
    fn stride(class: u8) -> u64 {
        STRIDE_SCALE / (class as u64 + 1)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Class whose front entry is admitted next: smallest pass among
    /// non-empty classes, ties to the *higher* class. Deterministic.
    fn pick(&self) -> Option<u8> {
        let mut best: Option<(u64, u8)> = None;
        for (&c, cq) in &self.classes {
            if cq.q.is_empty() {
                continue;
            }
            match best {
                Some((bp, _)) if cq.pass > bp => {}
                // `>=` on class: ascending iteration means equal pass
                // keeps the later (higher) class.
                _ => best = Some((cq.pass, c)),
            }
        }
        best.map(|(_, c)| c)
    }

    fn class_mut(&mut self, class: u8) -> &mut ClassQueue {
        let vtime = self.vtime;
        let cq = self.classes.entry(class).or_insert(ClassQueue {
            q: VecDeque::new(),
            pass: vtime,
        });
        if cq.q.is_empty() {
            cq.pass = cq.pass.max(vtime);
        }
        cq
    }

    /// Enqueue a fresh submission at the back of its class queue.
    pub fn push_back(&mut self, entry: PendingEntry) {
        let class = entry.req.params.priority;
        self.class_mut(class).q.push_back(entry);
        self.len += 1;
    }

    /// Requeue at the *front* of the class queue (preempted lanes,
    /// stalled prefills): the entry already paid its admission, so the
    /// stride charge is refunded — the class retries at its pre-pop
    /// pass and a requeue never costs the class future throughput.
    pub fn push_front(&mut self, entry: PendingEntry) {
        let class = entry.req.params.priority;
        let cq = self.class_mut(class);
        cq.pass = cq.pass.saturating_sub(Self::stride(class));
        cq.q.push_front(entry);
        self.len += 1;
    }

    /// Front entry of the stride-selected class (what `pop` would
    /// return), without charging the admission.
    pub fn peek(&self) -> Option<&PendingEntry> {
        let c = self.pick()?;
        self.classes[&c].q.front()
    }

    /// Admit the stride-selected front entry, advancing the winning
    /// class's pass by its stride.
    pub fn pop(&mut self) -> Option<PendingEntry> {
        let c = self.pick()?;
        let cq = self.classes.get_mut(&c).unwrap();
        let entry = cq.q.pop_front().unwrap();
        self.vtime = cq.pass;
        cq.pass += Self::stride(c);
        self.len -= 1;
        Some(entry)
    }

    /// Remove the entry with request id `id` (cancellation), wherever
    /// it is queued. No pass accounting: a cancelled admission was
    /// never granted.
    pub fn take(&mut self, id: u64) -> Option<PendingEntry> {
        for cq in self.classes.values_mut() {
            if let Some(pos) = cq.q.iter().position(|e| e.req.id == id) {
                self.len -= 1;
                return cq.q.remove(pos);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::super::request::GenerationParams;
    use super::*;

    fn req(id: u64, class: u8) -> PendingEntry {
        let params = GenerationParams {
            priority: class,
            ..GenerationParams::greedy(4)
        };
        PendingEntry::fresh(Request::with_params(id, vec![1, 2, 3], params))
    }

    fn drain_ids(q: &mut PendingQueues) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push(e.req.id);
        }
        assert!(q.is_empty());
        out
    }

    #[test]
    fn single_class_is_plain_fifo() {
        let mut q = PendingQueues::default();
        for id in 0..6 {
            q.push_back(req(id, 0));
        }
        assert_eq!(drain_ids(&mut q), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn weighted_fair_ratio_between_classes() {
        // Saturated class 0 (weight 1) vs class 1 (weight 2): class 1
        // receives two admissions per class-0 admission.
        let mut q = PendingQueues::default();
        for id in 0..4 {
            q.push_back(req(id, 0));
        }
        for id in 10..18 {
            q.push_back(req(id, 1));
        }
        let order = drain_ids(&mut q);
        // Both classes start at pass 0 (tie → class 1), then the
        // strides settle into the 2:1 steady state — and class 0 is
        // never starved.
        assert_eq!(order,
                   vec![10, 0, 11, 12, 1, 13, 14, 2, 15, 16, 3, 17]);
    }

    #[test]
    fn ties_prefer_higher_class_and_fifo_within_class() {
        let mut q = PendingQueues::default();
        q.push_back(req(1, 0));
        q.push_back(req(2, 3));
        q.push_back(req(3, 3));
        // Equal pass (both fresh at vtime 0): class 3 wins the tie and
        // its entries drain FIFO (2 strictly before 3); the class-0
        // entry interleaves per stride, unstarved.
        assert_eq!(q.pop().unwrap().req.id, 2);
        assert_eq!(q.pop().unwrap().req.id, 1);
        assert_eq!(q.pop().unwrap().req.id, 3);
    }

    #[test]
    fn push_front_refunds_the_stride_charge() {
        let mut q = PendingQueues::default();
        q.push_back(req(1, 0));
        q.push_back(req(2, 0));
        q.push_back(req(9, 2));
        let e = q.pop().unwrap(); // class 2 wins the tie
        assert_eq!(e.req.id, 9);
        // Requeue (e.g. preempted): the refund restores its pass, so it
        // wins the very next selection instead of waiting a full round.
        q.push_front(e);
        assert_eq!(q.pop().unwrap().req.id, 9);
        assert_eq!(q.pop().unwrap().req.id, 1);
        assert_eq!(q.pop().unwrap().req.id, 2);
    }

    #[test]
    fn idle_class_joins_at_current_vtime() {
        let mut q = PendingQueues::default();
        for id in 0..8 {
            q.push_back(req(id, 1));
        }
        for _ in 0..6 {
            q.pop();
        }
        // A class-0 straggler arriving late joins at the current vtime
        // (one stride behind the running class — the standard stride
        // arrival rule), so it gets exactly one prompt admission and
        // then interleaves; it cannot bank ancient credit and
        // monopolize the queue.
        q.push_back(req(100, 0));
        assert_eq!(q.pop().unwrap().req.id, 100);
        assert_eq!(q.pop().unwrap().req.id, 6);
        assert_eq!(q.pop().unwrap().req.id, 7);
    }

    #[test]
    fn take_removes_by_id_across_classes() {
        let mut q = PendingQueues::default();
        q.push_back(req(1, 0));
        q.push_back(req(2, 1));
        q.push_back(req(3, 0));
        assert_eq!(q.take(2).unwrap().req.id, 2);
        assert!(q.take(2).is_none());
        assert_eq!(q.len(), 2);
        assert_eq!(drain_ids(&mut q), vec![1, 3]);
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = PendingQueues::default();
        q.push_back(req(1, 0));
        q.push_back(req(2, 2));
        for _ in 0..2 {
            let peeked = q.peek().unwrap().req.id;
            assert_eq!(q.pop().unwrap().req.id, peeked);
        }
    }
}
