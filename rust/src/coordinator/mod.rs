//! The serving coordinator: request router, continuous batcher,
//! prefill/decode scheduler, KV-cache pool, metrics, and a TCP gateway.
//!
//! Architecture (vLLM-router-like, scaled to one box):
//!
//! ```text
//!  clients ──TCP/ndjson──► gateway ──mpsc──► scheduler (owns Engine)
//!                                               │  admit  → blocks for the first
//!                                               │           chunk from BlockPool
//!                                               │  step   → ONE forward_batch
//!                                               │           (prefill spans +
//!                                               │            decode lanes, ragged)
//!                                               │  cancel → blocks back next iteration
//!                                               ▼
//!                                  event streams (one per request:
//!                                  Token… then Done/Error)
//! ```
//!
//! The scheduler runs iteration-level (continuous) batching: every loop
//! it applies cancellations, admits pending requests (bounded by free KV
//! **blocks** — paged, block-granular allocation, DESIGN.md §13 — and
//! `max_batch`), then stacks up to `max_prefills_per_iter`
//! prefill spans — several chunked prefills may be in flight
//! concurrently — and every active decode lane into **one ragged
//! [`crate::engine::BatchPlan`]** executed by a single
//! `Engine::forward_batch` call (DESIGN.md §12). Requests carry
//! [`GenerationParams`] (temperature/top-k/top-p, per-request seed, stop
//! tokens, token budget) and report progress as per-token [`Event`]
//! frames — the generation API v2 contract (DESIGN.md §11). The
//! replica-sharded front door ([`router`], DESIGN.md §16) stacks N of
//! these servers behind one gateway with least-loaded dispatch,
//! session affinity, and graceful drain/respawn. Invariants
//! (property-tested): every request gets exactly one terminal event, the
//! active set never exceeds `max_batch`, KV blocks are never
//! double-handed-out or leaked (cancellation included), FIFO admission
//! order, one engine call per iteration.

pub mod kv_pool;
pub mod metrics;
pub(crate) mod pending;
pub mod prefix_cache;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod server;

pub use kv_pool::BlockPool;
pub use metrics::{Metrics, ReplicaStats, RouterMetrics};
pub use prefix_cache::PrefixCache;
pub use request::{
    Event, FinishReason, GenerationParams, Request, Response, SubmitError,
};
pub use router::{Router, RouterConfig, RouterGateway};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::{RequestHandle, Server};
