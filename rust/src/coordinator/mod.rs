//! The serving coordinator: request router, continuous batcher,
//! prefill/decode scheduler, KV-cache pool, metrics, and a TCP gateway.
//!
//! Architecture (vLLM-router-like, scaled to one box):
//!
//! ```text
//!  clients ──TCP/json──► gateway ──mpsc──► scheduler (owns Engine)
//!                                             │  admit → prefill (slab from KvPool)
//!                                             │  step  → decode_batch over active set
//!                                             ▼
//!                                       responses (mpsc per request)
//! ```
//!
//! The scheduler runs iteration-level (continuous) batching: every loop it
//! admits up to `max_prefills_per_iter` pending requests (bounded by free
//! KV slabs and `max_batch`), then advances *all* active sequences one
//! decode step in a single batched engine call. Invariants (property-
//! tested): every request is answered exactly once, the active set never
//! exceeds `max_batch`, KV slabs are never double-allocated, FIFO
//! admission order.

pub mod kv_pool;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use kv_pool::KvPool;
pub use metrics::Metrics;
pub use request::{Request, Response};
pub use scheduler::{Scheduler, SchedulerConfig};
pub use server::Server;
