//! Shared KV block arena ([`BlockPool`]) — block-granular allocation
//! (DESIGN.md §13).
//!
//! The pool pre-allocates `total_blocks` fixed-size [`KvBlock`]s
//! (`block_tokens` tokens × all layers, dtype-parametric f32/int8 exactly
//! like the old slabs) and moves them in and out of per-sequence
//! [`KvCache`] block tables: [`BlockPool::reserve`] grows a cache to
//! cover a span's new tokens, [`BlockPool::release`] reclaims every
//! block of a finished/cancelled sequence. Running out of *blocks* — not
//! slabs — is the scheduler's backpressure signal, so admission capacity
//! is proportional to the tokens actually in flight rather than to
//! `max_seq` reservations.
//!
//! Ownership replaces the old raw-pointer `get_many_mut`: free blocks
//! are plain owned storage; a block leaves the free list wrapped in an
//! `Arc` so sequences sharing a frozen prefix (and the prefix cache's
//! radix index) can hold the same physical block. Writes demand unique
//! ownership — the scheduler copies-on-write the one shareable-and-
//! writable block, the partially-filled boundary, via
//! [`BlockPool::reserve_writable`] before every engine call. A block
//! returns to the free list only when its *last* handle is released
//! ([`std::sync::Arc::try_unwrap`] in [`BlockPool::release`] /
//! [`BlockPool::reclaim`]). Invariants enforced here and
//! property-tested in `tests/coordinator_props.rs`:
//!   * `free + allocated == total` at all times, in blocks and tokens,
//!     where `allocated` counts distinct *physical* blocks off the free
//!     list however many tables share them;
//!   * releasing a sequence twice panics (the double-free contract);
//!   * reserve is all-or-nothing: a failed reservation hands out no
//!     blocks;
//!   * alloc/free churn never leaks (counters balance the allocation):
//!     `blocks_alloc` counts free-list departures, `blocks_freed`
//!     free-list returns — attaching a shared handle touches neither.

use std::sync::Arc;

use crate::engine::{KvBlock, KvCache, KvDtype};

pub struct BlockPool {
    free: Vec<KvBlock>,
    total_blocks: usize,
    block_tokens: usize,
    n_layers: usize,
    d: usize,
    dtype: KvDtype,
    max_seq: usize,
    per_block_bytes: usize,
    blocks_alloc: u64,
    blocks_freed: u64,
}

impl BlockPool {
    /// Arena of f32 blocks (seed-compatible default).
    pub fn new(total_blocks: usize, block_tokens: usize, n_layers: usize,
               max_seq: usize, d: usize) -> Self {
        Self::with_dtype(KvDtype::F32, total_blocks, block_tokens, n_layers,
                         max_seq, d)
    }

    /// Arena with an explicit block storage dtype — `Int8` blocks are 4×
    /// smaller, which compounds with paging into the Table-3 serving
    /// capacity story. The arena must cover at least one full `max_seq`
    /// sequence, or nothing could ever finish a worst-case prompt.
    pub fn with_dtype(dtype: KvDtype, total_blocks: usize,
                      block_tokens: usize, n_layers: usize, max_seq: usize,
                      d: usize) -> Self {
        let block_tokens = block_tokens.clamp(1, max_seq.max(1));
        assert!(total_blocks * block_tokens >= max_seq,
                "KV arena ({total_blocks} blocks × {block_tokens} tokens) \
                 smaller than one max_seq ({max_seq}) sequence");
        let free: Vec<KvBlock> = (0..total_blocks)
            .map(|_| KvBlock::new(dtype, n_layers, block_tokens, d))
            .collect();
        let per_block_bytes = free.first().map_or(0, KvBlock::bytes);
        BlockPool {
            free,
            total_blocks,
            block_tokens,
            n_layers,
            d,
            dtype,
            max_seq,
            per_block_bytes,
            blocks_alloc: 0,
            blocks_freed: 0,
        }
    }

    /// Total blocks in the arena.
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by sequences.
    pub fn allocated_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Tokens per block (B).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Token capacity of the free list.
    pub fn free_tokens(&self) -> usize {
        self.free.len() * self.block_tokens
    }

    /// Token capacity of the blocks held by sequences — the denominator
    /// of the `kv_util` metric.
    pub fn allocated_tokens(&self) -> usize {
        self.allocated_blocks() * self.block_tokens
    }

    /// Cumulative blocks handed to sequences (metrics: alloc churn).
    pub fn blocks_alloc(&self) -> u64 {
        self.blocks_alloc
    }

    /// Cumulative blocks reclaimed from sequences.
    pub fn blocks_freed(&self) -> u64 {
        self.blocks_freed
    }

    /// `true` when the free list can grow a table by `ceil(tokens/B)`
    /// blocks — the admission gate ("enough blocks for the first prefill
    /// chunk"), optionally leaving `headroom_blocks` untouched for this
    /// iteration's committed decode lanes.
    pub fn can_cover(&self, tokens: usize, headroom_blocks: usize) -> bool {
        tokens.div_ceil(self.block_tokens)
            <= self.free.len().saturating_sub(headroom_blocks)
    }

    /// A fresh empty pooled sequence cache (`cap == max_seq`, zero
    /// blocks): every block it will ever hold comes from
    /// [`BlockPool::reserve`].
    pub fn new_sequence(&self) -> KvCache {
        KvCache::pooled(self.dtype, self.n_layers, self.max_seq, self.d,
                        self.block_tokens)
    }

    /// Grow `cache` until it can hold `total_tokens` tokens. All-or-
    /// nothing: `Err(missing_blocks)` hands out nothing. A no-op when
    /// the cache already covers the request (reserving an admitted
    /// chunk's tokens twice is free).
    pub fn reserve(&mut self, cache: &mut KvCache, total_tokens: usize)
                   -> Result<(), usize> {
        debug_assert_eq!(cache.block_tokens(), self.block_tokens,
                         "cache from a different pool");
        let need = total_tokens
            .div_ceil(self.block_tokens)
            .saturating_sub(cache.n_blocks());
        if need > self.free.len() {
            return Err(need - self.free.len());
        }
        for _ in 0..need {
            cache.push_block(Arc::new(self.free.pop().unwrap()));
        }
        self.blocks_alloc += need as u64;
        Ok(())
    }

    /// Blocks `cache` would pull off the free list to *write* up to
    /// `total_tokens`: table growth plus one fresh block when the next
    /// write would land in a shared boundary block (copy-on-write). The
    /// admission gate charges a prefix-sharing request only this — the
    /// unshared blocks it actually needs.
    pub fn blocks_needed(&self, cache: &KvCache, total_tokens: usize)
                         -> usize {
        let growth = total_tokens
            .div_ceil(self.block_tokens)
            .saturating_sub(cache.n_blocks());
        let cow = usize::from(total_tokens > cache.len
                              && cache.boundary_shared());
        growth + cow
    }

    /// [`BlockPool::reserve`] plus copy-on-write: after this succeeds,
    /// every position in `[cache.len, total_tokens)` is backed by a
    /// uniquely-owned block, so the engine may write. All-or-nothing
    /// like `reserve`.
    pub fn reserve_writable(&mut self, cache: &mut KvCache,
                            total_tokens: usize) -> Result<(), usize> {
        let need = self.blocks_needed(cache, total_tokens);
        if need > self.free.len() {
            return Err(need - self.free.len());
        }
        if total_tokens > cache.len && cache.boundary_shared() {
            cache.cow_boundary(Arc::new(self.free.pop().unwrap()));
        }
        let growth = total_tokens
            .div_ceil(self.block_tokens)
            .saturating_sub(cache.n_blocks());
        for _ in 0..growth {
            cache.push_block(Arc::new(self.free.pop().unwrap()));
        }
        self.blocks_alloc += need as u64;
        Ok(())
    }

    /// Reclaim every block of a finished/cancelled sequence. Panics if
    /// the sequence was already released (double-free contract) or never
    /// came from a pool. Blocks still shared with other sequences or the
    /// prefix cache stay allocated; each returns to the free list when
    /// its last handle is reclaimed.
    pub fn release(&mut self, cache: &mut KvCache) {
        for block in cache.take_blocks() {
            self.reclaim(block);
        }
    }

    /// Drop one handle to a pool block (prefix-cache eviction, CoW
    /// leftovers): if it was the last handle, the block physically
    /// returns to the free list and counts as freed.
    pub fn reclaim(&mut self, block: Arc<KvBlock>) {
        if let Ok(b) = Arc::try_unwrap(block) {
            self.blocks_freed += 1;
            self.free.push(b);
        }
    }

    /// Resident bytes of one block (sharing-savings accounting).
    pub fn block_bytes(&self) -> usize {
        self.per_block_bytes
    }

    /// Resident bytes of the whole arena (free + held blocks; Table 3).
    pub fn total_bytes(&self) -> usize {
        self.total_blocks * self.per_block_bytes
    }

    /// Storage dtype of the arena's blocks.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        // 8 blocks × 4 tokens, max_seq 16, 2 layers, d 8
        BlockPool::new(8, 4, 2, 16, 8)
    }

    #[test]
    fn reserve_until_empty_then_err() {
        let mut p = pool();
        let mut caches: Vec<KvCache> =
            (0..2).map(|_| p.new_sequence()).collect();
        for c in caches.iter_mut() {
            p.reserve(c, 16).unwrap(); // 4 blocks each
        }
        assert_eq!(p.free_blocks(), 0);
        let mut extra = p.new_sequence();
        assert_eq!(p.reserve(&mut extra, 4), Err(1));
        assert_eq!(extra.n_blocks(), 0, "failed reserve must hand out 0");
        for c in caches.iter_mut() {
            p.release(c);
        }
        assert_eq!(p.free_blocks(), p.total_blocks());
    }

    #[test]
    fn released_sequence_is_reset_and_blocks_reusable() {
        let mut p = pool();
        let mut c = p.new_sequence();
        p.reserve(&mut c, 7).unwrap(); // 2 blocks
        c.len = 7;
        p.release(&mut c);
        assert_eq!(c.len, 0, "release resets the sequence length");
        assert_eq!(p.free_blocks(), 8);
        let mut c2 = p.new_sequence();
        p.reserve(&mut c2, 16).unwrap();
        assert_eq!(c2.len, 0);
        assert_eq!(c2.held_tokens(), 16);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = pool();
        let mut c = p.new_sequence();
        p.reserve(&mut c, 4).unwrap();
        p.release(&mut c);
        p.release(&mut c);
    }

    #[test]
    fn reserve_is_idempotent_for_covered_tokens() {
        let mut p = pool();
        let mut c = p.new_sequence();
        p.reserve(&mut c, 5).unwrap(); // 2 blocks
        assert_eq!(c.n_blocks(), 2);
        p.reserve(&mut c, 5).unwrap();
        p.reserve(&mut c, 8).unwrap(); // still 2 blocks
        assert_eq!(c.n_blocks(), 2);
        assert_eq!(p.blocks_alloc(), 2);
    }

    #[test]
    fn accounting_stays_exact() {
        let mut p = pool();
        let mut a = p.new_sequence();
        let mut b = p.new_sequence();
        p.reserve(&mut a, 9).unwrap(); // 3 blocks
        p.reserve(&mut b, 4).unwrap(); // 1 block
        assert_eq!(p.allocated_blocks() + p.free_blocks(), p.total_blocks());
        assert_eq!(p.allocated_tokens(), 16);
        assert_eq!(p.blocks_alloc() - p.blocks_freed(),
                   p.allocated_blocks() as u64);
        p.release(&mut a);
        assert_eq!(p.blocks_alloc() - p.blocks_freed(),
                   p.allocated_blocks() as u64);
        p.release(&mut b);
        assert_eq!(p.free_blocks(), p.total_blocks());
        assert_eq!(p.blocks_alloc(), p.blocks_freed());
    }

    #[test]
    #[should_panic(expected = "smaller than one max_seq")]
    fn arena_must_cover_one_sequence() {
        let _ = BlockPool::new(2, 4, 2, 16, 8);
    }

    #[test]
    fn int8_arena_is_4x_smaller() {
        let f = BlockPool::with_dtype(KvDtype::F32, 4, 16, 2, 16, 8);
        let q = BlockPool::with_dtype(KvDtype::Int8, 4, 16, 2, 16, 8);
        assert_eq!(q.dtype(), KvDtype::Int8);
        assert_eq!(f.total_bytes(), 4 * q.total_bytes());
    }

    #[test]
    fn can_cover_respects_headroom() {
        let p = pool(); // 8 free blocks
        assert!(p.can_cover(32, 0));
        assert!(!p.can_cover(33, 0));
        assert!(p.can_cover(24, 2));
        assert!(!p.can_cover(28, 2));
    }

    #[test]
    fn shared_blocks_return_to_free_only_on_last_release() {
        let mut p = pool(); // 8 blocks × 4 tokens
        let mut a = p.new_sequence();
        p.reserve(&mut a, 8).unwrap(); // 2 blocks
        a.len = 8;
        // b borrows a's two frozen blocks: no free-list traffic.
        let mut b = p.new_sequence();
        b.push_block(a.block_arc(0));
        b.push_block(a.block_arc(1));
        b.len = 8;
        assert_eq!(p.free_blocks(), 6);
        assert_eq!(p.blocks_alloc(), 2);
        assert_eq!(a.shared_blocks(), 2);
        p.release(&mut a);
        assert_eq!(p.free_blocks(), 6, "b still references both blocks");
        assert_eq!(p.blocks_freed(), 0);
        p.release(&mut b);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.blocks_alloc(), p.blocks_freed());
    }

    #[test]
    fn reserve_writable_charges_and_performs_boundary_cow() {
        let mut p = pool(); // 8 blocks × 4 tokens
        let mut a = p.new_sequence();
        p.reserve(&mut a, 6).unwrap(); // 2 blocks
        a.len = 6; // boundary block 1 holds rows 4..6
        let mut b = p.new_sequence();
        b.push_block(a.block_arc(0)); // full frozen block: shared, fine
        b.push_block(a.block_arc(1)); // partial boundary: needs CoW
        b.len = 6;
        // next write (pos 6) lands in the shared boundary → 1 CoW
        // block; growing to 9 tokens additionally needs 1 new block.
        assert_eq!(p.blocks_needed(&b, 7), 1);
        assert_eq!(p.blocks_needed(&b, 9), 2);
        assert_eq!(p.blocks_needed(&b, 6), 0, "no write, no CoW");
        p.reserve_writable(&mut b, 9).unwrap();
        assert!(!b.boundary_shared());
        assert_eq!(b.shared_blocks(), 1, "full block 0 stays shared");
        assert_eq!(p.free_blocks(), 4);
        assert_eq!(p.blocks_alloc(), 4);
        // all-or-nothing when the free list can't cover CoW + growth:
        // c shares a's full block 0 as a *partial* boundary (2 of its 4
        // rows matched), so writing needs 1 CoW + 3 growth blocks.
        let mut d = p.new_sequence();
        p.reserve(&mut d, 4).unwrap(); // free: 4 → 3
        let mut c = p.new_sequence();
        c.push_block(a.block_arc(0));
        c.len = 2;
        assert!(c.boundary_shared());
        assert_eq!(p.blocks_needed(&c, 16), 4);
        assert_eq!(p.reserve_writable(&mut c, 16), Err(1));
        assert_eq!(c.n_blocks(), 1, "failed reserve hands out nothing");
        assert!(c.boundary_shared(), "failed reserve leaves CoW undone");
    }

    #[test]
    fn reclaim_frees_only_last_handle() {
        let mut p = pool();
        let mut a = p.new_sequence();
        p.reserve(&mut a, 4).unwrap();
        let extra = a.block_arc(0);
        p.release(&mut a);
        assert_eq!(p.free_blocks(), 7, "extra handle keeps it allocated");
        p.reclaim(extra);
        assert_eq!(p.free_blocks(), 8);
        assert_eq!(p.blocks_alloc(), p.blocks_freed());
    }
}
