//! Fixed-slab KV-cache pool.
//!
//! Pre-allocates `capacity` KV slabs (each `max_seq` tokens) and hands out
//! ids. Running out of slabs is the backpressure signal the scheduler uses
//! to stop admitting. Invariants enforced here and property-tested in
//! `tests/coordinator_props.rs`:
//!   * a slab id is never handed out twice without an intervening free;
//!   * freeing an unallocated id is an error;
//!   * freed slabs are reset (len == 0) before reuse.

use crate::engine::{KvCache, KvDtype};

pub struct KvPool {
    slabs: Vec<KvCache>,
    free: Vec<usize>,
    allocated: Vec<bool>,
}

impl KvPool {
    /// Pool of f32 slabs (seed-compatible default).
    pub fn new(capacity: usize, n_layers: usize, max_seq: usize, d: usize)
               -> Self {
        Self::with_dtype(KvDtype::F32, capacity, n_layers, max_seq, d)
    }

    /// Pool with an explicit slab storage dtype — `Int8` slabs are 4×
    /// smaller, which is the whole Table-3 scaling story for resident KV.
    pub fn with_dtype(dtype: KvDtype, capacity: usize, n_layers: usize,
                      max_seq: usize, d: usize) -> Self {
        let slabs = (0..capacity)
            .map(|_| KvCache::with_dtype(dtype, n_layers, max_seq, d))
            .collect();
        KvPool {
            slabs,
            free: (0..capacity).rev().collect(),
            allocated: vec![false; capacity],
        }
    }

    pub fn capacity(&self) -> usize {
        self.slabs.len()
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        debug_assert!(!self.allocated[id]);
        self.allocated[id] = true;
        self.slabs[id].reset();
        Some(id)
    }

    pub fn dealloc(&mut self, id: usize) {
        assert!(self.allocated[id], "double free of KV slab {id}");
        self.allocated[id] = false;
        self.free.push(id);
    }

    pub fn get_mut(&mut self, id: usize) -> &mut KvCache {
        assert!(self.allocated[id], "access to unallocated slab {id}");
        &mut self.slabs[id]
    }

    /// Mutable access to several distinct slabs at once (batched decode).
    pub fn get_many_mut(&mut self, ids: &[usize]) -> Vec<&mut KvCache> {
        // verify distinctness
        for (a, &ia) in ids.iter().enumerate() {
            assert!(self.allocated[ia], "slab {ia} not allocated");
            for &ib in &ids[a + 1..] {
                assert_ne!(ia, ib, "duplicate slab id in batch");
            }
        }
        // split via raw pointers, safe because ids are distinct
        let base = self.slabs.as_mut_ptr();
        ids.iter()
            .map(|&i| unsafe { &mut *base.add(i) })
            .collect()
    }

    pub fn total_bytes(&self) -> usize {
        self.slabs.iter().map(|s| s.bytes()).sum()
    }

    /// Storage dtype of the slabs (uniform across the pool).
    pub fn dtype(&self) -> KvDtype {
        self.slabs.first().map_or(KvDtype::F32, |s| s.dtype())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> KvPool {
        KvPool::new(4, 2, 16, 8)
    }

    #[test]
    fn alloc_until_empty() {
        let mut p = pool();
        let ids: Vec<_> = (0..4).map(|_| p.alloc().unwrap()).collect();
        assert_eq!(p.available(), 0);
        assert!(p.alloc().is_none());
        let mut sorted = ids.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 4, "ids must be unique");
    }

    #[test]
    fn freed_slab_is_reset() {
        let mut p = pool();
        let id = p.alloc().unwrap();
        p.get_mut(id).len = 7;
        p.dealloc(id);
        let id2 = p.alloc().unwrap();
        assert_eq!(p.get_mut(id2).len, 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = pool();
        let id = p.alloc().unwrap();
        p.dealloc(id);
        p.dealloc(id);
    }

    #[test]
    #[should_panic(expected = "duplicate slab id")]
    fn duplicate_batch_ids_panic() {
        let mut p = pool();
        let id = p.alloc().unwrap();
        let _ = p.get_many_mut(&[id, id]);
    }

    #[test]
    fn get_many_mut_distinct() {
        let mut p = pool();
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let caches = p.get_many_mut(&[a, b]);
        assert_eq!(caches.len(), 2);
    }

    #[test]
    fn int8_slabs_are_4x_smaller() {
        let f = KvPool::with_dtype(KvDtype::F32, 4, 2, 16, 8);
        let q = KvPool::with_dtype(KvDtype::Int8, 4, 2, 16, 8);
        assert_eq!(q.dtype(), KvDtype::Int8);
        assert_eq!(f.total_bytes(), 4 * q.total_bytes());
    }
}
