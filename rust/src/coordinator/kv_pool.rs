//! Shared KV block arena ([`BlockPool`]) — block-granular allocation
//! (DESIGN.md §13).
//!
//! The pool pre-allocates `total_blocks` fixed-size [`KvBlock`]s
//! (`block_tokens` tokens × all layers, dtype-parametric f32/int8 exactly
//! like the old slabs) and moves them in and out of per-sequence
//! [`KvCache`] block tables: [`BlockPool::reserve`] grows a cache to
//! cover a span's new tokens, [`BlockPool::release`] reclaims every
//! block of a finished/cancelled sequence. Running out of *blocks* — not
//! slabs — is the scheduler's backpressure signal, so admission capacity
//! is proportional to the tokens actually in flight rather than to
//! `max_seq` reservations.
//!
//! Ownership replaces the old raw-pointer `get_many_mut`: blocks are
//! plain owned storage that physically moves between the pool's free
//! list and the sequences' block tables, so disjoint multi-sequence
//! mutable access needs no `unsafe` anywhere. Invariants enforced here
//! and property-tested in `tests/coordinator_props.rs`:
//!   * a block is never held by two sequences (moves, not aliases);
//!   * `free + allocated == total` at all times, in blocks and tokens;
//!   * releasing a sequence twice panics (the double-free contract);
//!   * reserve is all-or-nothing: a failed reservation hands out no
//!     blocks;
//!   * alloc/free churn never leaks (counters balance the allocation).

use crate::engine::{KvBlock, KvCache, KvDtype};

pub struct BlockPool {
    free: Vec<KvBlock>,
    total_blocks: usize,
    block_tokens: usize,
    n_layers: usize,
    d: usize,
    dtype: KvDtype,
    max_seq: usize,
    per_block_bytes: usize,
    blocks_alloc: u64,
    blocks_freed: u64,
}

impl BlockPool {
    /// Arena of f32 blocks (seed-compatible default).
    pub fn new(total_blocks: usize, block_tokens: usize, n_layers: usize,
               max_seq: usize, d: usize) -> Self {
        Self::with_dtype(KvDtype::F32, total_blocks, block_tokens, n_layers,
                         max_seq, d)
    }

    /// Arena with an explicit block storage dtype — `Int8` blocks are 4×
    /// smaller, which compounds with paging into the Table-3 serving
    /// capacity story. The arena must cover at least one full `max_seq`
    /// sequence, or nothing could ever finish a worst-case prompt.
    pub fn with_dtype(dtype: KvDtype, total_blocks: usize,
                      block_tokens: usize, n_layers: usize, max_seq: usize,
                      d: usize) -> Self {
        let block_tokens = block_tokens.clamp(1, max_seq.max(1));
        assert!(total_blocks * block_tokens >= max_seq,
                "KV arena ({total_blocks} blocks × {block_tokens} tokens) \
                 smaller than one max_seq ({max_seq}) sequence");
        let free: Vec<KvBlock> = (0..total_blocks)
            .map(|_| KvBlock::new(dtype, n_layers, block_tokens, d))
            .collect();
        let per_block_bytes = free.first().map_or(0, KvBlock::bytes);
        BlockPool {
            free,
            total_blocks,
            block_tokens,
            n_layers,
            d,
            dtype,
            max_seq,
            per_block_bytes,
            blocks_alloc: 0,
            blocks_freed: 0,
        }
    }

    /// Total blocks in the arena.
    pub fn total_blocks(&self) -> usize {
        self.total_blocks
    }

    /// Blocks currently on the free list.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by sequences.
    pub fn allocated_blocks(&self) -> usize {
        self.total_blocks - self.free.len()
    }

    /// Tokens per block (B).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Token capacity of the free list.
    pub fn free_tokens(&self) -> usize {
        self.free.len() * self.block_tokens
    }

    /// Token capacity of the blocks held by sequences — the denominator
    /// of the `kv_util` metric.
    pub fn allocated_tokens(&self) -> usize {
        self.allocated_blocks() * self.block_tokens
    }

    /// Cumulative blocks handed to sequences (metrics: alloc churn).
    pub fn blocks_alloc(&self) -> u64 {
        self.blocks_alloc
    }

    /// Cumulative blocks reclaimed from sequences.
    pub fn blocks_freed(&self) -> u64 {
        self.blocks_freed
    }

    /// `true` when the free list can grow a table by `ceil(tokens/B)`
    /// blocks — the admission gate ("enough blocks for the first prefill
    /// chunk"), optionally leaving `headroom_blocks` untouched for this
    /// iteration's committed decode lanes.
    pub fn can_cover(&self, tokens: usize, headroom_blocks: usize) -> bool {
        tokens.div_ceil(self.block_tokens)
            <= self.free.len().saturating_sub(headroom_blocks)
    }

    /// A fresh empty pooled sequence cache (`cap == max_seq`, zero
    /// blocks): every block it will ever hold comes from
    /// [`BlockPool::reserve`].
    pub fn new_sequence(&self) -> KvCache {
        KvCache::pooled(self.dtype, self.n_layers, self.max_seq, self.d,
                        self.block_tokens)
    }

    /// Grow `cache` until it can hold `total_tokens` tokens. All-or-
    /// nothing: `Err(missing_blocks)` hands out nothing. A no-op when
    /// the cache already covers the request (reserving an admitted
    /// chunk's tokens twice is free).
    pub fn reserve(&mut self, cache: &mut KvCache, total_tokens: usize)
                   -> Result<(), usize> {
        debug_assert_eq!(cache.block_tokens(), self.block_tokens,
                         "cache from a different pool");
        let need = total_tokens
            .div_ceil(self.block_tokens)
            .saturating_sub(cache.n_blocks());
        if need > self.free.len() {
            return Err(need - self.free.len());
        }
        for _ in 0..need {
            cache.push_block(self.free.pop().unwrap());
        }
        self.blocks_alloc += need as u64;
        Ok(())
    }

    /// Reclaim every block of a finished/cancelled sequence. Panics if
    /// the sequence was already released (double-free contract) or never
    /// came from a pool.
    pub fn release(&mut self, cache: &mut KvCache) {
        let blocks = cache.take_blocks();
        self.blocks_freed += blocks.len() as u64;
        self.free.extend(blocks);
    }

    /// Resident bytes of the whole arena (free + held blocks; Table 3).
    pub fn total_bytes(&self) -> usize {
        self.total_blocks * self.per_block_bytes
    }

    /// Storage dtype of the arena's blocks.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> BlockPool {
        // 8 blocks × 4 tokens, max_seq 16, 2 layers, d 8
        BlockPool::new(8, 4, 2, 16, 8)
    }

    #[test]
    fn reserve_until_empty_then_err() {
        let mut p = pool();
        let mut caches: Vec<KvCache> =
            (0..2).map(|_| p.new_sequence()).collect();
        for c in caches.iter_mut() {
            p.reserve(c, 16).unwrap(); // 4 blocks each
        }
        assert_eq!(p.free_blocks(), 0);
        let mut extra = p.new_sequence();
        assert_eq!(p.reserve(&mut extra, 4), Err(1));
        assert_eq!(extra.n_blocks(), 0, "failed reserve must hand out 0");
        for c in caches.iter_mut() {
            p.release(c);
        }
        assert_eq!(p.free_blocks(), p.total_blocks());
    }

    #[test]
    fn released_sequence_is_reset_and_blocks_reusable() {
        let mut p = pool();
        let mut c = p.new_sequence();
        p.reserve(&mut c, 7).unwrap(); // 2 blocks
        c.len = 7;
        p.release(&mut c);
        assert_eq!(c.len, 0, "release resets the sequence length");
        assert_eq!(p.free_blocks(), 8);
        let mut c2 = p.new_sequence();
        p.reserve(&mut c2, 16).unwrap();
        assert_eq!(c2.len, 0);
        assert_eq!(c2.held_tokens(), 16);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = pool();
        let mut c = p.new_sequence();
        p.reserve(&mut c, 4).unwrap();
        p.release(&mut c);
        p.release(&mut c);
    }

    #[test]
    fn reserve_is_idempotent_for_covered_tokens() {
        let mut p = pool();
        let mut c = p.new_sequence();
        p.reserve(&mut c, 5).unwrap(); // 2 blocks
        assert_eq!(c.n_blocks(), 2);
        p.reserve(&mut c, 5).unwrap();
        p.reserve(&mut c, 8).unwrap(); // still 2 blocks
        assert_eq!(c.n_blocks(), 2);
        assert_eq!(p.blocks_alloc(), 2);
    }

    #[test]
    fn accounting_stays_exact() {
        let mut p = pool();
        let mut a = p.new_sequence();
        let mut b = p.new_sequence();
        p.reserve(&mut a, 9).unwrap(); // 3 blocks
        p.reserve(&mut b, 4).unwrap(); // 1 block
        assert_eq!(p.allocated_blocks() + p.free_blocks(), p.total_blocks());
        assert_eq!(p.allocated_tokens(), 16);
        assert_eq!(p.blocks_alloc() - p.blocks_freed(),
                   p.allocated_blocks() as u64);
        p.release(&mut a);
        assert_eq!(p.blocks_alloc() - p.blocks_freed(),
                   p.allocated_blocks() as u64);
        p.release(&mut b);
        assert_eq!(p.free_blocks(), p.total_blocks());
        assert_eq!(p.blocks_alloc(), p.blocks_freed());
    }

    #[test]
    #[should_panic(expected = "smaller than one max_seq")]
    fn arena_must_cover_one_sequence() {
        let _ = BlockPool::new(2, 4, 2, 16, 8);
    }

    #[test]
    fn int8_arena_is_4x_smaller() {
        let f = BlockPool::with_dtype(KvDtype::F32, 4, 16, 2, 16, 8);
        let q = BlockPool::with_dtype(KvDtype::Int8, 4, 16, 2, 16, 8);
        assert_eq!(q.dtype(), KvDtype::Int8);
        assert_eq!(f.total_bytes(), 4 * q.total_bytes());
    }

    #[test]
    fn can_cover_respects_headroom() {
        let p = pool(); // 8 free blocks
        assert!(p.can_cover(32, 0));
        assert!(!p.can_cover(33, 0));
        assert!(p.can_cover(24, 2));
        assert!(!p.can_cover(28, 2));
    }
}
