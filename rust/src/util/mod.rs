//! Small self-contained substrates: PRNG, JSON, statistics, property
//! testing. (The vendored registry has no rand / serde / criterion /
//! proptest — DESIGN.md §2 substitution table.)

pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
