//! Tiny property-testing harness (proptest is not vendored).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs and asserts
//! the property on each; on failure it performs a bounded greedy shrink
//! using the input's `Shrink` implementation and reports the smallest
//! failing case. Used by the coordinator-invariant tests.

use super::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for i8 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            Vec::new()
        } else {
            vec![0, self / 2]
        }
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink one element
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs; panics with the (shrunken)
/// counterexample on failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut generate: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (smallest, smsg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}, seed {seed}): {smsg}\n\
                 counterexample: {smallest:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Result<(), String>>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    for _ in 0..200 {
        let mut advanced = false;
        for cand in cur.shrink() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(1, 200, |r| r.usize(0, 100), |x| {
            if *x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(2, 200, |r| r.usize(0, 100), |x| {
            if *x < 50 {
                Ok(())
            } else {
                Err(format!("{x} >= 50"))
            }
        });
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property "len < 5" fails; shrinking should land near len 5.
        let gen = |r: &mut Rng| (0..r.usize(5, 40)).collect::<Vec<usize>>();
        let prop = |v: &Vec<usize>| {
            if v.len() < 5 {
                Ok(())
            } else {
                Err("too long".into())
            }
        };
        let mut rng = Rng::new(3);
        let bad = gen(&mut rng);
        let (small, _) = shrink_loop(bad, "seed".into(), &prop);
        assert!(small.len() >= 5 && small.len() <= 6);
    }
}
