//! SplitMix64/xoshiro256** PRNG — deterministic, seedable, dependency-free.

/// xoshiro256** seeded via SplitMix64. Good statistical quality for
/// workload generation and property tests; not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// SplitMix64 finalizer: a bijective avalanche mix. Shared by the
/// sequential generator below and by counter-based stream keying (the
/// sampler derives one independent RNG stream per `(seed, step)` from
/// it — `engine::Sampler`).
pub fn mix64(z: u64) -> u64 {
    let z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    let z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    mix64(*state)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Exponential with the given rate (for Poisson arrivals).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * scale;
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(0, i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..64).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }
}
