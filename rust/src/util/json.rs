//! Minimal JSON parser + serializer (serde is not vendored).
//!
//! Supports the full JSON grammar minus exotic escapes beyond \uXXXX
//! (BMP only). Numbers parse to f64; integer accessors round-trip exactly
//! for |n| < 2^53 — ample for `.qmod` metadata and task files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Required-field helpers with contextual error messages.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?.as_str().ok_or_else(|| format!("{key:?} not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| format!("{key:?} not a number"))
    }

    // ---- serialization ---------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.i;
                    while self
                        .peek()
                        .is_some_and(|c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let j = Json::parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e2}"#)
            .unwrap();
        assert_eq!(j.req_usize("a").unwrap(), 1);
        assert_eq!(j.get("c").unwrap().as_f64().unwrap(), -250.0);
        assert_eq!(j.get("b").unwrap().idx(2).unwrap().as_str().unwrap(), "x\n");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2,{"k":"v"}],"n":-3.25,"s":"a\"b","t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn big_int_roundtrip() {
        let j = Json::parse("1234567890123").unwrap();
        assert_eq!(j.as_i64().unwrap(), 1234567890123);
        assert_eq!(j.to_string(), "1234567890123");
    }
}
