//! Summary statistics + timing helpers for the bench harness.

use std::time::{Duration, Instant};

#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(1) as f64;
    let q = |p: f64| v[(p * (n - 1) as f64).round() as usize];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: v[0],
        p50: q(0.5),
        p90: q(0.9),
        p95: q(0.95),
        p99: q(0.99),
        max: v[n - 1],
    }
}

/// Run `f` repeatedly: `warmup` unmeasured runs then `iters` measured,
/// returning per-iteration seconds.
pub fn time_iters<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// Adaptive timing: run until `min_time` has elapsed or `max_iters`
/// reached (at least 3 iterations). Returns per-iteration seconds.
pub fn time_adaptive<F: FnMut()>(min_time: Duration, max_iters: usize,
                                 mut f: F) -> Vec<f64> {
    f(); // warmup
    let mut out = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < min_time || out.len() < 3)
        && out.len() < max_iters
    {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        // p95 rounds to the last rank on a 5-sample vector.
        assert_eq!(s.p95, 5.0);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(summarize(&[]).n, 0);
    }

    #[test]
    fn timing_runs() {
        let mut count = 0;
        let ts = time_iters(2, 5, || count += 1);
        assert_eq!(ts.len(), 5);
        assert_eq!(count, 7);
        assert!(ts.iter().all(|t| *t >= 0.0));
    }
}
