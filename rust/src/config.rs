//! Launcher configuration: resolve model bundles + scheduler settings from
//! CLI flags and/or a JSON config file — the deployment-facing config
//! system (DESIGN.md deliverable (a)).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::SchedulerConfig;
use crate::engine::KvDtype;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    pub method: String,
    pub scheduler: SchedulerConfig,
    pub port: u16,
    /// Engine replicas behind the router tier (`mergequant route`,
    /// DESIGN.md §16). `serve` ignores it; `route` splits the KV arena
    /// evenly across this many replicas.
    pub replicas: usize,
    /// Forced integer-microkernel variant
    /// (`scalar|avx2|vnni|neon`, DESIGN.md §17). `None` = auto
    /// dispatch (or the `MQ_KERNEL` env override). Kept as the raw
    /// spelling; the launcher validates and applies it.
    pub kernel: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "tiny-llama-s".into(),
            method: "mergequant".into(),
            scheduler: SchedulerConfig::default(),
            port: 0,
            replicas: 1,
            kernel: None,
        }
    }
}

/// One-line deprecation note for the pre-paging `kv_slabs` arena
/// sizing (PR 5 back-compat alias) — printed **once per process**
/// however many parse sites (config key, CLI flag) see the alias.
/// Returns whether this call emitted the warning (false = already
/// warned), so the behaviour is unit-testable.
pub fn warn_kv_slabs_deprecated(source: &str) -> bool {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    if WARNED.swap(true, Ordering::Relaxed) {
        return false;
    }
    eprintln!("warning: kv_slabs ({source}) is deprecated — size the \
               arena with kv_blocks (same bytes: kv_slabs × \
               ⌈max_seq/kv_block⌉ blocks)");
    true
}

/// The single resolver for the deprecated `kv_slabs` alias — every
/// parse site (config JSON, `--kv-slabs`) funnels through here so the
/// deprecation note is emitted exactly once and the apply-vs-fallback
/// logic cannot drift between sites. `Some(v)` applies `v` (and
/// warns); `None` keeps `fallback`.
pub fn resolve_kv_slabs(raw: Option<usize>, source: &str,
                        fallback: usize) -> usize {
    match raw {
        Some(v) => {
            warn_kv_slabs_deprecated(source);
            v
        }
        None => fallback,
    }
}

impl ServeConfig {
    /// Load from a JSON file, falling back to defaults per-field.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(anyhow::Error::msg)?;
        Ok(Self::from_json(&j))
    }

    pub fn from_json(j: &Json) -> Self {
        let mut cfg = ServeConfig::default();
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            cfg.model = m.into();
        }
        if let Some(m) = j.get("method").and_then(Json::as_str) {
            cfg.method = m.into();
        }
        if let Some(p) = j.get("port").and_then(Json::as_usize) {
            cfg.port = p as u16;
        }
        if let Some(r) = j.get("replicas").and_then(Json::as_usize) {
            cfg.replicas = r.max(1);
        }
        if let Some(k) = j.get("kernel").and_then(Json::as_str) {
            cfg.kernel = Some(k.into());
        }
        if let Some(s) = j.get("scheduler") {
            let d = SchedulerConfig::default();
            cfg.scheduler = SchedulerConfig {
                max_batch: s.get("max_batch").and_then(Json::as_usize)
                    .unwrap_or(d.max_batch),
                kv_slabs: resolve_kv_slabs(
                    s.get("kv_slabs").and_then(Json::as_usize),
                    "config scheduler.kv_slabs", d.kv_slabs),
                // Paged KV (DESIGN.md §13): block granularity + arena
                // size. `kv_slabs` stays as the back-compat arena sizing
                // (kv_blocks == 0 ⇒ kv_slabs × ⌈max_seq/kv_block⌉
                // blocks, the same bytes the slab pool pre-allocated).
                kv_block: s.get("kv_block").and_then(Json::as_usize)
                    .unwrap_or(d.kv_block),
                kv_blocks: s.get("kv_blocks").and_then(Json::as_usize)
                    .unwrap_or(d.kv_blocks),
                max_seq: s.get("max_seq").and_then(Json::as_usize)
                    .unwrap_or(d.max_seq),
                max_prefills_per_iter: s.get("max_prefills_per_iter")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.max_prefills_per_iter),
                queue_cap: s.get("queue_cap").and_then(Json::as_usize)
                    .unwrap_or(d.queue_cap),
                prefill_chunk: s.get("prefill_chunk")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.prefill_chunk),
                threads: s.get("threads").and_then(Json::as_usize)
                    .unwrap_or(d.threads),
                kv_dtype: match s.get("kv_cache").and_then(Json::as_str) {
                    Some(v) => KvDtype::parse(v).unwrap_or_else(|| {
                        // Mirror the CLI's loud rejection as far as a
                        // non-failing parse can: never drop the setting
                        // silently.
                        eprintln!("warning: scheduler.kv_cache {v:?} is \
                                   not one of f32|int8 — using {}",
                                  d.kv_dtype.as_str());
                        d.kv_dtype
                    }),
                    None => d.kv_dtype,
                },
                // Prefix sharing (DESIGN.md §14): radix index over
                // frozen KV blocks + CoW boundary blocks.
                prefix_cache: s.get("prefix_cache").and_then(Json::as_bool)
                    .unwrap_or(d.prefix_cache),
                prefix_cache_blocks: s.get("prefix_cache_blocks")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.prefix_cache_blocks),
                // SLO gate (DESIGN.md §15): decode-latency target in
                // ms; 0 keeps it off.
                max_decode_latency: s.get("max_decode_latency")
                    .and_then(Json::as_usize)
                    .map(|v| v as u64)
                    .unwrap_or(d.max_decode_latency),
                // Self-speculative decoding (DESIGN.md §18): draft
                // lane on/off, proposal length, and draft depth.
                speculative: s.get("speculative")
                    .and_then(Json::as_bool)
                    .unwrap_or(d.speculative),
                draft_k: s.get("draft_k").and_then(Json::as_usize)
                    .unwrap_or(d.draft_k),
                draft_layers: s.get("draft_layers")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.draft_layers),
            };
        }
        cfg
    }

    /// Path of the configured `.qmod` bundle.
    pub fn bundle_path(&self) -> PathBuf {
        crate::artifacts_dir()
            .join("models")
            .join(&self.model)
            .join(format!("{}.qmod", self.method))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_json_overrides() {
        let j = Json::parse(
            r#"{"model":"tiny-llama-m","method":"rtn",
                "scheduler":{"max_batch":4,"max_seq":256,"threads":6,
                             "kv_cache":"int8","kv_block":16,
                             "kv_blocks":64},
                "port":9999}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j);
        assert_eq!(c.model, "tiny-llama-m");
        assert_eq!(c.method, "rtn");
        assert_eq!(c.scheduler.max_batch, 4);
        assert_eq!(c.scheduler.max_seq, 256);
        assert_eq!(c.scheduler.threads, 6);
        assert_eq!(c.scheduler.kv_dtype, KvDtype::Int8);
        assert_eq!(c.scheduler.kv_block, 16);
        assert_eq!(c.scheduler.kv_blocks, 64);
        assert_eq!(c.scheduler.block_tokens(), 16);
        assert_eq!(c.scheduler.total_blocks(), 64);
        assert_eq!(c.scheduler.queue_cap,
                   SchedulerConfig::default().queue_cap);
        assert_eq!(c.port, 9999);
        assert_eq!(c.replicas, 1, "replicas defaults to standalone");
    }

    #[test]
    fn replicas_parse_and_clamp() {
        let c = ServeConfig::from_json(
            &Json::parse(r#"{"replicas":4}"#).unwrap());
        assert_eq!(c.replicas, 4);
        // 0 replicas is meaningless — clamp to a standalone fleet.
        let z = ServeConfig::from_json(
            &Json::parse(r#"{"replicas":0}"#).unwrap());
        assert_eq!(z.replicas, 1);
    }

    #[test]
    fn kv_slabs_backcompat_sizes_the_block_arena() {
        // No kv_blocks ⇒ the arena holds the same KV bytes the old slab
        // pool pre-allocated: kv_slabs × ⌈max_seq/kv_block⌉ blocks.
        let c = ServeConfig::from_json(&Json::parse(
            r#"{"scheduler":{"kv_slabs":4,"max_seq":96,"kv_block":32}}"#,
        ).unwrap());
        assert_eq!(c.scheduler.block_tokens(), 32);
        assert_eq!(c.scheduler.total_blocks(), 4 * 3);
        // kv_block 0 ⇒ one block per max_seq sequence (slab behaviour).
        let s = ServeConfig::from_json(&Json::parse(
            r#"{"scheduler":{"kv_slabs":4,"max_seq":96,"kv_block":0}}"#,
        ).unwrap());
        assert_eq!(s.scheduler.block_tokens(), 96);
        assert_eq!(s.scheduler.total_blocks(), 4);
    }

    #[test]
    fn prefix_cache_knobs_parse_and_default_off() {
        let c = ServeConfig::from_json(&Json::parse(
            r#"{"scheduler":{"prefix_cache":true,
                             "prefix_cache_blocks":128}}"#,
        ).unwrap());
        assert!(c.scheduler.prefix_cache);
        assert_eq!(c.scheduler.prefix_cache_blocks, 128);
        assert_eq!(c.scheduler.max_decode_latency, 0,
                   "SLO gate defaults off");
        let slo = ServeConfig::from_json(&Json::parse(
            r#"{"scheduler":{"max_decode_latency":25}}"#,
        ).unwrap());
        assert_eq!(slo.scheduler.max_decode_latency, 25);
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap());
        assert!(!d.scheduler.prefix_cache,
                "prefix cache must be opt-in");
        assert_eq!(d.scheduler.prefix_cache_blocks, 0);
    }

    #[test]
    fn kv_slabs_alias_resolves_and_warns_at_most_once() {
        // The resolver applies the alias value over the fallback …
        assert_eq!(resolve_kv_slabs(Some(7), "test", 3), 7);
        assert_eq!(resolve_kv_slabs(None, "test", 3), 3);
        // … and however many sites warn, only the first emission in
        // the process actually prints. (Another test may already have
        // consumed the first slot — only the *second* consecutive call
        // is deterministic.)
        warn_kv_slabs_deprecated("first site");
        assert!(!warn_kv_slabs_deprecated("second site"),
                "deprecation note must be once-per-process");
    }

    #[test]
    fn speculative_knobs_parse_and_default_off() {
        let c = ServeConfig::from_json(&Json::parse(
            r#"{"scheduler":{"speculative":true,"draft_k":4,
                             "draft_layers":1}}"#,
        ).unwrap());
        assert!(c.scheduler.speculative);
        assert_eq!(c.scheduler.draft_k, 4);
        assert_eq!(c.scheduler.draft_layers, 1);
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap());
        assert!(!d.scheduler.speculative,
                "speculative decoding must be opt-in");
        assert_eq!(d.scheduler.draft_k, 0);
        assert_eq!(d.scheduler.draft_layers, 0);
    }

    #[test]
    fn kernel_key_parses_and_defaults_off() {
        let c = ServeConfig::from_json(
            &Json::parse(r#"{"kernel":"scalar"}"#).unwrap());
        assert_eq!(c.kernel.as_deref(), Some("scalar"));
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap());
        assert!(d.kernel.is_none(), "kernel override must be opt-in");
    }

    #[test]
    fn kv_cache_defaults_to_f32_and_rejects_garbage() {
        let c = ServeConfig::from_json(
            &Json::parse(r#"{"scheduler":{"kv_cache":"mystery"}}"#).unwrap());
        assert_eq!(c.scheduler.kv_dtype, KvDtype::F32);
        let d = ServeConfig::from_json(&Json::parse("{}").unwrap());
        assert_eq!(d.scheduler.kv_dtype, KvDtype::F32);
    }

    #[test]
    fn bundle_path_shape() {
        let c = ServeConfig::default();
        let p = c.bundle_path();
        assert!(p.ends_with("models/tiny-llama-s/mergequant.qmod"));
    }
}
