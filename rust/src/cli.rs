//! Minimal CLI argument parser (clap substitute).
//!
//! Supports `program <subcommand> --flag value --bool-flag positional…`.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let next_is_value =
                    it.peek().is_some_and(|n| !n.starts_with("--"));
                if next_is_value {
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(name.to_string(), "true".into());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("serve --model tiny-llama-s --port 9000 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("model"), Some("tiny-llama-s"));
        assert_eq!(a.get_usize("port", 0), 9000);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn positional() {
        let a = parse("eval model.qmod --seq 128");
        assert_eq!(a.positional, vec!["model.qmod"]);
        assert_eq!(a.get_usize("seq", 0), 128);
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_or("model", "default"), "default");
        assert_eq!(a.get_usize("port", 8080), 8080);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn numeric_flags() {
        let a = parse("generate --temperature 0.8 --seed 123456789012345");
        assert!((a.get_f32("temperature", 0.0) - 0.8).abs() < 1e-6);
        assert_eq!(a.get_u64("seed", 0), 123_456_789_012_345);
        assert_eq!(a.get_f32("top-p", 1.0), 1.0);
    }
}
