//! GEMM kernels. Weight layout is **transposed**: `wt` is (j, n) row-major
//! so every output column reads one contiguous weight row — the right
//! layout for both GEMV decode and j-tiled prefill GEMM, and the CPU
//! analogue of the K-major tiling an INT4 tensor-core kernel wants.
//!
//! The integer kernels accumulate i32 and finish with the per-output-column
//! rescale epilogue of paper Eq. (5): after Quantization Step Migration the
//! per-channel static path needs *only* this epilogue, which is why it
//! aligns with integer acceleration kernels at all.

use super::pack::unpack_int4_into;

/// Minimum row count at which the packed-int4 weight format wins: below
/// this (decode GEMV) the per-row nibble unpack would double the work per
/// weight element, so the i8 mirror is used instead. Shared policy between
/// the serial engine path and [`super::parallel::par_qlinear`].
pub const PACKED_MIN_ROWS: usize = 8;

/// y (m, j) = x (m, n) @ wt^T, f32 reference path (the FP16 baseline cost).
pub fn gemm_f32(x: &[f32], wt: &[f32], m: usize, n: usize, j: usize,
                out: &mut [f32]) {
    assert_eq!(x.len(), m * n);
    assert_eq!(wt.len(), j * n);
    assert_eq!(out.len(), m * j);
    for i in 0..m {
        let xr = &x[i * n..(i + 1) * n];
        let or = &mut out[i * j..(i + 1) * j];
        for (c, o) in or.iter_mut().enumerate() {
            let wr = &wt[c * n..(c + 1) * n];
            *o = dot_f32(xr, wr);
        }
    }
}

/// f32 dot product — the shared inner loop of [`gemm_f32`] and the
/// attention score/value kernels.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    // 4 independent accumulators — breaks the dependency chain so LLVM
    // vectorizes and pipelines the loop.
    let mut acc = [0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Exact i8·i8 → i32 dot product — the shared inner loop of every
/// integer GEMM kernel (serial and parallel). Dispatches through the
/// process-wide [`super::simd`] table; every variant is bit-identical
/// to [`dot_i8_scalar`] (integer sums are associative and exact), so
/// tiled execution stays bitwise deterministic for any kernel choice.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    super::simd::active().dot(a, b)
}

/// Portable scalar reference for [`dot_i8`] — the pinned oracle every
/// SIMD variant must match bit for bit (`tests/simd_kernels.rs`).
#[inline]
pub fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    // i16 products (i8·i8 always fits) accumulated in i32: LLVM lowers
    // this reduction to vpmaddwd/vpdpwssd under AVX-512BW even without
    // the hand-written variants.
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc += (x as i16 * y as i16) as i32;
    }
    acc
}

/// Integer GEMM, unpacked i8 weights: acc (m, j) i32.
pub fn gemm_i8(xq: &[i8], wt: &[i8], m: usize, n: usize, j: usize,
               acc: &mut [i32]) {
    assert_eq!(xq.len(), m * n);
    assert_eq!(wt.len(), j * n);
    assert_eq!(acc.len(), m * j);
    let kern = super::simd::active();
    for i in 0..m {
        let xr = &xq[i * n..(i + 1) * n];
        let ar = &mut acc[i * j..(i + 1) * j];
        for (c, o) in ar.iter_mut().enumerate() {
            *o = kern.dot(xr, &wt[c * n..(c + 1) * n]);
        }
    }
}

/// Integer GEMM over **packed int4** weights (j, n/2 bytes per row).
///
/// Unpacks one weight row at a time into a scratch buffer: the row is then
/// reused across all m activation rows, so the unpack cost amortizes and
/// HBM→cache traffic is halved vs i8 (the bandwidth win static INT4 buys).
pub fn gemm_i8_packed4(xq: &[i8], wpacked: &[u8], m: usize, n: usize,
                       j: usize, scratch: &mut Vec<i8>, acc: &mut [i32]) {
    assert_eq!(xq.len(), m * n);
    let row_bytes = n.div_ceil(2);
    assert_eq!(wpacked.len(), j * row_bytes);
    assert_eq!(acc.len(), m * j);
    scratch.resize(n, 0);
    let kern = super::simd::active();
    for c in 0..j {
        unpack_int4_into(&wpacked[c * row_bytes..(c + 1) * row_bytes],
                         scratch);
        for i in 0..m {
            acc[i * j + c] = kern.dot(&xq[i * n..(i + 1) * n], scratch);
        }
    }
}

/// Epilogue for symmetric per-column scales (group = whole column):
/// y = acc · colscale, with an optional per-row factor (dynamic path).
pub fn epilogue_sym(acc: &[i32], col_scale: &[f32], row_scale: Option<&[f32]>,
                    m: usize, j: usize, out: &mut [f32]) {
    assert_eq!(acc.len(), m * j);
    assert_eq!(col_scale.len(), j);
    for i in 0..m {
        let rs = row_scale.map_or(1.0, |r| r[i]);
        let ar = &acc[i * j..(i + 1) * j];
        let or = &mut out[i * j..(i + 1) * j];
        for c in 0..j {
            or[c] = ar[c] as f32 * col_scale[c] * rs;
        }
    }
}

/// Asymmetric epilogue: y = (acc − rowsum·zero_j) · colscale · rowscale.
/// `xq_rowsum` is Σ_k xq_ik (one pass, stays in cache).
pub fn epilogue_asym(acc: &[i32], xq_rowsum: &[i32], zero: &[i32],
                     col_scale: &[f32], row_scale: Option<&[f32]>, m: usize,
                     j: usize, out: &mut [f32]) {
    for i in 0..m {
        let rs = row_scale.map_or(1.0, |r| r[i]);
        let rsum = xq_rowsum[i];
        for c in 0..j {
            out[i * j + c] = (acc[i * j + c] - rsum * zero[c]) as f32
                * col_scale[c]
                * rs;
        }
    }
}

/// Per-row sums Σ_k xq\[i,k\] (one cache-resident pass) — feeds the
/// asymmetric epilogue's zero-point correction.
pub fn rowsum_i8(xq: &[i8], m: usize, n: usize, out: &mut Vec<i32>) {
    out.clear();
    for i in 0..m {
        out.push(xq[i * n..(i + 1) * n].iter().map(|&v| v as i32).sum());
    }
}

/// Grouped integer GEMM + epilogue in one (general path; Table 5 W3-group).
/// scale/zero are (G, j) row-major; group divides n.
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8_grouped(xq: &[i8], wt: &[i8], m: usize, n: usize, j: usize,
                       group: usize, scale: &[f32], zero: Option<&[i32]>,
                       row_scale: Option<&[f32]>, out: &mut [f32]) {
    let g = if group == 0 { n } else { group };
    let ngroups = n / g;
    assert_eq!(scale.len(), ngroups * j);
    let kern = super::simd::active();
    for i in 0..m {
        let rs = row_scale.map_or(1.0, |r| r[i]);
        for c in 0..j {
            let wr = &wt[c * n..(c + 1) * n];
            let xr = &xq[i * n..(i + 1) * n];
            let mut y = 0f32;
            for gi in 0..ngroups {
                let lo = gi * g;
                let acc = kern.dot(&xr[lo..lo + g], &wr[lo..lo + g]);
                let corr = match zero {
                    Some(z) => {
                        let rsum: i32 =
                            xr[lo..lo + g].iter().map(|&v| v as i32).sum();
                        acc - rsum * z[gi * j + c]
                    }
                    None => acc,
                };
                y += corr as f32 * scale[gi * j + c];
            }
            out[i * j + c] = y * rs;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pack::pack_int4;
    use crate::util::rng::Rng;

    fn naive_f32(x: &[f32], wt: &[f32], m: usize, n: usize, j: usize)
                 -> Vec<f32> {
        let mut out = vec![0f32; m * j];
        for i in 0..m {
            for c in 0..j {
                let mut s = 0f64;
                for k in 0..n {
                    s += x[i * n + k] as f64 * wt[c * n + k] as f64;
                }
                out[i * j + c] = s as f32;
            }
        }
        out
    }

    #[test]
    fn gemm_f32_matches_naive() {
        let mut rng = Rng::new(1);
        let (m, n, j) = (7, 65, 33);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..j * n).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; m * j];
        gemm_f32(&x, &wt, m, n, j, &mut out);
        let want = naive_f32(&x, &wt, m, n, j);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_i8_exact() {
        let mut rng = Rng::new(2);
        let (m, n, j) = (5, 48, 17);
        let xq: Vec<i8> = (0..m * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let wt: Vec<i8> = (0..j * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let mut acc = vec![0i32; m * j];
        gemm_i8(&xq, &wt, m, n, j, &mut acc);
        for i in 0..m {
            for c in 0..j {
                let want: i32 = (0..n)
                    .map(|k| xq[i * n + k] as i32 * wt[c * n + k] as i32)
                    .sum();
                assert_eq!(acc[i * j + c], want);
            }
        }
    }

    #[test]
    fn packed4_matches_i8() {
        let mut rng = Rng::new(3);
        let (m, n, j) = (4, 64, 12);
        let xq: Vec<i8> = (0..m * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let wt: Vec<i8> = (0..j * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let mut packed = Vec::new();
        for c in 0..j {
            packed.extend(pack_int4(&wt[c * n..(c + 1) * n]));
        }
        let mut a1 = vec![0i32; m * j];
        let mut a2 = vec![0i32; m * j];
        gemm_i8(&xq, &wt, m, n, j, &mut a1);
        let mut scratch = Vec::new();
        gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch, &mut a2);
        assert_eq!(a1, a2);
    }

    #[test]
    fn epilogues() {
        let acc = vec![10i32, -4, 6, 8];
        let mut out = vec![0f32; 4];
        epilogue_sym(&acc, &[0.5, 2.0], Some(&[1.0, 0.5]), 2, 2, &mut out);
        assert_eq!(out, vec![5.0, -8.0, 1.5, 8.0]);

        let mut out2 = vec![0f32; 4];
        epilogue_asym(&acc, &[2, 3], &[1, -1], &[0.5, 2.0], None, 2, 2,
                      &mut out2);
        // row0: (10-2*1)*0.5=4, (-4+2)*2=-4 ; row1: (6-3)*0.5=1.5, (8+3)*2=22
        assert_eq!(out2, vec![4.0, -4.0, 1.5, 22.0]);
    }

    #[test]
    fn grouped_matches_dequant_reference() {
        let mut rng = Rng::new(4);
        let (m, n, j, g) = (3, 32, 5, 8);
        let xq: Vec<i8> = (0..m * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let wt: Vec<i8> = (0..j * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let ngroups = n / g;
        let scale: Vec<f32> =
            (0..ngroups * j).map(|_| rng.f32() * 0.1 + 0.01).collect();
        let zero: Vec<i32> = (0..ngroups * j).map(|_| rng.usize(0, 5) as i32 - 2).collect();
        let mut out = vec![0f32; m * j];
        gemm_i8_grouped(&xq, &wt, m, n, j, g, &scale, Some(&zero), None,
                        &mut out);
        // reference: dequantize weight then f32 GEMM
        for i in 0..m {
            for c in 0..j {
                let mut want = 0f64;
                for k in 0..n {
                    let gi = k / g;
                    let w = (wt[c * n + k] as i32 - zero[gi * j + c]) as f64
                        * scale[gi * j + c] as f64;
                    want += xq[i * n + k] as f64 * w;
                }
                assert!((out[i * j + c] as f64 - want).abs() < 1e-3);
            }
        }
    }
}
