//! Runtime-dispatched SIMD integer microkernels (DESIGN.md §17).
//!
//! Every integer GEMM in this crate bottoms out in the exact
//! i8·i8 → i32 dot product. This module provides hardware variants of
//! that inner loop — AVX2 and AVX-512 VNNI on x86_64, NEON on aarch64
//! — behind a process-wide dispatch table selected **once** via
//! feature probes, with the scalar loop
//! ([`super::gemm::dot_i8_scalar`]) as the portable fallback and the
//! pinned reference.
//!
//! The crucial property making a *global* dispatch choice sound: i8
//! products fit i16, i16-pair sums fit i32, and i32 addition is
//! associative and exact — so **every variant returns bit-identical
//! results for all inputs** (pinned by the in-module property tests
//! and by `tests/simd_kernels.rs`). A racy [`force`] mid-computation
//! therefore cannot change any output bit; the §7 determinism
//! contract holds per-kernel *and* across kernels.
//!
//! Selection order when `MQ_KERNEL` is unset: Vnni > Avx2 > Neon >
//! Scalar. `MQ_KERNEL=scalar|avx2|vnni|neon` (env, or `--kernel` on
//! the CLI) pins a variant; an unavailable or unknown request warns
//! once on stderr and falls back to the best available.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which microkernel implementation backs a [`Kernel`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelKind {
    /// Portable scalar reference loop (always available).
    Scalar = 0,
    /// AVX2: 32-lane widen + `vpmaddwd` pair-products (x86_64).
    Avx2 = 1,
    /// AVX-512 VNNI: 32-lane widen + `vpdpwssd` accumulate (x86_64).
    Vnni = 2,
    /// NEON: 16-lane `smull`/`sadalp` widening ladder (aarch64,
    /// baseline target feature — no runtime probe needed).
    Neon = 3,
}

impl KernelKind {
    /// All kinds, in dispatch-preference order (best first).
    pub const PREFERENCE: [KernelKind; 4] = [
        KernelKind::Vnni,
        KernelKind::Avx2,
        KernelKind::Neon,
        KernelKind::Scalar,
    ];

    /// Stable lowercase name (the `MQ_KERNEL` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Vnni => "vnni",
            KernelKind::Neon => "neon",
        }
    }

    /// Parse an `MQ_KERNEL` / `--kernel` value (case-insensitive).
    pub fn parse(s: &str) -> Option<KernelKind> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "avx2" => Some(KernelKind::Avx2),
            "vnni" => Some(KernelKind::Vnni),
            "neon" => Some(KernelKind::Neon),
            _ => None,
        }
    }
}

/// A resolved dispatch-table row. Hot tile loops hoist one of these
/// (`let kern = simd::active()`) and call through the stored function
/// pointer, so dispatch costs one relaxed load per *tile*, not per
/// dot.
#[derive(Clone, Copy)]
pub struct Kernel {
    kind: KernelKind,
    dot: fn(&[i8], &[i8]) -> i32,
}

impl Kernel {
    /// Which variant this row dispatches to.
    #[inline]
    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    /// Exact i8·i8 → i32 dot product over `min(a.len(), b.len())`
    /// elements — bitwise identical across all variants.
    #[inline]
    pub fn dot(&self, a: &[i8], b: &[i8]) -> i32 {
        (self.dot)(a, b)
    }
}

/// Build the dispatch row for `kind` without an availability check
/// (callers guarantee the host supports it; kinds foreign to the
/// compile target are unreachable behind [`available`] and map to the
/// scalar loop defensively).
fn row(kind: KernelKind) -> Kernel {
    let dot: fn(&[i8], &[i8]) -> i32 = match kind {
        KernelKind::Scalar => super::gemm::dot_i8_scalar,
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => dot_avx2_entry,
        #[cfg(target_arch = "x86_64")]
        KernelKind::Vnni => dot_vnni_entry,
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => neon::dot_i8_neon,
        #[allow(unreachable_patterns)]
        _ => super::gemm::dot_i8_scalar,
    };
    Kernel { kind, dot }
}

/// Variants usable on this host, scalar first (probe order, not
/// preference order).
pub fn available() -> Vec<KernelKind> {
    let mut v = vec![KernelKind::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(KernelKind::Avx2);
        }
        if std::arch::is_x86_feature_detected!("avx512f")
            && std::arch::is_x86_feature_detected!("avx512bw")
            && std::arch::is_x86_feature_detected!("avx512vnni")
        {
            v.push(KernelKind::Vnni);
        }
    }
    #[cfg(target_arch = "aarch64")]
    v.push(KernelKind::Neon);
    v
}

/// Dispatch row for `kind`, or `None` if this host can't run it.
pub fn for_kind(kind: KernelKind) -> Option<Kernel> {
    if available().contains(&kind) {
        Some(row(kind))
    } else {
        None
    }
}

/// The best variant this host supports (preference order).
pub fn best() -> Kernel {
    let avail = available();
    for &k in KernelKind::PREFERENCE.iter() {
        if avail.contains(&k) {
            return row(k);
        }
    }
    row(KernelKind::Scalar)
}

const UNINIT: u8 = u8::MAX;

/// The process-wide choice; `UNINIT` until first use so the
/// `MQ_KERNEL` probe happens lazily (tests can set the env var before
/// the first kernel call).
static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);

/// The active dispatch row. First call probes `MQ_KERNEL` and the
/// host features; later calls are one relaxed atomic load.
#[inline]
pub fn active() -> Kernel {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => row(KernelKind::Scalar),
        1 => row(KernelKind::Avx2),
        2 => row(KernelKind::Vnni),
        3 => row(KernelKind::Neon),
        _ => init(),
    }
}

/// Pin the process-wide dispatch to `kind`. Returns `false` (current
/// choice unchanged) when the host can't run that variant. Safe at
/// any time: all variants are bit-identical, so an in-flight GEMM
/// observing the old row produces the same stream.
pub fn force(kind: KernelKind) -> bool {
    match for_kind(kind) {
        Some(k) => {
            ACTIVE.store(k.kind() as u8, Ordering::Relaxed);
            true
        }
        None => false,
    }
}

/// Resolve a raw `MQ_KERNEL` value (`None` = unset) to a dispatch row
/// plus an optional warning line. Pure — no env access, no global
/// state — so the unknown-value and unavailable-value fallback paths
/// are unit-testable without perturbing the process-wide choice (CI
/// only exercises the valid-value path through the env).
fn resolve(raw: Option<&str>) -> (Kernel, Option<String>) {
    let Some(name) = raw else {
        return (best(), None);
    };
    match KernelKind::parse(name) {
        Some(kind) => match for_kind(kind) {
            Some(k) => (k, None),
            None => {
                let b = best();
                let warn = format!(
                    "[mergequant] MQ_KERNEL={name} not available \
                     on this host; using {}",
                    b.kind().name()
                );
                (b, Some(warn))
            }
        },
        None => {
            let b = best();
            let warn = format!(
                "[mergequant] MQ_KERNEL={name} unknown (want \
                 scalar|avx2|vnni|neon); using {}",
                b.kind().name()
            );
            (b, Some(warn))
        }
    }
}

/// Cold-path initializer: honor `MQ_KERNEL` when set and available,
/// otherwise pick [`best`], then publish the choice.
#[cold]
fn init() -> Kernel {
    let raw = std::env::var("MQ_KERNEL").ok();
    let (kern, warn) = resolve(raw.as_deref());
    if let Some(w) = warn {
        eprintln!("{w}");
    }
    ACTIVE.store(kern.kind() as u8, Ordering::Relaxed);
    kern
}

// ---------------------------------------------------------------- x86

/// Safe entry for the AVX2 body; only reachable through [`for_kind`]
/// after the runtime probe succeeded.
#[cfg(target_arch = "x86_64")]
fn dot_avx2_entry(a: &[i8], b: &[i8]) -> i32 {
    // Safety: installed in the dispatch table only when
    // is_x86_feature_detected!("avx2") returned true.
    unsafe { x86::dot_i8_avx2(a, b) }
}

/// Safe entry for the AVX-512 VNNI body; only reachable through
/// [`for_kind`] after the runtime probe succeeded.
#[cfg(target_arch = "x86_64")]
fn dot_vnni_entry(a: &[i8], b: &[i8]) -> i32 {
    // Safety: installed only when avx512f+avx512bw+avx512vnni were
    // all detected.
    unsafe { x86::dot_i8_vnni(a, b) }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// AVX2 i8·i8 → i32 dot: per 32-byte step, sign-extend both
    /// halves to i16×16, `vpmaddwd` pair-products into i32×8, add
    /// into the accumulator. Exact: |i8·i8| ≤ 16384 fits i16's
    /// product slot inside `vpmaddwd` (which widens to i32 before
    /// the pair add), and the per-lane i32 accumulation is exact for
    /// any realistic reduction length (≤ 2·32258 per step).
    ///
    /// # Safety
    /// Requires the `avx2` target feature at runtime.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_i8_avx2(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 32 <= n {
            let va = _mm256_loadu_si256(pa.add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(pb.add(i) as *const __m256i);
            let alo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(va));
            let ahi = _mm256_cvtepi8_epi16(
                _mm256_extracti128_si256::<1>(va),
            );
            let blo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(vb));
            let bhi = _mm256_cvtepi8_epi16(
                _mm256_extracti128_si256::<1>(vb),
            );
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(alo, blo));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(ahi, bhi));
            i += 32;
        }
        // Horizontal sum of the 8 i32 lanes.
        let lo = _mm256_castsi256_si128(acc);
        let hi = _mm256_extracti128_si256::<1>(acc);
        let s = _mm_add_epi32(lo, hi);
        let s = _mm_add_epi32(s, _mm_unpackhi_epi64(s, s));
        let s = _mm_add_epi32(s, _mm_shuffle_epi32::<1>(s));
        let mut total = _mm_cvtsi128_si32(s);
        while i < n {
            total += *pa.add(i) as i32 * *pb.add(i) as i32;
            i += 1;
        }
        total
    }

    /// AVX-512 VNNI i8·i8 → i32 dot: per 32-byte step, sign-extend
    /// to i16×32 in a zmm register and fold with one `vpdpwssd`
    /// (multiply i16 pairs, widen, accumulate i32). Exact by the
    /// same argument as the AVX2 path.
    ///
    /// # Safety
    /// Requires `avx512f`, `avx512bw` and `avx512vnni` at runtime.
    #[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
    pub unsafe fn dot_i8_vnni(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm512_setzero_si512();
        let mut i = 0usize;
        while i + 32 <= n {
            let va = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
                pa.add(i) as *const __m256i,
            ));
            let vb = _mm512_cvtepi8_epi16(_mm256_loadu_si256(
                pb.add(i) as *const __m256i,
            ));
            acc = _mm512_dpwssd_epi32(acc, va, vb);
            i += 32;
        }
        let mut total = _mm512_reduce_add_epi32(acc);
        while i < n {
            total += *pa.add(i) as i32 * *pb.add(i) as i32;
            i += 1;
        }
        total
    }
}

// --------------------------------------------------------------- arm

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// NEON i8·i8 → i32 dot: per 16-byte step, widening multiplies
    /// (`smull`/`smull2`, i8→i16) then pairwise-add-accumulate into
    /// the i32 accumulator (`sadalp`). NEON is a baseline feature of
    /// aarch64-unknown-linux-gnu, so no runtime probe or
    /// target_feature gate is needed. Exact: products fit i16,
    /// `sadalp` widens to i32 before adding (≤ 4·16129 per lane per
    /// step).
    pub fn dot_i8_neon(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len().min(b.len());
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // Safety: all loads are bounded by `n` ≤ both slice lengths;
        // NEON is statically enabled on this target.
        unsafe {
            let mut acc = vdupq_n_s32(0);
            let mut i = 0usize;
            while i + 16 <= n {
                let va = vld1q_s8(pa.add(i));
                let vb = vld1q_s8(pb.add(i));
                let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
                let hi = vmull_high_s8(va, vb);
                acc = vpadalq_s16(acc, lo);
                acc = vpadalq_s16(acc, hi);
                i += 16;
            }
            let mut total = vaddvq_s32(acc);
            while i < n {
                total += *pa.add(i) as i32 * *pb.add(i) as i32;
                i += 1;
            }
            total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemm::dot_i8_scalar;
    use crate::util::rng::Rng;

    #[test]
    fn names_roundtrip() {
        for k in KernelKind::PREFERENCE {
            assert_eq!(KernelKind::parse(k.name()), Some(k));
        }
        assert_eq!(KernelKind::parse("AVX2"), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse("sse9"), None);
    }

    #[test]
    fn scalar_always_available_and_active_resolves() {
        assert!(available().contains(&KernelKind::Scalar));
        assert!(for_kind(KernelKind::Scalar).is_some());
        // active() must resolve to one of the available variants.
        let k = active().kind();
        assert!(available().contains(&k), "active {k:?} not available");
    }

    /// Every host-available variant is bitwise the scalar reference,
    /// over random contents and lengths including sub-lane tails and
    /// the empty dot.
    #[test]
    fn property_all_variants_match_scalar() {
        for kind in available() {
            let kern = for_kind(kind).expect("listed as available");
            crate::util::proptest::check(
                97,
                200,
                |r| {
                    let n = r.usize(0, 200);
                    let a: Vec<i8> = (0..n)
                        .map(|_| r.usize(0, 256) as u8 as i8)
                        .collect();
                    let b: Vec<i8> = (0..n)
                        .map(|_| r.usize(0, 256) as u8 as i8)
                        .collect();
                    (a, b)
                },
                |(a, b)| {
                    let want = dot_i8_scalar(a, b);
                    let got = kern.dot(a, b);
                    if got == want {
                        Ok(())
                    } else {
                        Err(format!(
                            "{}: {got} != scalar {want} (n={})",
                            kind.name(),
                            a.len()
                        ))
                    }
                },
            );
        }
    }

    /// Extreme values (-128 everywhere) stay exact: the i16 product
    /// slot holds 16384 and the pair sums fit i32.
    #[test]
    fn extremes_exact() {
        for kind in available() {
            let kern = for_kind(kind).expect("available");
            for n in [0usize, 1, 15, 16, 17, 31, 32, 33, 160, 4096] {
                let a = vec![-128i8; n];
                let b = vec![-128i8; n];
                assert_eq!(kern.dot(&a, &b), 16384 * n as i32,
                           "{} n={n}", kind.name());
                let c = vec![127i8; n];
                assert_eq!(kern.dot(&a, &c), -16256 * n as i32,
                           "{} n={n}", kind.name());
            }
        }
    }

    /// The `MQ_KERNEL` fallback paths, pinned without touching the
    /// process env or the published dispatch choice: an unknown value
    /// and an unavailable-on-this-host value both fall back to
    /// [`best`] with a one-line warning; valid requests and an unset
    /// variable resolve silently.
    #[test]
    fn resolve_warns_and_falls_back() {
        let b = best().kind();
        // unset → best, silent
        let (k, warn) = resolve(None);
        assert_eq!(k.kind(), b);
        assert!(warn.is_none());
        // valid + available → honored, silent (scalar always is)
        let (k, warn) = resolve(Some("scalar"));
        assert_eq!(k.kind(), KernelKind::Scalar);
        assert!(warn.is_none());
        // unknown value → best, with the unknown-vocabulary warning
        let (k, warn) = resolve(Some("sse9"));
        assert_eq!(k.kind(), b);
        let w = warn.expect("unknown MQ_KERNEL must warn");
        assert!(w.contains("MQ_KERNEL=sse9 unknown"), "got: {w}");
        assert!(w.contains("scalar|avx2|vnni|neon"), "got: {w}");
        assert!(w.contains(&format!("using {}", b.name())), "got: {w}");
        // parseable but foreign to this host → best, with the
        // not-available warning
        #[cfg(target_arch = "x86_64")]
        let foreign = "neon";
        #[cfg(target_arch = "aarch64")]
        let foreign = "avx2";
        #[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
        {
            let (k, warn) = resolve(Some(foreign));
            assert_eq!(k.kind(), b);
            let w = warn.expect("unavailable MQ_KERNEL must warn");
            assert!(w.contains(&format!(
                        "MQ_KERNEL={foreign} not available")),
                    "got: {w}");
        }
        // and none of the above touched the published choice
        assert!(available().contains(&active().kind()));
    }

    /// `force` installs available variants and rejects foreign ones;
    /// restore the best kernel afterwards so test order can't matter.
    #[test]
    fn force_respects_availability() {
        for kind in available() {
            assert!(force(kind));
            assert_eq!(active().kind(), kind);
        }
        #[cfg(target_arch = "x86_64")]
        assert!(!force(KernelKind::Neon));
        #[cfg(target_arch = "aarch64")]
        assert!(!force(KernelKind::Avx2));
        force(best().kind());
    }
}
