//! Integer-kernel substrate (the CUTLASS-INT4 stand-in, DESIGN.md §2).
//!
//! * [`pack`] — INT4 nibble packing (two weights per byte).
//! * [`gemm`] — f32 reference GEMM and the i8/packed-int4 integer GEMM with
//!   the per-output-column rescale epilogue (the exact shape QSM aligns
//!   per-channel static quantization to, paper Eq. 5).
//! * [`dynamic`] — the explicit per-token Quant/DeQuant passes dynamic
//!   quantization needs (the overhead MergeQuant eliminates; Table 6).
//! * [`reconstruct`] — the dimension-reconstruction gather (paper App.
//!   C.1), MergeQuant's only runtime addition.
//! * [`hadamard`] — online block-FWHT(64) used by the `+hadamard`
//!   variants; bit-matches the Python `quant.hadamard.fwht_block64`.
//! * [`kv`] — statically-quantized INT8 KV cache: per-channel calibrated
//!   scales and the integer-domain attention kernels (QK^T as i8×i8→i32
//!   with the scales folded into the softmax pre-scale; prob×V with a
//!   per-column dequant epilogue; DESIGN.md §10).
//! * [`parallel`] — the parallel execution subsystem: a persistent scoped
//!   worker pool plus cache-blocked, output-tiled variants of the f32 /
//!   INT8 / packed-INT4 kernels, bitwise identical to the serial ones for
//!   every thread count (DESIGN.md §7).
//! * [`simd`] — runtime-dispatched SIMD variants of the i8·i8→i32 inner
//!   loop (AVX2 / AVX-512 VNNI / NEON), selected once via feature probes
//!   behind a dispatch table with the scalar loop as portable fallback;
//!   every variant is bit-identical to scalar (DESIGN.md §17).

#![warn(missing_docs)]

pub mod dynamic;
pub mod gemm;
pub mod hadamard;
pub mod kv;
pub mod pack;
pub mod parallel;
pub mod reconstruct;
pub mod simd;

/// Symmetric qmax for a bit width: 2^(b-1) − 1 (paper Eq. 1).
#[inline]
pub fn qmax_for_bits(bits: u32) -> i32 {
    (1 << (bits - 1)) - 1
}

/// Round-half-away-from-zero then clamp — the ⌈·⌋ of Eq. (1). `f32::round`
/// has exactly these semantics, matching the JAX pipeline's oracle.
#[inline]
pub fn quantize_value(x: f32, inv_scale: f32, qmax: i32) -> i8 {
    let q = (x * inv_scale).round();
    q.clamp(-(qmax as f32), qmax as f32) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax_for_bits(4), 7);
        assert_eq!(qmax_for_bits(3), 3);
        assert_eq!(qmax_for_bits(8), 127);
    }

    #[test]
    fn rounding_half_away() {
        assert_eq!(quantize_value(0.5, 1.0, 7), 1);
        assert_eq!(quantize_value(-0.5, 1.0, 7), -1);
        assert_eq!(quantize_value(2.5, 1.0, 7), 3);
        assert_eq!(quantize_value(100.0, 1.0, 7), 7);
        assert_eq!(quantize_value(-100.0, 1.0, 7), -7);
    }
}
