//! Online block-Hadamard transform, block size 64 — bit-compatible with
//! `python/compile/quant/hadamard.py::fwht_block64` (same butterfly order,
//! same 1/√64 normalisation). Used by the `+hadamard` method variants on
//! the per-token-dynamic projections.

/// Hadamard block size (channels per butterfly group).
pub const BLOCK: usize = 64;
const INV_SQRT: f32 = 0.125; // 1/sqrt(64)

/// In-place normalised FWHT on each 64-channel block of each row.
pub fn fwht_block64(x: &mut [f32], m: usize, d: usize) {
    assert_eq!(x.len(), m * d);
    assert_eq!(d % BLOCK, 0, "d must be divisible by 64");
    for i in 0..m {
        let row = &mut x[i * d..(i + 1) * d];
        for b in 0..d / BLOCK {
            let blk = &mut row[b * BLOCK..(b + 1) * BLOCK];
            fwht64(blk);
        }
    }
}

#[inline]
fn fwht64(v: &mut [f32]) {
    let mut h = 1;
    while h < BLOCK {
        let step = 2 * h;
        let mut base = 0;
        while base < BLOCK {
            for i in 0..h {
                let a = v[base + i];
                let b = v[base + h + i];
                v[base + i] = a + b;
                v[base + h + i] = a - b;
            }
            base += step;
        }
        h *= 2;
    }
    for x in v.iter_mut() {
        *x *= INV_SQRT;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn preserves_norm() {
        let mut rng = Rng::new(1);
        let d = 128;
        let orig: Vec<f32> = (0..2 * d).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        fwht_block64(&mut x, 2, d);
        for i in 0..2 {
            let n0: f32 =
                orig[i * d..(i + 1) * d].iter().map(|v| v * v).sum();
            let n1: f32 = x[i * d..(i + 1) * d].iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() / n0 < 1e-4);
        }
    }

    #[test]
    fn involutive() {
        let mut rng = Rng::new(2);
        let orig: Vec<f32> = (0..192).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        fwht_block64(&mut x, 1, 192);
        fwht_block64(&mut x, 1, 192);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn matches_dense_definition() {
        // H_64[a][b] = (-1)^{popcount(a & b)} / sqrt(64)
        let mut x = vec![0f32; 64];
        x[5] = 1.0;
        fwht64(&mut x);
        for (b, v) in x.iter().enumerate() {
            let sign = if (5usize & b).count_ones() % 2 == 0 { 1.0 } else { -1.0 };
            assert!((v - sign * INV_SQRT).abs() < 1e-6, "b={b}");
        }
    }

    #[test]
    fn smooths_outlier_spike() {
        // One huge channel spreads across its block — the rotation's point.
        let mut x = vec![0.1f32; 64];
        x[7] = 50.0;
        let before_max = 50.0f32;
        fwht_block64(&mut x, 1, 64);
        let after_max = x.iter().fold(0f32, |a, &v| a.max(v.abs()));
        assert!(after_max < before_max / 4.0);
    }
}
