//! INT4 nibble packing: two signed 4-bit values per byte.
//!
//! Values must lie in [-8, 7] (we only ever store [-qmax, qmax] ⊆ [-7, 7]
//! symmetric, or shifted-signed asymmetric codes, which also fit). Layout:
//! element 2i in the low nibble, 2i+1 in the high nibble; odd lengths pad
//! the final high nibble with 0.

/// Pack a row of i8 four-bit values; panics (debug) if out of range.
pub fn pack_int4(vals: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; vals.len().div_ceil(2)];
    for (i, &v) in vals.iter().enumerate() {
        debug_assert!((-8..=7).contains(&v), "int4 range: {v}");
        let nib = (v as u8) & 0x0F;
        if i % 2 == 0 {
            out[i / 2] |= nib;
        } else {
            out[i / 2] |= nib << 4;
        }
    }
    out
}

/// Unpack into a caller-provided buffer (len = number of values).
/// Branchless two-per-byte loop — vectorizes under AVX-512BW.
pub fn unpack_int4_into(packed: &[u8], out: &mut [i8]) {
    let pairs = out.len() / 2;
    for i in 0..pairs {
        let byte = packed[i];
        // Sign-extend low and high nibbles.
        out[2 * i] = ((byte << 4) as i8) >> 4;
        out[2 * i + 1] = (byte as i8) >> 4;
    }
    if out.len() % 2 == 1 {
        let byte = packed[pairs];
        out[out.len() - 1] = ((byte << 4) as i8) >> 4;
    }
}

/// Allocating unpack: `len` values from the packed row.
pub fn unpack_int4(packed: &[u8], len: usize) -> Vec<i8> {
    let mut out = vec![0i8; len];
    unpack_int4_into(packed, &mut out);
    out
}

/// Extract value i without unpacking the row.
#[inline]
pub fn get_int4(packed: &[u8], i: usize) -> i8 {
    let byte = packed[i / 2];
    let nib = if i % 2 == 0 { byte & 0x0F } else { byte >> 4 };
    ((nib << 4) as i8) >> 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_all_values() {
        let vals: Vec<i8> = (-8..=7).collect();
        let packed = pack_int4(&vals);
        assert_eq!(packed.len(), 8);
        assert_eq!(unpack_int4(&packed, vals.len()), vals);
    }

    #[test]
    fn roundtrip_odd_length() {
        let vals = vec![-7i8, 3, 5];
        let packed = pack_int4(&vals);
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_int4(&packed, 3), vals);
    }

    #[test]
    fn get_matches_unpack() {
        let mut rng = Rng::new(5);
        let vals: Vec<i8> =
            (0..1001).map(|_| rng.usize(0, 15) as i8 - 8).collect();
        let packed = pack_int4(&vals);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(get_int4(&packed, i), v);
        }
    }

    #[test]
    fn property_roundtrip_random() {
        crate::util::proptest::check(
            11,
            100,
            |r| {
                let len = r.usize(0, 200);
                (0..len).map(|_| r.usize(0, 16) as u32).collect::<Vec<u32>>()
            },
            |codes| {
                let vals: Vec<i8> =
                    codes.iter().map(|&c| c as i8 - 8).collect();
                let rt = unpack_int4(&pack_int4(&vals), vals.len());
                if rt == vals {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }
}
