//! Persistent scoped worker pool for intra-op kernel parallelism.
//!
//! Built on `std::thread` only — rayon/crossbeam are not vendored
//! (DESIGN.md §2 substitution table). Workers are spawned **once** when
//! the pool is created and live for the pool's lifetime, so the decode
//! hot path never pays thread-spawn latency; each [`ThreadPool::run`]
//! call executes one *batch* of borrowing tasks to completion before
//! returning, which is what makes the lifetime erasure inside sound
//! (DESIGN.md §7).
//!
//! Determinism contract: the pool executes tasks, it does not split them.
//! Kernels built on it partition only the *output* space (rows/columns),
//! never the reduction dimension, so results are bitwise identical for
//! every thread count — see [`super`] and `tests/parallel_gemm.rs`.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Lifetime-erased task stored in the shared queue. The erasure happens
/// only inside [`ThreadPool::run`], which blocks until every task of its
/// batch has finished — see the safety comment there.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// A borrowing unit of work: may capture references into the caller's
/// stack frame (activation slices, weight tensors, output tiles).
pub type ScopedTask<'scope> = Box<dyn FnOnce() + Send + 'scope>;

struct Queue {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    work_cv: Condvar,
}

/// Per-batch completion state: (tasks still pending, a task panicked).
type BatchState = (Mutex<(usize, bool)>, Condvar);

/// Persistent worker pool executing scoped task batches.
///
/// * `threads == 1` spawns **no** workers: [`ThreadPool::run`] executes
///   the batch inline on the caller (zero overhead, the serial baseline).
/// * `threads >= 2` spawns that many workers; `run` enqueues the batch
///   and blocks until the last task completes. The caller does not steal
///   work, so `threads` is exactly the compute-thread count.
///
/// `run` may be called from several threads at once (each batch tracks
/// its own completion), though the engine uses one caller per pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Create a pool of `threads` compute threads (`0` is clamped to 1;
    /// use [`ThreadPool::resolve`] first to map 0 → all cores).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue {
                tasks: VecDeque::new(),
                shutdown: false,
            }),
            work_cv: Condvar::new(),
        });
        let workers = if threads == 1 {
            Vec::new()
        } else {
            (0..threads)
                .map(|i| {
                    let sh = Arc::clone(&shared);
                    std::thread::Builder::new()
                        .name(format!("mq-kernel-{i}"))
                        .spawn(move || worker_loop(sh))
                        .expect("spawning kernel worker")
                })
                .collect()
        };
        ThreadPool { shared, workers, threads }
    }

    /// Number of compute threads (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Resolve a configured thread count: `0` means "all available
    /// cores" (`std::thread::available_parallelism`), anything else is
    /// taken literally.
    pub fn resolve(threads: usize) -> usize {
        if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        }
    }

    /// Execute a batch of independent tasks to completion.
    ///
    /// Blocks until every task has run. Tasks must be mutually
    /// independent (kernels guarantee this by writing disjoint output
    /// tiles). If any task panics, the panic is re-raised here after the
    /// rest of the batch drains.
    pub fn run<'scope>(&self, tasks: Vec<ScopedTask<'scope>>) {
        if self.workers.is_empty() || tasks.len() <= 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let batch: Arc<BatchState> =
            Arc::new((Mutex::new((tasks.len(), false)), Condvar::new()));
        {
            let mut q = self.shared.queue.lock().unwrap();
            for t in tasks {
                let done = Arc::clone(&batch);
                let wrapped: ScopedTask<'scope> = Box::new(move || {
                    let r = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(t),
                    );
                    let (lock, cv) = &*done;
                    let mut st = lock.lock().unwrap();
                    st.0 -= 1;
                    st.1 |= r.is_err();
                    if st.0 == 0 {
                        cv.notify_all();
                    }
                });
                // SAFETY: `wrapped` may borrow data from the caller's
                // stack ('scope). We erase that lifetime to store it in
                // the persistent queue, but `run` does not return until
                // the batch counter hits zero, i.e. until every wrapped
                // task has finished executing and dropped its captures —
                // so no borrow outlives the data it points to.
                let wrapped: Task = unsafe {
                    std::mem::transmute::<ScopedTask<'scope>, Task>(wrapped)
                };
                q.tasks.push_back(wrapped);
            }
            self.shared.work_cv.notify_all();
        }
        let (lock, cv) = &*batch;
        let mut st = lock.lock().unwrap();
        while st.0 > 0 {
            st = cv.wait(st).unwrap();
        }
        if st.1 {
            panic!("worker task panicked (see stderr for the original)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break t;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        task();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_task_runs_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits = AtomicUsize::new(0);
        for round in 0..8 {
            let n = 1 + round * 13; // more tasks than threads
            let tasks: Vec<ScopedTask<'_>> = (0..n)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::Relaxed);
                    }) as ScopedTask<'_>
                })
                .collect();
            pool.run(tasks);
        }
        let want: usize = (0..8).map(|r| 1 + r * 13).sum();
        assert_eq!(hits.load(Ordering::Relaxed), want);
    }

    #[test]
    fn borrows_stack_data_and_writes_disjoint_chunks() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u64; 97];
        let tasks: Vec<ScopedTask<'_>> = data
            .chunks_mut(10)
            .enumerate()
            .map(|(ci, chunk)| {
                Box::new(move || {
                    for (k, v) in chunk.iter_mut().enumerate() {
                        *v = (ci * 10 + k) as u64;
                    }
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run(tasks);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut x = 0;
        pool.run(vec![Box::new(|| x += 1) as ScopedTask<'_>]);
        assert_eq!(x, 1);
    }

    #[test]
    #[should_panic(expected = "worker task panicked")]
    fn task_panic_propagates_to_caller() {
        let pool = ThreadPool::new(2);
        let tasks: Vec<ScopedTask<'_>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    if i == 2 {
                        panic!("boom");
                    }
                }) as ScopedTask<'_>
            })
            .collect();
        pool.run(tasks);
    }

    #[test]
    fn resolve_zero_means_cores() {
        assert!(ThreadPool::resolve(0) >= 1);
        assert_eq!(ThreadPool::resolve(3), 3);
    }
}
