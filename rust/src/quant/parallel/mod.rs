//! Parallel execution subsystem: cache-blocked, output-tiled variants of
//! the f32 / INT8 / packed-INT4 GEMM kernels running on a persistent
//! [`ThreadPool`] (DESIGN.md §7).
//!
//! # Tiling
//!
//! Work is partitioned over the **output** matrix only: row blocks of
//! [`TILE_ROWS`] activation rows × column tiles of up to [`TILE_COLS`]
//! output columns (shrunk adaptively so every thread gets ≥ 2 tiles).
//! One (row-block, column-tile) pair is one pool task; a task walks its
//! tile with the *same* inner loops as the serial kernel, including the
//! per-output-column rescale epilogue of paper Eq. (5) — the epilogue
//! never leaves the tile, so the i32 accumulator for a tile stays in
//! registers/L1 and is not materialized as an (m, j) tensor.
//!
//! # Determinism
//!
//! The reduction (k) dimension is **never split**: every output element
//! is produced by exactly one task running exactly the serial kernel's
//! dot-product loop. Integer accumulation is exact, and the f32 epilogue
//! applies the same operations in the same order, so results are
//! **bitwise identical** to the serial kernels for every thread count
//! (property-tested in `tests/parallel_gemm.rs`; this is what keeps
//! `tests/artifact_parity.rs` valid under parallel execution).

pub mod pool;

pub use pool::{ScopedTask, ThreadPool};

use super::gemm::{
    dot_f32, gemm_f32, gemm_i8, gemm_i8_packed4, PACKED_MIN_ROWS,
};
use super::pack::unpack_int4_into;
use super::simd;

/// Row-block height: activation rows per task. 32 rows of int8
/// activations at n = 4096 is 128 KB — fits L2 alongside the weight tile.
pub const TILE_ROWS: usize = 32;

/// Maximum output-column tile width. 64 columns × n = 4096 int4 weights
/// is 128 KB of packed weight per tile — the cache-blocking unit.
pub const TILE_COLS: usize = 64;

/// Minimum multiply-accumulate count (m·n·j) worth parallelizing; below
/// this the serial kernel wins on task-dispatch overhead. Falling back is
/// always safe: serial and parallel paths are bitwise identical.
pub const PAR_MIN_MACS: usize = 1 << 16;

/// Raw mutable output pointer shared across tasks. Tasks write disjoint
/// index sets (enforced by the tiling), which is what makes the `Send`/
/// `Sync` assertion sound.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);

// SAFETY: tasks only ever write through disjoint indices (disjoint
// (row, column) tiles of the output matrix), and the pool's `run`
// barriers the batch before the buffer is read again.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Column-tile width adapted to the matrix and pool: at most
/// [`TILE_COLS`], at least 8, aiming for ≥ 2 tiles per thread so the
/// queue can load-balance ragged shapes.
fn col_tile(j: usize, threads: usize) -> usize {
    TILE_COLS.min(j.div_ceil(threads * 2)).max(8)
}

/// Parallel `y (m, j) = x (m, n) @ wt^T` over f32 — tiled
/// [`gemm_f32`], bitwise identical to it for every thread count.
pub fn par_gemm_f32(pool: &ThreadPool, x: &[f32], wt: &[f32], m: usize,
                    n: usize, j: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * n);
    assert_eq!(wt.len(), j * n);
    assert_eq!(out.len(), m * j);
    if pool.threads() == 1 || m * n * j < PAR_MIN_MACS {
        gemm_f32(x, wt, m, n, j, out);
        return;
    }
    let tc = col_tile(j, pool.threads());
    let optr = SendPtr(out.as_mut_ptr());
    let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
    for r0 in (0..m).step_by(TILE_ROWS) {
        let r1 = (r0 + TILE_ROWS).min(m);
        for c0 in (0..j).step_by(tc) {
            let c1 = (c0 + tc).min(j);
            tasks.push(Box::new(move || {
                for i in r0..r1 {
                    let xr = &x[i * n..(i + 1) * n];
                    for c in c0..c1 {
                        let v = dot_f32(xr, &wt[c * n..(c + 1) * n]);
                        // SAFETY: (i, c) tiles are disjoint across tasks.
                        unsafe { *optr.0.add(i * j + c) = v };
                    }
                }
            }));
        }
    }
    pool.run(tasks);
}

/// Parallel integer GEMM over unpacked i8 weights — tiled [`gemm_i8`],
/// identical i32 accumulators for every thread count.
pub fn par_gemm_i8(pool: &ThreadPool, xq: &[i8], wt: &[i8], m: usize,
                   n: usize, j: usize, acc: &mut [i32]) {
    assert_eq!(xq.len(), m * n);
    assert_eq!(wt.len(), j * n);
    assert_eq!(acc.len(), m * j);
    if pool.threads() == 1 || m * n * j < PAR_MIN_MACS {
        gemm_i8(xq, wt, m, n, j, acc);
        return;
    }
    let tc = col_tile(j, pool.threads());
    let aptr = SendPtr(acc.as_mut_ptr());
    // Hoisted once: tasks share the dispatch row, one relaxed load
    // total instead of one per dot.
    let kern = simd::active();
    let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
    for r0 in (0..m).step_by(TILE_ROWS) {
        let r1 = (r0 + TILE_ROWS).min(m);
        for c0 in (0..j).step_by(tc) {
            let c1 = (c0 + tc).min(j);
            tasks.push(Box::new(move || {
                for i in r0..r1 {
                    let xr = &xq[i * n..(i + 1) * n];
                    for c in c0..c1 {
                        let v = kern.dot(xr, &wt[c * n..(c + 1) * n]);
                        // SAFETY: (i, c) tiles are disjoint across tasks.
                        unsafe { *aptr.0.add(i * j + c) = v };
                    }
                }
            }));
        }
    }
    pool.run(tasks);
}

/// Parallel integer GEMM over **packed int4** weights — tiled
/// [`gemm_i8_packed4`]. Each task unpacks the weight rows of its column
/// tile into a task-local scratch row (the caller's `scratch` is only
/// used by the serial fallback, keeping that path allocation-free).
pub fn par_gemm_i8_packed4(pool: &ThreadPool, xq: &[i8], wpacked: &[u8],
                           m: usize, n: usize, j: usize,
                           scratch: &mut Vec<i8>, acc: &mut [i32]) {
    let row_bytes = n.div_ceil(2);
    assert_eq!(xq.len(), m * n);
    assert_eq!(wpacked.len(), j * row_bytes);
    assert_eq!(acc.len(), m * j);
    if pool.threads() == 1 || m * n * j < PAR_MIN_MACS {
        gemm_i8_packed4(xq, wpacked, m, n, j, scratch, acc);
        return;
    }
    let tc = col_tile(j, pool.threads());
    let aptr = SendPtr(acc.as_mut_ptr());
    let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
    for r0 in (0..m).step_by(TILE_ROWS) {
        let r1 = (r0 + TILE_ROWS).min(m);
        for c0 in (0..j).step_by(tc) {
            let c1 = (c0 + tc).min(j);
            tasks.push(Box::new(move || {
                let kern = simd::active();
                let mut wrow = vec![0i8; n];
                for c in c0..c1 {
                    unpack_int4_into(
                        &wpacked[c * row_bytes..(c + 1) * row_bytes],
                        &mut wrow,
                    );
                    for i in r0..r1 {
                        let v = kern.dot(&xq[i * n..(i + 1) * n], &wrow);
                        // SAFETY: (i, c) tiles are disjoint across tasks.
                        unsafe { *aptr.0.add(i * j + c) = v };
                    }
                }
            }));
        }
    }
    pool.run(tasks);
}

/// Fused parallel quantized linear: integer GEMM (packed-int4 when
/// `packed` is present and `m ≥` [`PACKED_MIN_ROWS`], i8 otherwise) with
/// the per-output-column rescale epilogue of paper Eq. (5) applied
/// *inside each tile* — the (m, j) i32 accumulator is never written to
/// memory.
///
/// Semantics (bitwise, per element, matching the serial
/// `gemm_i8`/`gemm_i8_packed4` + `epilogue_sym`/`epilogue_asym` chain):
///
/// * symmetric (`zero == None`): `out[i,c] = acc as f32 · col_scale[c] ·
///   row_scale[i]`
/// * asymmetric: `out[i,c] = (acc − xq_rowsum[i]·zero[c]) as f32 ·
///   col_scale[c] · row_scale[i]`
///
/// `xq_rowsum` is required iff `zero` is present. `scratch` backs the
/// serial fallback's weight-row unpack (decode stays allocation-free).
#[allow(clippy::too_many_arguments)]
pub fn par_qlinear(pool: &ThreadPool, xq: &[i8], wt: &[i8],
                   packed: Option<&[u8]>, m: usize, n: usize, j: usize,
                   col_scale: &[f32], zero: Option<&[i32]>,
                   xq_rowsum: Option<&[i32]>, row_scale: Option<&[f32]>,
                   scratch: &mut Vec<i8>, out: &mut [f32]) {
    assert_eq!(xq.len(), m * n);
    assert_eq!(col_scale.len(), j);
    assert_eq!(out.len(), m * j);
    if let Some(z) = zero {
        assert_eq!(z.len(), j);
        assert_eq!(xq_rowsum.expect("asymmetric path needs xq_rowsum").len(),
                   m);
    }
    if let Some(r) = row_scale {
        assert_eq!(r.len(), m);
    }
    let use_packed = packed.is_some() && m >= PACKED_MIN_ROWS;
    if use_packed {
        assert_eq!(packed.unwrap().len(), j * n.div_ceil(2));
    } else {
        assert_eq!(wt.len(), j * n);
    }
    let optr = SendPtr(out.as_mut_ptr());
    if pool.threads() == 1 || m * n * j < PAR_MIN_MACS {
        scratch.resize(n, 0);
        qlinear_tile(xq, wt, packed, n, j, col_scale, zero, xq_rowsum,
                     row_scale, use_packed, 0, m, 0, j, scratch, optr);
        return;
    }
    let tc = col_tile(j, pool.threads());
    let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
    for r0 in (0..m).step_by(TILE_ROWS) {
        let r1 = (r0 + TILE_ROWS).min(m);
        for c0 in (0..j).step_by(tc) {
            let c1 = (c0 + tc).min(j);
            tasks.push(Box::new(move || {
                let mut wrow =
                    if use_packed { vec![0i8; n] } else { Vec::new() };
                qlinear_tile(xq, wt, packed, n, j, col_scale, zero,
                             xq_rowsum, row_scale, use_packed, r0, r1, c0,
                             c1, &mut wrow, optr);
            }));
        }
    }
    pool.run(tasks);
}

/// One (row-block × column-tile) of the fused quantized linear. Shared
/// by the serial fallback (whole matrix as one tile) and the pool tasks.
#[allow(clippy::too_many_arguments)]
fn qlinear_tile(xq: &[i8], wt: &[i8], packed: Option<&[u8]>, n: usize,
                j: usize, col_scale: &[f32], zero: Option<&[i32]>,
                xq_rowsum: Option<&[i32]>, row_scale: Option<&[f32]>,
                use_packed: bool, r0: usize, r1: usize, c0: usize,
                c1: usize, wrow: &mut [i8], out: SendPtr<f32>) {
    let row_bytes = n.div_ceil(2);
    let kern = simd::active();
    for c in c0..c1 {
        let w: &[i8] = if use_packed {
            let p = packed.unwrap();
            unpack_int4_into(&p[c * row_bytes..(c + 1) * row_bytes], wrow);
            wrow
        } else {
            &wt[c * n..(c + 1) * n]
        };
        let cs = col_scale[c];
        let zc = zero.map(|z| z[c]);
        for i in r0..r1 {
            let a = kern.dot(&xq[i * n..(i + 1) * n], w);
            let corr = match zc {
                Some(z) => a - xq_rowsum.unwrap()[i] * z,
                None => a,
            };
            let rs = row_scale.map_or(1.0, |r| r[i]);
            // Exactly epilogue_sym/epilogue_asym's expression — keeps the
            // fused path bitwise identical to GEMM + standalone epilogue.
            // SAFETY: (i, c) tiles are disjoint across tasks.
            unsafe { *out.0.add(i * j + c) = corr as f32 * cs * rs };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gemm::{epilogue_asym, epilogue_sym, rowsum_i8};
    use crate::quant::pack::pack_int4;
    use crate::util::rng::Rng;

    fn rand_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
        (0..len).map(|_| rng.usize(0, 15) as i8 - 7).collect()
    }

    #[test]
    fn fused_serial_matches_unfused_chain() {
        // The serial fallback of par_qlinear must already be bitwise
        // equal to gemm + epilogue (the parallel path is covered by
        // tests/parallel_gemm.rs across thread counts).
        let mut rng = Rng::new(17);
        let pool = ThreadPool::new(1);
        for &(m, n, j) in &[(3usize, 33usize, 9usize), (12, 64, 20)] {
            let xq = rand_i8(&mut rng, m * n);
            let wt = rand_i8(&mut rng, j * n);
            let mut packed = Vec::new();
            for c in 0..j {
                packed.extend(pack_int4(&wt[c * n..(c + 1) * n]));
            }
            let cs: Vec<f32> =
                (0..j).map(|_| 0.01 + rng.f32() * 0.05).collect();
            let rs: Vec<f32> =
                (0..m).map(|_| 0.5 + rng.f32()).collect();
            let zero: Vec<i32> =
                (0..j).map(|_| rng.usize(0, 5) as i32 - 2).collect();

            let mut acc = vec![0i32; m * j];
            let mut scratch = Vec::new();
            if m >= PACKED_MIN_ROWS {
                gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch,
                                &mut acc);
            } else {
                gemm_i8(&xq, &wt, m, n, j, &mut acc);
            }
            let mut rsum = Vec::new();
            rowsum_i8(&xq, m, n, &mut rsum);

            // symmetric
            let mut want = vec![0f32; m * j];
            epilogue_sym(&acc, &cs, Some(&rs), m, j, &mut want);
            let mut got = vec![0f32; m * j];
            par_qlinear(&pool, &xq, &wt, Some(&packed), m, n, j, &cs, None,
                        None, Some(&rs), &mut scratch, &mut got);
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "sym m{m} n{n} j{j}"
            );

            // asymmetric
            let mut want2 = vec![0f32; m * j];
            epilogue_asym(&acc, &rsum, &zero, &cs, Some(&rs), m, j,
                          &mut want2);
            let mut got2 = vec![0f32; m * j];
            par_qlinear(&pool, &xq, &wt, Some(&packed), m, n, j, &cs,
                        Some(&zero), Some(&rsum), Some(&rs), &mut scratch,
                        &mut got2);
            assert_eq!(
                got2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "asym m{m} n{n} j{j}"
            );
        }
    }

    #[test]
    fn col_tile_bounds() {
        assert_eq!(col_tile(512, 4), 64);
        assert_eq!(col_tile(64, 4), 8);
        assert!(col_tile(1, 8) >= 1);
        assert_eq!(col_tile(4096, 2), 64);
    }
}
