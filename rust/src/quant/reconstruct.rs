//! Dimension reconstruction at runtime (paper App. C.1).
//!
//! After the offline split/prune of the static scale vector, the only
//! runtime cost MergeQuant adds is this gather: reorder the quantized
//! activation channels by `recon_idx` (dropping pruned channels and
//! duplicating split "strong" channels). One read + one write pass over
//! an already-int8 tensor — compare `dynamic::per_token_quant`, which must
//! read f32, reduce, divide and round (Table 6 measures the two).

/// Gather channels of xq (m, d) by idx (d,) into out (m, d).
pub fn reconstruct_i8(xq: &[i8], idx: &[u32], m: usize, d: usize,
                      out: &mut [i8]) {
    assert_eq!(xq.len(), m * d);
    assert_eq!(idx.len(), d);
    assert_eq!(out.len(), m * d);
    for i in 0..m {
        let row = &xq[i * d..(i + 1) * d];
        let or = &mut out[i * d..(i + 1) * d];
        for (o, &src) in or.iter_mut().zip(idx) {
            *o = row[src as usize];
        }
    }
}

/// f32 variant (used by the paper's own snippet on fp activations; we
/// bench both to show the comparison is not storage-format-rigged).
pub fn reconstruct_f32(x: &[f32], idx: &[u32], m: usize, d: usize,
                       out: &mut [f32]) {
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        let or = &mut out[i * d..(i + 1) * d];
        for (o, &src) in or.iter_mut().zip(idx) {
            *o = row[src as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{proptest, rng::Rng};

    #[test]
    fn gather_basic() {
        let x = vec![1i8, 2, 3, 4, 5, 6];
        let idx = vec![2u32, 2, 0];
        let mut out = vec![0i8; 6];
        reconstruct_i8(&x, &idx, 2, 3, &mut out);
        assert_eq!(out, vec![3, 3, 1, 6, 6, 4]);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(1);
        let d = 64;
        let x: Vec<f32> = (0..2 * d).map(|_| rng.normal()).collect();
        let idx: Vec<u32> = (0..d as u32).collect();
        let mut out = vec![0f32; 2 * d];
        reconstruct_f32(&x, &idx, 2, d, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn property_gather_values_come_from_source() {
        proptest::check(
            21,
            100,
            |r| {
                let d = r.usize(1, 64);
                let idx: Vec<u32> =
                    (0..d).map(|_| r.usize(0, d) as u32).collect();
                idx
            },
            |idx| {
                let d = idx.len();
                let x: Vec<i8> = (0..d as i8).collect();
                let mut out = vec![0i8; d];
                reconstruct_i8(&x, idx, 1, d, &mut out);
                for (o, &src) in out.iter().zip(idx) {
                    if *o != x[src as usize] {
                        return Err(format!("out {o} != x[{src}]"));
                    }
                }
                Ok(())
            },
        );
    }
}
