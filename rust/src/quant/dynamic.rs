//! Per-token dynamic quantization ops — **baseline only**. These are
//! the explicit "Quant"/"DeQuant" passes that dynamic W4A4 pays on
//! every token (paper Fig. 4 red box, Table 6), deliberately kept as
//! separate memory passes mirroring the PyTorch implementation the
//! paper benchmarks against. Nothing MergeQuant-static routes through
//! here: the per-channel static path ([`QuantMode::ChannelStatic`],
//! DESIGN.md §17) quantizes with compile-time multipliers and folds
//! dequantization into the weight columns, and the BENCH
//! `quant_overhead` axis exists to measure exactly this module's cost
//! against it.
//!
//! [`QuantMode::ChannelStatic`]: crate::engine::QuantMode

use super::quantize_value;

/// Per-token (per-row) absmax quantize: x (m, n) f32 → xq i8 + row scales.
/// One full read pass + one write pass over the activation tensor.
pub fn per_token_quant(x: &[f32], m: usize, n: usize, qmax: i32, clip: f32,
                       xq: &mut [i8], scales: &mut [f32]) {
    assert_eq!(x.len(), m * n);
    assert_eq!(xq.len(), m * n);
    assert_eq!(scales.len(), m);
    for i in 0..m {
        let row = &x[i * n..(i + 1) * n];
        let absmax = row.iter().fold(0f32, |a, &v| a.max(v.abs()));
        let s = (absmax * clip / qmax as f32).max(1e-8);
        scales[i] = s;
        let inv = 1.0 / s;
        let qr = &mut xq[i * n..(i + 1) * n];
        for (q, &v) in qr.iter_mut().zip(row) {
            *q = quantize_value(v, inv, qmax);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::qmax_for_bits;
    use crate::util::rng::Rng;

    /// Allocating wrapper over [`per_token_quant`] — test-only; the
    /// `dequant_pass` it used to pair with was a dead export (the
    /// fused engine runs the epilogue in `gemm::epilogue_sym`) and
    /// was removed with it.
    fn dynamic_quant_step(x: &[f32], m: usize, n: usize, bits: u32,
                          clip: f32) -> (Vec<i8>, Vec<f32>) {
        let mut xq = vec![0i8; m * n];
        let mut scales = vec![0f32; m];
        per_token_quant(x, m, n, qmax_for_bits(bits), clip, &mut xq,
                        &mut scales);
        (xq, scales)
    }

    #[test]
    fn quant_dequant_bounded_error() {
        let mut rng = Rng::new(1);
        let (m, n) = (16, 64);
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal() * 3.0).collect();
        let (xq, s) = dynamic_quant_step(&x, m, n, 4, 1.0);
        for i in 0..m {
            for k in 0..n {
                let deq = xq[i * n + k] as f32 * s[i];
                // max error is half a step per element
                assert!((deq - x[i * n + k]).abs() <= 0.5 * s[i] + 1e-6);
            }
        }
    }

    #[test]
    fn per_row_scales_independent() {
        let x = [1.0f32, 2.0, 100.0, 50.0];
        let (xq, s) = dynamic_quant_step(&x, 2, 2, 4, 1.0);
        assert!((s[0] - 2.0 / 7.0).abs() < 1e-6);
        assert!((s[1] - 100.0 / 7.0).abs() < 1e-6);
        assert_eq!(xq[1], 7);
        assert_eq!(xq[2], 7);
    }

    #[test]
    fn clip_shrinks_scale() {
        let x = [7.0f32, -7.0];
        let (_, s1) = dynamic_quant_step(&x, 1, 2, 4, 1.0);
        let (_, s2) = dynamic_quant_step(&x, 1, 2, 4, 0.5);
        assert!((s2[0] - 0.5 * s1[0]).abs() < 1e-7);
    }

    #[test]
    fn integral_and_in_range() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() * 10.0).collect();
        let (xq, _) = dynamic_quant_step(&x, 4, 64, 3, 1.0);
        assert!(xq.iter().all(|&q| (-3..=3).contains(&q)));
    }
}
