//! Statically-quantized INT8 KV cache: per-channel scales + the
//! integer-domain attention kernels (DESIGN.md §10).
//!
//! The KV cache is the MergeQuant thesis applied to attention state: all
//! scale math happens at **calibration time** (`python/compile`), the
//! `.qmod` bundle carries per-channel static scales, and decode adds zero
//! dynamic quantization passes — every runtime op below uses only
//! precomputed multipliers.
//!
//! Scale algebra (per layer, d = H·hd channels):
//!
//! * `k_scale[c] = absmax_c(K) / 127` — per-channel K quantizer;
//!   `K̂[t,c] = round(K[t,c] / k_scale[c])`.
//! * `v_scale[c] = absmax_c(V) / 127` — per-channel V quantizer.
//! * `qk_scale[h] = max_{c∈h} (absmax_c(Q) · k_scale[c]) / 127` — the
//!   per-head score scale. Q is quantized with the **K channel scales
//!   folded in**: `Q̂[c] = round(Q[c] · k_scale[c] / qk_scale[h])`, so
//!   the per-channel factors cancel inside the i8×i8 dot and
//!   `Q·Kᵀ ≈ dot_i8(Q̂, K̂) · qk_scale[h]` — the two static scales
//!   collapse into one scalar folded into the softmax pre-scale
//!   (`qk_scale[h] / √hd`), exactly the Eq.-5 shape: integer GEMM +
//!   scalar epilogue.
//! * `prob × V` accumulates `Σ_t p_t · V̂[t,c]` with the i8 values cast
//!   in the inner loop and applies `v_scale[c]` **once per output
//!   column** in the epilogue.

/// INT8 code range for the KV cache (symmetric, 8-bit).
pub const KV_QMAX: i32 = 127;

/// KV-cache element type. `F32` is the paper-parity baseline; `Int8`
/// stores K/V as per-channel statically-quantized int8 (4× smaller).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvDtype {
    /// Full-precision cache (seed behaviour, default).
    F32,
    /// Per-channel static INT8 cache (calibrated scales from the bundle).
    Int8,
}

impl KvDtype {
    /// Bytes per stored K or V element.
    pub fn bytes_per_elt(self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::Int8 => 1,
        }
    }

    /// Parse a config/CLI spelling (`"f32"` | `"int8"`).
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s {
            "f32" | "fp32" => Some(KvDtype::F32),
            "int8" | "i8" => Some(KvDtype::Int8),
            _ => None,
        }
    }

    /// Canonical config spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
        }
    }
}

/// Calibrated per-layer KV quantization scales, with every runtime
/// multiplier precomputed at load time (nothing on the decode path ever
/// divides or recomputes a scale).
#[derive(Clone, Debug)]
pub struct KvLayerScales {
    /// (d,) per-channel K scales (dequant multipliers).
    pub k_scale: Vec<f32>,
    /// (d,) precomputed `1 / k_scale` (K quantize multipliers).
    pub k_inv: Vec<f32>,
    /// (d,) per-channel V scales (the per-column PV epilogue).
    pub v_scale: Vec<f32>,
    /// (d,) precomputed `1 / v_scale` (V quantize multipliers).
    pub v_inv: Vec<f32>,
    /// (H,) per-head score scales `qk_scale[h]`.
    pub qk_scale: Vec<f32>,
    /// (d,) precomputed Q quantize multipliers `k_scale[c] / qk_scale[h(c)]`.
    pub q_mult: Vec<f32>,
}

impl KvLayerScales {
    /// Build from raw calibrated scales; `d = k_scale.len()` must be a
    /// multiple of `qk_scale.len()` (the head count).
    pub fn new(k_scale: Vec<f32>, v_scale: Vec<f32>, qk_scale: Vec<f32>)
               -> Self {
        let d = k_scale.len();
        let h = qk_scale.len();
        assert_eq!(v_scale.len(), d, "v_scale length");
        assert!(h > 0 && d % h == 0, "head count {h} must divide d {d}");
        let hd = d / h;
        let floor = |v: f32| if v > 1e-12 { v } else { 1e-12 };
        let k_scale: Vec<f32> = k_scale.into_iter().map(floor).collect();
        let v_scale: Vec<f32> = v_scale.into_iter().map(floor).collect();
        let qk_scale: Vec<f32> = qk_scale.into_iter().map(floor).collect();
        let k_inv = k_scale.iter().map(|s| 1.0 / s).collect();
        let v_inv = v_scale.iter().map(|s| 1.0 / s).collect();
        let q_mult = (0..d).map(|c| k_scale[c] / qk_scale[c / hd]).collect();
        KvLayerScales { k_scale, k_inv, v_scale, v_inv, qk_scale, q_mult }
    }

    /// Resident bytes of the scale payload (Table 3 accounting).
    pub fn resident_bytes(&self) -> usize {
        (self.k_scale.len() + self.k_inv.len() + self.v_scale.len()
            + self.v_inv.len() + self.qk_scale.len() + self.q_mult.len()) * 4
    }
}

/// Quantize one (d,) row with per-channel multipliers: `out[c] =
/// clamp(round(src[c] · mult[c]), ±127)`. Round-half-away semantics match
/// the weight pipeline (`f32::round`). Pure element-wise — no absmax
/// reduction, no scale computation: this is a *static* pass.
#[inline]
pub fn quantize_row_i8(src: &[f32], mult: &[f32], out: &mut [i8]) {
    for ((o, &x), &m) in out.iter_mut().zip(src).zip(mult) {
        *o = (x * m).round().clamp(-(KV_QMAX as f32), KV_QMAX as f32) as i8;
    }
}

/// Dequantize one (d,) int8 row with per-channel scales (tests / debug).
#[inline]
pub fn dequantize_row_i8(src: &[i8], scale: &[f32], out: &mut [f32]) {
    for ((o, &q), &s) in out.iter_mut().zip(src).zip(scale) {
        *o = q as f32 * s;
    }
}

/// One attention pass for a single query row over an **int8** cached K/V
/// region of length `klen` — the integer-domain mirror of the engine's
/// f32 `attend_one`. `q` is the f32 query row (d,); `kq`/`vq` are the
/// layer's int8 cache planes with row stride `cache_stride`; `out` is the
/// (d,) context row. `scores` and `qq` are caller scratch (so parallel
/// tasks keep private buffers; per-row math is order-fixed and therefore
/// bitwise identical for every thread count, DESIGN.md §7).
///
/// Per head: Q̂ = round(q · q_mult) once; scores via exact i8×i8→i32 dots
/// rescaled by the single folded scalar `qk_scale[h] / √hd`; softmax in
/// f32; context as `Σ_t p_t·V̂[t,c]` with the per-column `v_scale`
/// epilogue at the end.
#[allow(clippy::too_many_arguments)]
pub fn attend_one_i8(q: &[f32], kq: &[i8], vq: &[i8], sc: &KvLayerScales,
                     cache_stride: usize, klen: usize, n_heads: usize,
                     scores: &mut Vec<f32>, qq: &mut Vec<i8>,
                     out: &mut [f32]) {
    let d = q.len();
    let hd = d / n_heads;
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    scores.resize(klen, 0.0);
    qq.resize(hd, 0);
    let kern = crate::quant::simd::active();
    for head in 0..n_heads {
        let lo = head * hd;
        // Static Q quantization: per-channel multipliers precomputed at
        // load (k_scale folded in), one rounding pass per head.
        quantize_row_i8(&q[lo..lo + hd], &sc.q_mult[lo..lo + hd], qq);
        let pre = sc.qk_scale[head] * inv_sqrt;
        // scores: i8×i8 → i32, one scalar rescale (Eq. 5 shape)
        let mut maxv = f32::NEG_INFINITY;
        for t in 0..klen {
            let kh = &kq[t * cache_stride + lo..t * cache_stride + lo + hd];
            let s = kern.dot(qq, kh) as f32 * pre;
            scores[t] = s;
            maxv = maxv.max(s);
        }
        // softmax (f32, identical shape to the f32 path)
        let mut denom = 0f32;
        for s in scores[..klen].iter_mut() {
            *s = (*s - maxv).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        // prob × V: accumulate over int8 V, dequantize per-column once
        let oh = &mut out[lo..lo + hd];
        oh.fill(0.0);
        for t in 0..klen {
            let w = scores[t] * inv;
            let vh = &vq[t * cache_stride + lo..t * cache_stride + lo + hd];
            for c in 0..hd {
                oh[c] += w * vh[c] as f32;
            }
        }
        // per-column dequant epilogue
        for (o, &s) in oh.iter_mut().zip(&sc.v_scale[lo..lo + hd]) {
            *o *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let mut rng = Rng::new(7);
        let d = 64;
        let scale: Vec<f32> = (0..d).map(|_| 0.01 + rng.f32() * 0.2).collect();
        let inv: Vec<f32> = scale.iter().map(|s| 1.0 / s).collect();
        // values within the representable range |x| <= 127·s
        let x: Vec<f32> = (0..d)
            .map(|c| (rng.f32() * 2.0 - 1.0) * scale[c] * 127.0)
            .collect();
        let mut q = vec![0i8; d];
        quantize_row_i8(&x, &inv, &mut q);
        let mut back = vec![0f32; d];
        dequantize_row_i8(&q, &scale, &mut back);
        for c in 0..d {
            assert!((x[c] - back[c]).abs() <= scale[c] / 2.0 + 1e-6,
                    "channel {c}: {} vs {} (scale {})",
                    x[c], back[c], scale[c]);
        }
    }

    #[test]
    fn attend_i8_matches_f32_reference_closely() {
        // Build a tiny random K/V block, quantize it, and compare the
        // integer attention against an exact f32 attention on the
        // dequantized values — the only error left is Q quantization.
        let mut rng = Rng::new(11);
        let (h, hd, klen) = (2, 16, 9);
        let d = h * hd;
        let kf: Vec<f32> = (0..klen * d).map(|_| rng.normal()).collect();
        let vf: Vec<f32> = (0..klen * d).map(|_| rng.normal()).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let absmax = |xs: &[f32], c: usize| {
            (0..klen).fold(0f32, |a, t| a.max(xs[t * d + c].abs())).max(1e-3)
        };
        let k_scale: Vec<f32> =
            (0..d).map(|c| absmax(&kf, c) / 127.0).collect();
        let v_scale: Vec<f32> =
            (0..d).map(|c| absmax(&vf, c) / 127.0).collect();
        let qk: Vec<f32> = (0..h)
            .map(|hh| {
                (0..hd).fold(0f32, |a, i| {
                    let c = hh * hd + i;
                    a.max(q[c].abs() * k_scale[c])
                }) / 127.0
            })
            .collect();
        let sc = KvLayerScales::new(k_scale.clone(), v_scale.clone(), qk);
        let mut kq = vec![0i8; klen * d];
        let mut vq = vec![0i8; klen * d];
        for t in 0..klen {
            quantize_row_i8(&kf[t * d..(t + 1) * d], &sc.k_inv,
                            &mut kq[t * d..(t + 1) * d]);
            quantize_row_i8(&vf[t * d..(t + 1) * d], &sc.v_inv,
                            &mut vq[t * d..(t + 1) * d]);
        }
        let mut scores = Vec::new();
        let mut qqb = Vec::new();
        let mut got = vec![0f32; d];
        attend_one_i8(&q, &kq, &vq, &sc, d, klen, h, &mut scores, &mut qqb,
                      &mut got);
        // f32 reference on the *dequantized* K/V
        let mut kd = vec![0f32; klen * d];
        let mut vd = vec![0f32; klen * d];
        for t in 0..klen {
            dequantize_row_i8(&kq[t * d..(t + 1) * d], &sc.k_scale,
                              &mut kd[t * d..(t + 1) * d]);
            dequantize_row_i8(&vq[t * d..(t + 1) * d], &sc.v_scale,
                              &mut vd[t * d..(t + 1) * d]);
        }
        let mut want = vec![0f32; d];
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        for head in 0..h {
            let lo = head * hd;
            let mut sc_row = vec![0f32; klen];
            let mut maxv = f32::NEG_INFINITY;
            for t in 0..klen {
                let mut s = 0f32;
                for c in 0..hd {
                    s += q[lo + c] * kd[t * d + lo + c];
                }
                sc_row[t] = s * inv_sqrt;
                maxv = maxv.max(sc_row[t]);
            }
            let mut denom = 0f32;
            for s in sc_row.iter_mut() {
                *s = (*s - maxv).exp();
                denom += *s;
            }
            for t in 0..klen {
                let w = sc_row[t] / denom;
                for c in 0..hd {
                    want[lo + c] += w * vd[t * d + lo + c];
                }
            }
        }
        for c in 0..d {
            assert!((got[c] - want[c]).abs() < 0.05,
                    "channel {c}: {} vs {}", got[c], want[c]);
        }
    }

    #[test]
    fn dtype_parse_and_bytes() {
        assert_eq!(KvDtype::parse("int8"), Some(KvDtype::Int8));
        assert_eq!(KvDtype::parse("f32"), Some(KvDtype::F32));
        assert_eq!(KvDtype::parse("weird"), None);
        assert_eq!(KvDtype::Int8.bytes_per_elt(), 1);
        assert_eq!(KvDtype::F32.bytes_per_elt(), 4);
        assert_eq!(KvDtype::parse(KvDtype::Int8.as_str()), Some(KvDtype::Int8));
    }

}
