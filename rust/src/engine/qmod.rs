//! `.qmod` bundle loader — mirrors `python/compile/qmod.py` exactly.
//!
//! Weights arrive as (n, j) int8 from Python; the loader transposes them to
//! the engine's (j, n) layout and, for bit widths ≤ 4, packs them into
//! nibbles (`quant::pack`) so the resident format really is 4-bit.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::kv::KvLayerScales;
use crate::quant::pack::pack_int4;
use crate::util::json::Json;

const MAGIC: &[u8] = b"QMOD1\n";
const ALIGN: usize = 64;

#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Quantized weight in engine layout.
#[derive(Clone, Debug)]
pub struct QWeight {
    pub n: usize,
    pub j: usize,
    /// Transposed integer weights (j, n), one i8 per value…
    pub wt: Vec<i8>,
    /// …or packed nibbles (j, ceil(n/2)) when bits ≤ 4 (the hot format).
    pub packed: Option<Vec<u8>>,
    /// (G, j) scales, row-major; G = n/group (1 when group = 0).
    pub scale: Vec<f32>,
    /// (G, j) zero points (asymmetric only).
    pub zero: Option<Vec<i32>>,
    pub group: usize,
    pub bits: u32,
}

impl QWeight {
    pub fn ngroups(&self) -> usize {
        if self.group == 0 { 1 } else { self.n / self.group }
    }

    /// Resident bytes of the weight payload (Table 3 accounting).
    pub fn resident_bytes(&self) -> usize {
        let w = match &self.packed {
            Some(p) => p.len(),
            None => self.wt.len(),
        };
        w + self.scale.len() * 4
            + self.zero.as_ref().map_or(0, |z| z.len() * 4)
    }

    /// Dequantize to (j, n) f32 (tests / parity checks only).
    pub fn dequant_t(&self) -> Vec<f32> {
        let g = if self.group == 0 { self.n } else { self.group };
        let mut out = vec![0f32; self.j * self.n];
        for c in 0..self.j {
            for k in 0..self.n {
                let gi = k / g;
                let mut v = self.wt[c * self.n + k] as f32;
                if let Some(z) = &self.zero {
                    v -= z[gi * self.j + c] as f32;
                }
                out[c * self.n + k] = v * self.scale[gi * self.j + c];
            }
        }
        out
    }
}

#[derive(Clone, Debug)]
pub enum QuantMode {
    /// Input is already integer (merged-norm output) — paper Eq. 5 path.
    Static,
    /// SmoothQuant-style fixed scalar activation scale.
    TensorStatic { a_scale: f32, a_qmax: i32 },
    /// Per-input-channel *static* activation quantization — the full
    /// QSM W4A4 path for the o/down projections (format-3 bundles).
    /// `a_inv[c] = 1/s_c` are the calibrated quantize multipliers
    /// (Table 7 adaptive clipping baked into `s`); the matching
    /// dequant factors are **folded into the weight columns** at
    /// compile time (`Reconstruction.apply_to_weight`), so the
    /// runtime epilogue is the per-output-column Eq.-5 rescale alone
    /// — zero per-token scale math, like [`QuantMode::Static`].
    /// `recon_idx` is the optional dimension-reconstruction gather
    /// (Table 6 / paper App. C.1) applied to the quantized
    /// activations before the integer GEMM.
    ChannelStatic {
        a_inv: Vec<f32>,
        a_qmax: i32,
        recon_idx: Option<Vec<u32>>,
    },
    /// Per-token dynamic (the baseline, and out/down projections).
    Dynamic { a_qmax: i32, a_clip: f32, hadamard: bool },
}

impl QuantMode {
    /// Short stable name for banners, `inspect`, and the replica stats
    /// frame (the router reports it per replica so a mixed fleet is
    /// debuggable from the gateway).
    pub fn name(&self) -> &'static str {
        match self {
            QuantMode::Static => "static",
            QuantMode::TensorStatic { .. } => "tensor_static",
            QuantMode::ChannelStatic { .. } => "channel_static",
            QuantMode::Dynamic { hadamard: true, .. } => "dynamic+had",
            QuantMode::Dynamic { .. } => "dynamic",
        }
    }
}

#[derive(Clone, Debug)]
pub enum Linear {
    Fp { wt: Vec<f32>, n: usize, j: usize },
    Quant { qw: QWeight, mode: QuantMode },
}

impl Linear {
    pub fn dims(&self) -> (usize, usize) {
        match self {
            Linear::Fp { n, j, .. } => (*n, *j),
            Linear::Quant { qw, .. } => (qw.n, qw.j),
        }
    }

    pub fn resident_bytes(&self) -> usize {
        match self {
            Linear::Fp { wt, .. } => wt.len() * 4,
            Linear::Quant { qw, mode } => {
                let act = match mode {
                    QuantMode::ChannelStatic { a_inv, recon_idx, .. } => {
                        (a_inv.len()
                            + recon_idx.as_ref().map_or(0, Vec::len))
                            * 4
                    }
                    _ => 0,
                };
                qw.resident_bytes() + act
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct Norm {
    pub g: Vec<f32>,
    /// Some(qmax) ⇒ merged multiplier emits clamped integers (Eq. 4).
    pub quant_qmax: Option<i32>,
    /// Dimension-reconstruction gather indices (paper App. C.1).
    pub recon_idx: Option<Vec<u32>>,
}

#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_norm: Norm,
    pub q: Linear,
    pub k: Linear,
    pub v: Linear,
    pub o: Linear,
    pub ffn_norm: Norm,
    pub gate: Linear,
    pub up: Linear,
    pub down: Linear,
}

#[derive(Clone, Debug)]
pub struct QModel {
    pub config: ModelConfig,
    pub method: String,
    pub embed: Vec<f32>,       // (vocab, d)
    pub outlier_gain: Vec<f32>, // (d,)
    pub final_norm: Vec<f32>,  // (d,)
    pub lm_head_t: Vec<f32>,   // (vocab, d) transposed
    pub layers: Vec<LayerWeights>,
    /// Calibrated per-layer KV-cache scales (format-2 bundles; `None` for
    /// older bundles — the engine then refuses `kv_cache=int8` with a
    /// typed error rather than guessing scales).
    pub kv: Option<Vec<KvLayerScales>>,
}

struct Blob<'a> {
    meta: Json,
    data: &'a [u8],
}

impl<'a> Blob<'a> {
    fn tensor_entry(&self, name: &str) -> Result<(&Json, &'a [u8])> {
        let tensors = self.meta.req("tensors").map_err(anyhow::Error::msg)?;
        let entry = tensors
            .as_arr()
            .context("tensors not array")?
            .iter()
            .find(|t| t.get("name").and_then(Json::as_str) == Some(name))
            .with_context(|| format!("tensor {name} missing"))?;
        let off = entry.req_usize("offset").map_err(anyhow::Error::msg)?;
        let nbytes = entry.req_usize("nbytes").map_err(anyhow::Error::msg)?;
        Ok((entry, &self.data[off..off + nbytes]))
    }

    fn f32(&self, name: &str) -> Result<Vec<f32>> {
        let (entry, raw) = self.tensor_entry(name)?;
        ensure_dtype(entry, "f32")?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn i8(&self, name: &str) -> Result<Vec<i8>> {
        let (entry, raw) = self.tensor_entry(name)?;
        ensure_dtype(entry, "i8")?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }

    fn i16_as_i32(&self, name: &str) -> Result<Vec<i32>> {
        let (entry, raw) = self.tensor_entry(name)?;
        ensure_dtype(entry, "i16")?;
        Ok(raw
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes([c[0], c[1]]) as i32)
            .collect())
    }

    fn i32_as_u32(&self, name: &str) -> Result<Vec<u32>> {
        let (entry, raw) = self.tensor_entry(name)?;
        ensure_dtype(entry, "i32")?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u32)
            .collect())
    }

    fn shape(&self, name: &str) -> Result<Vec<usize>> {
        let (entry, _) = self.tensor_entry(name)?;
        Ok(entry
            .req("shape")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("shape not array")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect())
    }
}

fn ensure_dtype(entry: &Json, want: &str) -> Result<()> {
    let dt = entry.req_str("dtype").map_err(anyhow::Error::msg)?;
    if dt != want {
        bail!("dtype {dt} != {want}");
    }
    Ok(())
}

fn transpose_f32(w: &[f32], n: usize, j: usize) -> Vec<f32> {
    let mut out = vec![0f32; n * j];
    for r in 0..n {
        for c in 0..j {
            out[c * n + r] = w[r * j + c];
        }
    }
    out
}

fn transpose_i8(w: &[i8], n: usize, j: usize) -> Vec<i8> {
    let mut out = vec![0i8; n * j];
    for r in 0..n {
        for c in 0..j {
            out[c * n + r] = w[r * j + c];
        }
    }
    out
}

fn load_qweight(blob: &Blob, meta: &Json) -> Result<QWeight> {
    let wq_name = meta.req_str("wq").map_err(anyhow::Error::msg)?;
    let shape = blob.shape(wq_name)?;
    let (n, j) = (shape[0], shape[1]);
    let wq = blob.i8(wq_name)?;
    let wt = transpose_i8(&wq, n, j);
    let bits = meta.req_usize("bits").map_err(anyhow::Error::msg)? as u32;
    let group = meta.req_usize("group").map_err(anyhow::Error::msg)?;
    let scale = blob.f32(meta.req_str("scale").map_err(anyhow::Error::msg)?)?;
    let zero = match meta.get("zero").and_then(Json::as_str) {
        Some(zname) => Some(blob.i16_as_i32(zname)?),
        None => None,
    };
    // Pack to nibbles when values fit int4 (symmetric ≤4 bits, or shifted
    // asymmetric codes which lie in [-2^(b-1), 2^(b-1)-1] ⊆ [-8, 7]).
    let packed = if bits <= 4 {
        let row_bytes = n.div_ceil(2);
        let mut p = Vec::with_capacity(j * row_bytes);
        for c in 0..j {
            p.extend(pack_int4(&wt[c * n..(c + 1) * n]));
        }
        Some(p)
    } else {
        None
    };
    Ok(QWeight { n, j, wt, packed, scale, zero, group, bits })
}

fn load_linear(blob: &Blob, meta: &Json) -> Result<Linear> {
    match meta.req_str("mode").map_err(anyhow::Error::msg)? {
        "fp" => {
            let name = meta.req_str("w").map_err(anyhow::Error::msg)?;
            let shape = blob.shape(name)?;
            let w = blob.f32(name)?;
            Ok(Linear::Fp {
                wt: transpose_f32(&w, shape[0], shape[1]),
                n: shape[0],
                j: shape[1],
            })
        }
        "static" => Ok(Linear::Quant {
            qw: load_qweight(blob, meta.req("qw").map_err(anyhow::Error::msg)?)?,
            mode: QuantMode::Static,
        }),
        "tensor_static" => Ok(Linear::Quant {
            qw: load_qweight(blob, meta.req("qw").map_err(anyhow::Error::msg)?)?,
            mode: QuantMode::TensorStatic {
                a_scale: meta
                    .req("a_scale")
                    .map_err(anyhow::Error::msg)?
                    .as_f64()
                    .context("a_scale")? as f32,
                a_qmax: meta.req_usize("a_qmax").map_err(anyhow::Error::msg)?
                    as i32,
            },
        }),
        "channel_static" => {
            let qw =
                load_qweight(blob,
                             meta.req("qw").map_err(anyhow::Error::msg)?)?;
            let a_scale = blob
                .f32(meta.req_str("a_scale").map_err(anyhow::Error::msg)?)?;
            if a_scale.len() != qw.n {
                bail!("channel_static a_scale has {} channels, weight \
                       expects {}", a_scale.len(), qw.n);
            }
            let recon_idx = match meta.get("recon_idx").and_then(Json::as_str)
            {
                Some(name) => {
                    let idx = blob.i32_as_u32(name)?;
                    if idx.len() != qw.n {
                        bail!("channel_static recon_idx has {} entries, \
                               weight expects {}", idx.len(), qw.n);
                    }
                    if let Some(&bad) =
                        idx.iter().find(|&&v| v as usize >= a_scale.len())
                    {
                        bail!("channel_static recon_idx entry {bad} out of \
                               range (d={})", a_scale.len());
                    }
                    Some(idx)
                }
                None => None,
            };
            // Precompute the quantize multipliers once (nothing on the
            // decode path divides); floor degenerate scales like the KV
            // loader does.
            let a_inv =
                a_scale.iter().map(|s| 1.0 / s.max(1e-12)).collect();
            Ok(Linear::Quant {
                qw,
                mode: QuantMode::ChannelStatic {
                    a_inv,
                    a_qmax: meta
                        .req_usize("a_qmax")
                        .map_err(anyhow::Error::msg)?
                        as i32,
                    recon_idx,
                },
            })
        }
        "dynamic" => Ok(Linear::Quant {
            qw: load_qweight(blob, meta.req("qw").map_err(anyhow::Error::msg)?)?,
            mode: QuantMode::Dynamic {
                a_qmax: meta.req_usize("a_qmax").map_err(anyhow::Error::msg)?
                    as i32,
                a_clip: meta
                    .req("a_clip")
                    .map_err(anyhow::Error::msg)?
                    .as_f64()
                    .context("a_clip")? as f32,
                hadamard: meta
                    .get("hadamard")
                    .and_then(Json::as_bool)
                    .unwrap_or(false),
            },
        }),
        other => bail!("unknown linear mode {other}"),
    }
}

fn load_norm(blob: &Blob, meta: &Json) -> Result<Norm> {
    let g = blob.f32(meta.req_str("g").map_err(anyhow::Error::msg)?)?;
    let (quant_qmax, recon_idx) = match meta.get("quant") {
        Some(q) => {
            let qmax = q.req_usize("qmax").map_err(anyhow::Error::msg)? as i32;
            let idx = match q.get("recon_idx").and_then(Json::as_str) {
                Some(name) => Some(blob.i32_as_u32(name)?),
                None => None,
            };
            (Some(qmax), idx)
        }
        None => (None, None),
    };
    Ok(Norm { g, quant_qmax, recon_idx })
}

impl QModel {
    pub fn load(path: &Path) -> Result<QModel> {
        let raw = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if !raw.starts_with(MAGIC) {
            bail!("bad magic in {}", path.display());
        }
        if raw.len() < MAGIC.len() + 4 {
            bail!("truncated header in {}", path.display());
        }
        let mlen = u32::from_le_bytes(
            raw[MAGIC.len()..MAGIC.len() + 4].try_into().unwrap(),
        ) as usize;
        let meta_start = MAGIC.len() + 4;
        if raw.len() < meta_start + mlen {
            bail!("truncated metadata in {} ({} < {})", path.display(),
                  raw.len(), meta_start + mlen);
        }
        let meta: Json = Json::parse(
            std::str::from_utf8(&raw[meta_start..meta_start + mlen])
                .context("meta not utf8")?,
        )
        .map_err(anyhow::Error::msg)?;
        let mut base = meta_start + mlen;
        base += base.wrapping_neg() % ALIGN;
        let data = raw.get(base..).unwrap_or(&[]);

        let blob = Blob { meta: meta.clone(), data };
        let cfgj = meta.req("config").map_err(anyhow::Error::msg)?;
        let config = ModelConfig {
            name: cfgj.req_str("name").map_err(anyhow::Error::msg)?.into(),
            vocab: cfgj.req_usize("vocab").map_err(anyhow::Error::msg)?,
            d_model: cfgj.req_usize("d_model").map_err(anyhow::Error::msg)?,
            n_heads: cfgj.req_usize("n_heads").map_err(anyhow::Error::msg)?,
            d_ff: cfgj.req_usize("d_ff").map_err(anyhow::Error::msg)?,
            n_layers: cfgj.req_usize("n_layers").map_err(anyhow::Error::msg)?,
            max_seq: cfgj.req_usize("max_seq").map_err(anyhow::Error::msg)?,
            rope_theta: cfgj
                .req("rope_theta")
                .map_err(anyhow::Error::msg)?
                .as_f64()
                .context("rope_theta")? as f32,
        };
        let (v, d) = (config.vocab, config.d_model);
        let lm_head = blob.f32("lm_head")?; // (d, v)
        let mut layers = Vec::new();
        let mut kv_layers: Vec<KvLayerScales> = Vec::new();
        for lm in meta
            .req("layers")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .context("layers")?
        {
            // Optional per-layer calibrated KV scales (format 2).
            if let Some(kvm) = lm.get("kv") {
                let k_scale =
                    blob.f32(kvm.req_str("k_scale").map_err(anyhow::Error::msg)?)?;
                let v_scale =
                    blob.f32(kvm.req_str("v_scale").map_err(anyhow::Error::msg)?)?;
                let qk_scale =
                    blob.f32(kvm.req_str("qk_scale").map_err(anyhow::Error::msg)?)?;
                if k_scale.len() != d || v_scale.len() != d
                    || qk_scale.len() != config.n_heads
                {
                    bail!("kv scale shapes ({}, {}, {}) do not match \
                           d={d} heads={}", k_scale.len(), v_scale.len(),
                          qk_scale.len(), config.n_heads);
                }
                kv_layers.push(KvLayerScales::new(k_scale, v_scale, qk_scale));
            }
            layers.push(LayerWeights {
                attn_norm: load_norm(&blob, lm.req("attn_norm").map_err(anyhow::Error::msg)?)?,
                q: load_linear(&blob, lm.req("q").map_err(anyhow::Error::msg)?)?,
                k: load_linear(&blob, lm.req("k").map_err(anyhow::Error::msg)?)?,
                v: load_linear(&blob, lm.req("v").map_err(anyhow::Error::msg)?)?,
                o: load_linear(&blob, lm.req("o").map_err(anyhow::Error::msg)?)?,
                ffn_norm: load_norm(&blob, lm.req("ffn_norm").map_err(anyhow::Error::msg)?)?,
                gate: load_linear(&blob, lm.req("gate").map_err(anyhow::Error::msg)?)?,
                up: load_linear(&blob, lm.req("up").map_err(anyhow::Error::msg)?)?,
                down: load_linear(&blob, lm.req("down").map_err(anyhow::Error::msg)?)?,
            });
        }
        if !kv_layers.is_empty() && kv_layers.len() != layers.len() {
            bail!("kv scales on {} of {} layers (must be all or none)",
                  kv_layers.len(), layers.len());
        }
        Ok(QModel {
            config,
            method: meta.req_str("method").map_err(anyhow::Error::msg)?.into(),
            embed: blob.f32("embed")?,
            outlier_gain: blob.f32("outlier_gain")?,
            final_norm: blob.f32("final_norm")?,
            lm_head_t: transpose_f32(&lm_head, d, v),
            layers,
            kv: if kv_layers.is_empty() { None } else { Some(kv_layers) },
        })
    }

    /// Total resident weight bytes (Table 3 memory accounting).
    pub fn weight_bytes(&self) -> usize {
        let mut total = (self.embed.len()
            + self.outlier_gain.len()
            + self.final_norm.len()
            + self.lm_head_t.len())
            * 4;
        for l in &self.layers {
            total += (l.attn_norm.g.len() + l.ffn_norm.g.len()) * 4;
            total += l.attn_norm.recon_idx.as_ref().map_or(0, |r| r.len() * 4);
            total += l.ffn_norm.recon_idx.as_ref().map_or(0, |r| r.len() * 4);
            for lin in [&l.q, &l.k, &l.v, &l.o, &l.gate, &l.up, &l.down] {
                total += lin.resident_bytes();
            }
        }
        if let Some(kv) = &self.kv {
            total += kv.iter().map(|s| s.resident_bytes()).sum::<usize>();
        }
        total
    }

    /// The bundle's activation-quantization discipline, summarized by
    /// the mode of the hardest projection (`down` — the one the QSM
    /// variants differ on). `"fp"` for unquantized baselines.
    pub fn quant_mode_name(&self) -> &'static str {
        match self.layers.first().map(|l| &l.down) {
            Some(Linear::Quant { mode, .. }) => mode.name(),
            _ => "fp",
        }
    }

    /// Layer-truncated clone for the self-speculative draft lane
    /// (DESIGN.md §18): the same bundle — same embeddings, norms, LM
    /// head, quantized weights — with only the first `n_layers`
    /// transformer layers (and their KV scales). `0` means full depth
    /// (a pure self-draft whose greedy proposals always verify).
    /// Values above the real depth clamp to it.
    pub fn truncated(&self, n_layers: usize) -> QModel {
        let n = match n_layers {
            0 => self.config.n_layers,
            n => n.min(self.config.n_layers),
        };
        let mut m = self.clone();
        m.layers.truncate(n);
        if let Some(kv) = &mut m.kv {
            kv.truncate(n);
        }
        m.config.n_layers = n;
        m
    }
}
