//! Attention over cached K/V: f32 and int8-KV paths, plus the ragged
//! per-span fan-out used by the unified forward pass.
//!
//! Every query row is attended independently against its own sequence's
//! cached prefix (causal: row at absolute position `p` sees `p + 1`
//! cached entries). Per-row math is strictly sequential and identical in
//! the serial and parallel paths, so results are **bitwise identical**
//! for every thread count and both KV dtypes (DESIGN.md §7/§10) — and,
//! because rows never interact, for every ragged batch composition
//! (DESIGN.md §12).

use crate::quant::gemm::dot_f32;
use crate::quant::kv::{self, KvDtype, KvLayerScales};
use crate::quant::parallel::{ScopedTask, ThreadPool};

use super::cache::KvCache;
use super::qmod::ModelConfig;

/// Attention context of one row in a ragged batch: which lane's cache it
/// reads and how long the causal prefix is (its absolute position + 1).
#[derive(Clone, Copy, Debug)]
pub(super) struct RowAttn {
    pub lane: usize,
    pub klen: usize,
}

/// One attention head-batched pass for a single query row against a
/// cached f32 K/V region of length `klen`. q: (d,), out: (d,).
#[allow(clippy::too_many_arguments)]
fn attend_one(cfg: &ModelConfig, q: &[f32], kcache: &[f32], vcache: &[f32],
              cache_stride: usize, klen: usize, scores: &mut Vec<f32>,
              out: &mut [f32]) {
    let (h, hd) = (cfg.n_heads, cfg.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    scores.resize(klen, 0.0);
    for head in 0..h {
        let qh = &q[head * hd..(head + 1) * hd];
        // scores
        let mut maxv = f32::NEG_INFINITY;
        for t in 0..klen {
            let kh = &kcache[t * cache_stride + head * hd
                ..t * cache_stride + (head + 1) * hd];
            let s = dot_f32(qh, kh) * scale;
            scores[t] = s;
            maxv = maxv.max(s);
        }
        // softmax
        let mut denom = 0f32;
        for s in scores[..klen].iter_mut() {
            *s = (*s - maxv).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        // weighted value sum
        let oh = &mut out[head * hd..(head + 1) * hd];
        oh.fill(0.0);
        for t in 0..klen {
            let w = scores[t] * inv;
            let vh = &vcache[t * cache_stride + head * hd
                ..t * cache_stride + (head + 1) * hd];
            for c in 0..hd {
                oh[c] += w * vh[c];
            }
        }
    }
}

/// One query row attended over layer `l` of `cache`, dispatching on the
/// cache dtype: f32 storage runs the seed [`attend_one`], int8 storage
/// runs the integer-domain path (`quant::kv::attend_one_i8`). Both are
/// per-row order-fixed, so the §7 bitwise-determinism guarantee holds
/// for either dtype.
#[allow(clippy::too_many_arguments)]
pub(super) fn attend_cached(cfg: &ModelConfig, cache: &KvCache,
                            kvsc: Option<&[KvLayerScales]>, l: usize,
                            q: &[f32], klen: usize, scores: &mut Vec<f32>,
                            qq: &mut Vec<i8>, out: &mut [f32]) {
    match cache.dtype() {
        KvDtype::F32 => attend_one(cfg, q, cache.layer_k_f32(l),
                                   cache.layer_v_f32(l), cfg.d_model, klen,
                                   scores, out),
        KvDtype::Int8 => {
            let sc = &kvsc.expect("validated int8 KV scales")[l];
            kv::attend_one_i8(q, cache.layer_k_i8(l), cache.layer_v_i8(l),
                              sc, cfg.d_model, klen, cfg.n_heads, scores,
                              qq, out);
        }
    }
}

/// Attention for every row of a ragged batch: row `i` attends over
/// `caches[rows[i].lane]` with causal prefix `rows[i].klen`, writing its
/// (d,) output into `attn[i·d..]`.
///
/// Fan-out is over blocks of rows spanning span boundaries freely —
/// each task owns a disjoint slice of `attn` and private score buffers,
/// and per-row math is identical to the serial path, so results are
/// bitwise independent of the thread count for both KV dtypes. Blocks
/// are 4×-oversubscribed: rows attending longer prefixes (late prefill
/// rows, deep decode lanes) cost more, so equal-size blocks are unequal
/// work.
#[allow(clippy::too_many_arguments)]
pub(super) fn attend_batch(pool: &ThreadPool, cfg: &ModelConfig,
                           caches: &[&mut KvCache],
                           lane_scales: &[Option<&[KvLayerScales]>],
                           l: usize, qbuf: &[f32], rows: &[RowAttn],
                           scores: &mut Vec<f32>, qq: &mut Vec<i8>,
                           attn: &mut [f32]) {
    let d = cfg.d_model;
    let m = rows.len();
    if pool.threads() == 1 || m == 1 {
        for (i, r) in rows.iter().enumerate() {
            attend_cached(cfg, &caches[r.lane], lane_scales[r.lane], l,
                          &qbuf[i * d..(i + 1) * d], r.klen, scores, qq,
                          &mut attn[i * d..(i + 1) * d]);
        }
        return;
    }
    let block = m.div_ceil(pool.threads() * 4).max(1);
    let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
    for (bi, ablock) in attn[..m * d].chunks_mut(block * d).enumerate() {
        tasks.push(Box::new(move || {
            let mut scores = Vec::new();
            let mut qq = Vec::new();
            for (ri, arow) in ablock.chunks_mut(d).enumerate() {
                let i = bi * block + ri;
                let r = rows[i];
                attend_cached(cfg, &caches[r.lane], lane_scales[r.lane], l,
                              &qbuf[i * d..(i + 1) * d], r.klen,
                              &mut scores, &mut qq, arow);
            }
        }));
    }
    pool.run(tasks);
}
