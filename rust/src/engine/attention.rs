//! Attention over cached K/V: f32 and int8-KV paths, plus the ragged
//! per-span fan-out used by the unified forward pass.
//!
//! The cached prefix is **paged** (DESIGN.md §13): both dtype paths walk
//! it block-by-block — logical position `t` is row `t % B` of block
//! `t / B` — instead of over one contiguous plane. The per-row math and
//! the accumulation order over `t` are exactly the slab-layout ones, so
//! results are **bitwise identical** for every block size, every thread
//! count, and both KV dtypes (DESIGN.md §7/§10/§13) — and, because rows
//! never interact, for every ragged batch composition (DESIGN.md §12).
//!
//! Every query row is attended independently against its own sequence's
//! cached prefix (causal: row at absolute position `p` sees `p + 1`
//! cached entries).

use crate::quant::gemm::dot_f32;
use crate::quant::kv::{self, KvDtype, KvLayerScales};
use crate::quant::simd;
use crate::quant::parallel::{ScopedTask, ThreadPool};

use super::cache::KvCache;
use super::qmod::ModelConfig;

/// Attention context of one row in a ragged batch: which lane's cache it
/// reads and how long the causal prefix is (its absolute position + 1).
#[derive(Clone, Copy, Debug)]
pub(super) struct RowAttn {
    pub lane: usize,
    pub klen: usize,
}

/// One attention head-batched pass for a single query row against the
/// cached f32 K/V prefix of length `klen` in layer `l` of `cache`,
/// iterated block-by-block. q: (d,), out: (d,).
fn attend_one(cfg: &ModelConfig, q: &[f32], cache: &KvCache, l: usize,
              klen: usize, scores: &mut Vec<f32>, out: &mut [f32]) {
    let (h, hd, d) = (cfg.n_heads, cfg.head_dim(), cfg.d_model);
    let bt = cache.block_tokens();
    let scale = 1.0 / (hd as f32).sqrt();
    scores.resize(klen, 0.0);
    for head in 0..h {
        let lo = head * hd;
        let qh = &q[lo..lo + hd];
        // scores, ascending t via (block, row) — same order, same dots,
        // same bits as the contiguous-plane walk
        let mut maxv = f32::NEG_INFINITY;
        let (mut t0, mut b) = (0usize, 0usize);
        while t0 < klen {
            let rows = bt.min(klen - t0);
            let kp = cache.block_k_f32(b, l);
            for r in 0..rows {
                let kh = &kp[r * d + lo..r * d + lo + hd];
                let s = dot_f32(qh, kh) * scale;
                scores[t0 + r] = s;
                maxv = maxv.max(s);
            }
            t0 += rows;
            b += 1;
        }
        // softmax
        let mut denom = 0f32;
        for s in scores[..klen].iter_mut() {
            *s = (*s - maxv).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        // weighted value sum, again ascending t block-by-block
        let oh = &mut out[lo..lo + hd];
        oh.fill(0.0);
        let (mut t0, mut b) = (0usize, 0usize);
        while t0 < klen {
            let rows = bt.min(klen - t0);
            let vp = cache.block_v_f32(b, l);
            for r in 0..rows {
                let w = scores[t0 + r] * inv;
                let vh = &vp[r * d + lo..r * d + lo + hd];
                for c in 0..hd {
                    oh[c] += w * vh[c];
                }
            }
            t0 += rows;
            b += 1;
        }
    }
}

/// Integer-domain mirror of [`attend_one`] over an int8 cached prefix,
/// iterated block-by-block (the contiguous-plane reference kernel is
/// `quant::kv::attend_one_i8`; a slab cache is one block, and the paged
/// walk preserves the accumulation order over `t`, so the two are
/// bitwise identical — pinned directly by the
/// `paged_int8_attention_is_bitwise_the_reference_kernel` unit test
/// below, and exercised end-to-end in `tests/ragged_batch.rs`).
///
/// Per head: Q̂ = round(q · q_mult) once; scores via exact i8×i8→i32
/// dots rescaled by the single folded scalar `qk_scale[h] / √hd`;
/// softmax in f32; context as `Σ_t p_t·V̂[t,c]` with the per-column
/// `v_scale` epilogue at the end (DESIGN.md §10).
#[allow(clippy::too_many_arguments)]
fn attend_one_i8(cfg: &ModelConfig, q: &[f32], cache: &KvCache,
                 sc: &KvLayerScales, l: usize, klen: usize,
                 scores: &mut Vec<f32>, qq: &mut Vec<i8>, out: &mut [f32]) {
    let (h, hd, d) = (cfg.n_heads, cfg.head_dim(), cfg.d_model);
    let bt = cache.block_tokens();
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    scores.resize(klen, 0.0);
    qq.resize(hd, 0);
    let kern = simd::active();
    for head in 0..h {
        let lo = head * hd;
        // Static Q quantization: per-channel multipliers precomputed at
        // load (k_scale folded in), one rounding pass per head.
        kv::quantize_row_i8(&q[lo..lo + hd], &sc.q_mult[lo..lo + hd], qq);
        let pre = sc.qk_scale[head] * inv_sqrt;
        let mut maxv = f32::NEG_INFINITY;
        let (mut t0, mut b) = (0usize, 0usize);
        while t0 < klen {
            let rows = bt.min(klen - t0);
            let kp = cache.block_k_i8(b, l);
            for r in 0..rows {
                let kh = &kp[r * d + lo..r * d + lo + hd];
                let s = kern.dot(qq, kh) as f32 * pre;
                scores[t0 + r] = s;
                maxv = maxv.max(s);
            }
            t0 += rows;
            b += 1;
        }
        let mut denom = 0f32;
        for s in scores[..klen].iter_mut() {
            *s = (*s - maxv).exp();
            denom += *s;
        }
        let inv = 1.0 / denom;
        let oh = &mut out[lo..lo + hd];
        oh.fill(0.0);
        let (mut t0, mut b) = (0usize, 0usize);
        while t0 < klen {
            let rows = bt.min(klen - t0);
            let vp = cache.block_v_i8(b, l);
            for r in 0..rows {
                let w = scores[t0 + r] * inv;
                let vh = &vp[r * d + lo..r * d + lo + hd];
                for c in 0..hd {
                    oh[c] += w * vh[c] as f32;
                }
            }
            t0 += rows;
            b += 1;
        }
        // per-column dequant epilogue
        for (o, &s) in oh.iter_mut().zip(&sc.v_scale[lo..lo + hd]) {
            *o *= s;
        }
    }
}

/// One query row attended over layer `l` of `cache`, dispatching on the
/// cache dtype: f32 storage runs the seed [`attend_one`], int8 storage
/// runs the integer-domain path. Both are per-row order-fixed, so the §7
/// bitwise-determinism guarantee holds for either dtype and any block
/// size.
#[allow(clippy::too_many_arguments)]
pub(super) fn attend_cached(cfg: &ModelConfig, cache: &KvCache,
                            kvsc: Option<&[KvLayerScales]>, l: usize,
                            q: &[f32], klen: usize, scores: &mut Vec<f32>,
                            qq: &mut Vec<i8>, out: &mut [f32]) {
    match cache.dtype() {
        KvDtype::F32 => attend_one(cfg, q, cache, l, klen, scores, out),
        KvDtype::Int8 => {
            let sc = &kvsc.expect("validated int8 KV scales")[l];
            attend_one_i8(cfg, q, cache, sc, l, klen, scores, qq, out);
        }
    }
}

/// Attention for every row of a ragged batch: row `i` attends over
/// `caches[rows[i].lane]` with causal prefix `rows[i].klen`, writing its
/// (d,) output into `attn[i·d..]`.
///
/// Fan-out is over blocks of rows spanning span boundaries freely —
/// each task owns a disjoint slice of `attn` and private score buffers,
/// and per-row math is identical to the serial path, so results are
/// bitwise independent of the thread count for both KV dtypes. Blocks
/// are 4×-oversubscribed: rows attending longer prefixes (late prefill
/// rows, deep decode lanes) cost more, so equal-size blocks are unequal
/// work.
#[allow(clippy::too_many_arguments)]
pub(super) fn attend_batch(pool: &ThreadPool, cfg: &ModelConfig,
                           caches: &[&mut KvCache],
                           lane_scales: &[Option<&[KvLayerScales]>],
                           l: usize, qbuf: &[f32], rows: &[RowAttn],
                           scores: &mut Vec<f32>, qq: &mut Vec<i8>,
                           attn: &mut [f32]) {
    let d = cfg.d_model;
    let m = rows.len();
    if pool.threads() == 1 || m == 1 {
        for (i, r) in rows.iter().enumerate() {
            attend_cached(cfg, &caches[r.lane], lane_scales[r.lane], l,
                          &qbuf[i * d..(i + 1) * d], r.klen, scores, qq,
                          &mut attn[i * d..(i + 1) * d]);
        }
        return;
    }
    let block = m.div_ceil(pool.threads() * 4).max(1);
    let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
    for (bi, ablock) in attn[..m * d].chunks_mut(block * d).enumerate() {
        tasks.push(Box::new(move || {
            let mut scores = Vec::new();
            let mut qq = Vec::new();
            for (ri, arow) in ablock.chunks_mut(d).enumerate() {
                let i = bi * block + ri;
                let r = rows[i];
                attend_cached(cfg, &caches[r.lane], lane_scales[r.lane], l,
                              &qbuf[i * d..(i + 1) * d], r.klen,
                              &mut scores, &mut qq, arow);
            }
        }));
    }
    pool.run(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The §13 kernel-equivalence pin: the paged block-walking int8
    /// attention must reproduce the contiguous-plane reference kernel
    /// (`quant::kv::attend_one_i8`) bit for bit — including a block
    /// size that does not divide the prefix length, and a non-zero
    /// layer (the logical→physical plane offset).
    #[test]
    fn paged_int8_attention_is_bitwise_the_reference_kernel() {
        let (h, hd, klen, bt) = (2usize, 8usize, 13usize, 4usize);
        let d = h * hd;
        let n_layers = 2;
        let cfg = ModelConfig {
            name: "attn-test".into(),
            vocab: 16,
            d_model: d,
            n_heads: h,
            d_ff: 32,
            n_layers,
            max_seq: 64,
            rope_theta: 10_000.0,
        };
        let mut rng = Rng::new(23);
        let kf: Vec<f32> = (0..klen * d).map(|_| rng.normal()).collect();
        let vf: Vec<f32> = (0..klen * d).map(|_| rng.normal()).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let absmax = |xs: &[f32], c: usize| {
            (0..klen).fold(1e-3f32, |a, t| a.max(xs[t * d + c].abs()))
        };
        let k_scale: Vec<f32> =
            (0..d).map(|c| absmax(&kf, c) / 127.0).collect();
        let v_scale: Vec<f32> =
            (0..d).map(|c| absmax(&vf, c) / 127.0).collect();
        let qk: Vec<f32> = (0..h)
            .map(|hh| {
                (0..hd).fold(1e-6f32, |a, i| {
                    let c = hh * hd + i;
                    a.max(q[c].abs() * k_scale[c])
                }) / 127.0
            })
            .collect();
        let sc = KvLayerScales::new(k_scale, v_scale, qk);

        // Reference: contiguous planes quantized row by row.
        let mut kq = vec![0i8; klen * d];
        let mut vq = vec![0i8; klen * d];
        for t in 0..klen {
            kv::quantize_row_i8(&kf[t * d..(t + 1) * d], &sc.k_inv,
                                &mut kq[t * d..(t + 1) * d]);
            kv::quantize_row_i8(&vf[t * d..(t + 1) * d], &sc.v_inv,
                                &mut vq[t * d..(t + 1) * d]);
        }
        let mut scores = Vec::new();
        let mut qq = Vec::new();
        let mut want = vec![0f32; d];
        kv::attend_one_i8(&q, &kq, &vq, &sc, d, klen, h, &mut scores,
                          &mut qq, &mut want);

        // Paged: the same rows written through the block table (layer 0
        // gets decoy zeros so a plane-offset bug cannot cancel out),
        // attended at layer 1 with a block size that splits the prefix
        // 4+4+4+1.
        let mut cache =
            KvCache::paged(KvDtype::Int8, n_layers, klen + 3, d, bt);
        let zeros = vec![0f32; d];
        for t in 0..klen {
            cache.write(0, t, &zeros, &zeros, Some(&sc));
            cache.write(1, t, &kf[t * d..(t + 1) * d],
                        &vf[t * d..(t + 1) * d], Some(&sc));
        }
        cache.len = klen;
        let mut scores2 = Vec::new();
        let mut qq2 = Vec::new();
        let mut got = vec![0f32; d];
        attend_one_i8(&cfg, &q, &cache, &sc, 1, klen, &mut scores2,
                      &mut qq2, &mut got);
        let bits = |xs: &[f32]| -> Vec<u32> {
            xs.iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits(&got), bits(&want),
                   "paged int8 kernel diverged from the reference");
    }
}
