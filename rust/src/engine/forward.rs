//! The unified ragged-batch forward pass (DESIGN.md §12).
//!
//! One engine call per scheduler iteration: a [`BatchPlan`] describes a
//! ragged batch of per-sequence row **spans** — a 1-row decode lane and
//! a 256-row prefill chunk are the same thing, a span with a start
//! position (its cache's current length) and a token slice. Every layer
//! runs ONE merged-norm → integer-GEMM → epilogue pipeline over the
//! stacked rows of all spans; attention is dispatched per-span (causal
//! over each sequence's cached prefix, `engine::attention`); the final
//! norm + LM head run only over the rows each span asked logits for.
//!
//! Semantics mirror `python/compile/quant/qforward.py` exactly (validated
//! against the artifact goldens): same rounding, same clamp ranges, same
//! merged-norm → gather → integer-GEMM → epilogue pipeline. The static
//! MergeQuant path runs **zero** per-token quantization passes — the norm
//! emits integers (Eq. 4) and the epilogue is per-output-column (Eq. 5);
//! the dynamic baselines pay `quant::dynamic` passes per linear — exactly
//! the overhead the paper measures in Table 6.
//!
//! **Why stacking is bitwise safe:** every op in the pipeline is
//! per-row independent — the tiled kernels never split the reduction
//! dimension, rmsnorm/RoPE/SiLU/residual are row- or element-local, and
//! attention rows only read their own lane's cache. A row's values
//! therefore do not depend on `m`, on which other rows ride in the
//! batch, or on the thread count — the unified pass is bitwise
//! identical to the sequential seed `prefill` + `decode_batch` replay
//! (property-tested in `tests/ragged_batch.rs` across
//! {threads}×{kv dtype}).

use crate::quant::dynamic::per_token_quant;
use crate::quant::gemm::{gemm_i8_grouped, rowsum_i8};
use crate::quant::hadamard::fwht_block64;
use crate::quant::kv::{KvDtype, KvLayerScales};
use crate::quant::parallel::{par_gemm_f32, par_qlinear, ScopedTask,
                             ThreadPool};
use crate::quant::reconstruct::reconstruct_i8;

use super::attention::{attend_batch, RowAttn};
use super::cache::KvCache;
use super::model::Engine;
use super::qmod::{Linear, Norm, QuantMode, QWeight};

const EPS: f32 = 1e-5;

/// Typed engine failures. [`Engine::forward_batch`] validates *before*
/// touching any cache state, so an `Err` leaves every cache and the
/// workspace unmodified — the coordinator surfaces these as per-request
/// failures instead of dying on a panic (DESIGN.md §6).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// Writing position `pos` would exceed the cache's *logical*
    /// capacity `cap` (`max_seq` for serving caches) — a per-sequence
    /// limit. `lane` is the index of the offending span in the
    /// [`BatchPlan`] (for the `prefill`/`decode_batch` wrappers this
    /// coincides with the seed meaning: 0 for prefill, the batch lane
    /// for decode).
    KvOverflow { lane: usize, pos: usize, cap: usize },
    /// Writing position `pos` would run past the `reserved` tokens of
    /// block storage a pooled cache currently holds — a *pool*
    /// condition, distinct from the per-sequence [`KvOverflow`]: the
    /// coordinator reserves blocks from its shared `BlockPool` before
    /// every span, so seeing this error means the span was planned
    /// without covering its new tokens (DESIGN.md §13).
    ///
    /// [`KvOverflow`]: EngineError::KvOverflow
    KvExhausted { lane: usize, pos: usize, reserved: usize },
    /// An int8 KV cache was supplied but the bundle carries no calibrated
    /// KV scales (pre-format-2 `.qmod`).
    MissingKvScales,
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::KvOverflow { lane, pos, cap } => write!(
                f, "KV cache overflow on lane {lane}: position {pos} >= \
                    capacity {cap}"),
            EngineError::KvExhausted { lane, pos, reserved } => write!(
                f, "KV blocks exhausted on lane {lane}: position {pos} \
                    past the {reserved} reserved tokens"),
            EngineError::MissingKvScales => write!(
                f, "int8 KV cache requested but the bundle has no \
                    calibrated KV scales"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Reusable scratch buffers — no allocation on the decode hot path after
/// the first step. One row-stacked buffer set serves every batch shape:
/// prefill spans and decode lanes share the same (m, ·) buffers, sized
/// by the total row count of the ragged batch (DESIGN.md §12).
#[derive(Default)]
pub struct Workspace {
    pub x: Vec<f32>,        // residual stream (m, d)
    pub h: Vec<f32>,        // f32 norm output (m, d)
    pub hq: Vec<i8>,        // quantized norm output (m, d)
    pub hq2: Vec<i8>,       // reconstructed quantized activations (m, d)
    pub qbuf: Vec<f32>,     // q/k/v projections (m, d)
    pub kbuf: Vec<f32>,
    pub vbuf: Vec<f32>,
    pub attn: Vec<f32>,     // attention output (m, d)
    pub gate: Vec<f32>,     // (m, ff)
    pub up: Vec<f32>,
    pub ff: Vec<f32>,       // silu(gate)·up (m, ff)
    pub proj: Vec<f32>,     // o/down projection output (m, d)
    pub xq: Vec<i8>,        // dynamic-quant activation buffer
    pub row_scale: Vec<f32>,
    pub row_sum: Vec<i32>,
    pub had: Vec<f32>,      // hadamard-transformed activations
    pub scratch_w: Vec<i8>, // unpacked weight row
    pub scores: Vec<f32>,   // attention score row (≤ max cache len)
    pub qint: Vec<i8>,      // quantized query head (int8-KV attention)
    pub xsel: Vec<f32>,     // logit-row gather of the residual (sel, d)
    pub logits: Vec<f32>,   // (sel, vocab) — emitted rows only
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current resident bytes across all scratch buffers (Table 3).
    pub fn bytes(&self) -> usize {
        self.x.len() * 4
            + self.h.len() * 4
            + self.hq.len()
            + self.hq2.len()
            + (self.qbuf.len() + self.kbuf.len() + self.vbuf.len()) * 4
            + (self.attn.len() + self.gate.len() + self.up.len()
                + self.ff.len() + self.proj.len()) * 4
            + self.xq.len()
            + self.row_scale.len() * 4
            + self.row_sum.len() * 4
            + self.had.len() * 4
            + self.scratch_w.len()
            + self.scores.len() * 4
            + self.qint.len()
            + self.xsel.len() * 4
            + self.logits.len() * 4
    }
}

/// Which rows of a span contribute logits to `ws.logits`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanLogits {
    /// No output rows (a non-final prefill chunk).
    None,
    /// Only the span's last row (decode lanes, final prefill chunks).
    Last,
    /// Every row (the seed `prefill` contract — perplexity eval, parity
    /// tests).
    All,
}

/// One sequence's slice of a ragged batch: `len` consecutive token rows
/// appended to the cache at `lane`, starting at that cache's current
/// length.
#[derive(Clone, Debug)]
pub struct Span {
    /// Index into the `caches` slice passed to
    /// [`Engine::forward_batch`].
    pub lane: usize,
    /// Number of token rows (1 for a decode lane).
    pub len: usize,
    /// Which of this span's rows emit logits.
    pub logits: SpanLogits,
}

impl Span {
    /// Rows this span contributes to `ws.logits`.
    fn emitted(&self) -> usize {
        match self.logits {
            SpanLogits::None => 0,
            SpanLogits::Last => usize::from(self.len > 0),
            SpanLogits::All => self.len,
        }
    }
}

/// A ragged batch: the flat token stack plus one [`Span`] per
/// participating sequence. Built fresh each scheduler iteration — one
/// plan, one engine call (DESIGN.md §12).
#[derive(Debug, Default)]
pub struct BatchPlan {
    tokens: Vec<u32>,
    spans: Vec<Span>,
}

impl BatchPlan {
    /// An empty plan (no spans, no rows).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a span for `lane` running `tokens`. Empty token slices are
    /// ignored (a zero-row span computes nothing — seed `prefill(&[])`
    /// semantics).
    pub fn push_span(&mut self, lane: usize, tokens: &[u32],
                     logits: SpanLogits) {
        if tokens.is_empty() {
            return;
        }
        self.tokens.extend_from_slice(tokens);
        self.spans.push(Span { lane, len: tokens.len(), logits });
    }

    /// Speculative verify span (DESIGN.md §18): the lane's committed
    /// next token followed by `draft` proposed tokens, every row
    /// emitting logits. Row `i` of the span scores the token at
    /// position `start + i + 1` — row 0 is exactly the logits a plain
    /// decode step would emit, rows `1..=k` score each drafted
    /// continuation — so verifying k drafts costs ONE target forward
    /// instead of k. With an empty draft this degenerates to the plain
    /// decode span ([`SpanLogits::Last`]); the two are bitwise
    /// identical on row 0 by the batch-composition invariance property
    /// (`tests/ragged_batch.rs`), which is the whole reason greedy
    /// speculative streams match non-speculative goldens exactly.
    pub fn push_verify_span(&mut self, lane: usize, next: u32,
                            draft: &[u32]) {
        if draft.is_empty() {
            self.push_span(lane, &[next], SpanLogits::Last);
            return;
        }
        self.tokens.push(next);
        self.tokens.extend_from_slice(draft);
        self.spans.push(Span {
            lane,
            len: 1 + draft.len(),
            logits: SpanLogits::All,
        });
    }

    /// Total stacked rows across all spans.
    pub fn rows(&self) -> usize {
        self.tokens.len()
    }

    /// `true` when the plan has no spans.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// The spans, in row-stacking order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// The flat token stack (span order).
    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// Total logits rows the plan emits.
    pub fn emitted_rows(&self) -> usize {
        self.spans.iter().map(Span::emitted).sum()
    }

    /// Row range of span `span` inside `ws.logits` (in emitted-row
    /// units: multiply by `vocab` for element offsets). Empty for
    /// [`SpanLogits::None`] spans.
    pub fn logits_rows(&self, span: usize) -> std::ops::Range<usize> {
        let before: usize =
            self.spans[..span].iter().map(Span::emitted).sum();
        before..before + self.spans[span].emitted()
    }

    /// Global row indices (into the stacked (m, ·) buffers) that emit
    /// logits, in emission order.
    fn selected_rows(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.emitted_rows());
        let mut row = 0usize;
        for sp in &self.spans {
            match sp.logits {
                SpanLogits::None => {}
                SpanLogits::Last => out.push(row + sp.len - 1),
                SpanLogits::All => out.extend(row..row + sp.len),
            }
            row += sp.len;
        }
        out
    }
}

enum Act<'a> {
    F32(&'a [f32]),
    I8(&'a [i8]),
}

impl Engine {
    // ------------------------------------------------------------------
    // Primitive ops
    // ------------------------------------------------------------------

    fn rmsnorm_f32(x: &[f32], g: &[f32], m: usize, d: usize, out: &mut [f32]) {
        for i in 0..m {
            let row = &x[i * d..(i + 1) * d];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + EPS).sqrt();
            let or = &mut out[i * d..(i + 1) * d];
            for c in 0..d {
                or[c] = row[c] * inv * g[c];
            }
        }
    }

    /// Merged-multiplier norm emitting integers (Eq. 4), then the
    /// dimension-reconstruction gather (App. C.1). Result lands in `hq2`.
    fn rmsnorm_quant(x: &[f32], norm: &Norm, m: usize, d: usize,
                     hq: &mut [i8], hq2: &mut [i8]) {
        let qmax = norm.quant_qmax.unwrap() as f32;
        for i in 0..m {
            let row = &x[i * d..(i + 1) * d];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + EPS).sqrt();
            let qr = &mut hq[i * d..(i + 1) * d];
            for c in 0..d {
                let v = (row[c] * inv * norm.g[c]).round();
                qr[c] = v.clamp(-qmax, qmax) as i8;
            }
        }
        if let Some(idx) = &norm.recon_idx {
            reconstruct_i8(&hq[..m * d], idx, m, d, &mut hq2[..m * d]);
        } else {
            hq2[..m * d].copy_from_slice(&hq[..m * d]);
        }
    }

    /// Integer GEMM + rescale epilogue. Group-0 fast path goes through the
    /// fused tiled kernel (`quant::parallel::par_qlinear`): packed-int4
    /// weights when `m` amortizes the unpack, epilogue applied inside each
    /// tile so the i32 accumulator never hits memory. The grouped general
    /// path (Table 5 W3-group) stays serial.
    #[allow(clippy::too_many_arguments)]
    fn int_matmul(pool: &ThreadPool, qw: &QWeight, xq: &[i8], m: usize,
                  row_scale: Option<&[f32]>, rsum: &mut Vec<i32>,
                  scratch: &mut Vec<i8>, out: &mut [f32]) {
        let (n, j) = (qw.n, qw.j);
        if qw.group != 0 {
            gemm_i8_grouped(&xq[..m * n], &qw.wt, m, n, j, qw.group,
                            &qw.scale, qw.zero.as_deref(), row_scale,
                            &mut out[..m * j]);
            return;
        }
        let rowsum: Option<&[i32]> = match &qw.zero {
            Some(_) => {
                rowsum_i8(&xq[..m * n], m, n, rsum);
                Some(rsum.as_slice())
            }
            None => None,
        };
        par_qlinear(pool, &xq[..m * n], &qw.wt, qw.packed.as_deref(), m, n,
                    j, &qw.scale, qw.zero.as_deref(), rowsum, row_scale,
                    scratch, &mut out[..m * j]);
    }

    /// Apply one linear to m rows; writes (m, j) into `out`. Scratch
    /// buffers are passed individually so callers can split a Workspace.
    #[allow(clippy::too_many_arguments)]
    fn linear(pool: &ThreadPool, lin: &Linear, input: Act, m: usize,
              xqb: &mut Vec<i8>, rs: &mut Vec<f32>, rsum: &mut Vec<i32>,
              had: &mut Vec<f32>, scratch: &mut Vec<i8>, out: &mut [f32]) {
        match lin {
            Linear::Fp { wt, n, j } => {
                let x = match input {
                    Act::F32(x) => x,
                    Act::I8(_) => unreachable!("fp linear needs f32 input"),
                };
                par_gemm_f32(pool, &x[..m * n], wt, m, *n, *j,
                             &mut out[..m * j]);
            }
            Linear::Quant { qw, mode } => match mode {
                QuantMode::Static => {
                    let xq = match input {
                        Act::I8(xq) => xq,
                        Act::F32(_) => unreachable!("static linear needs i8"),
                    };
                    Self::int_matmul(pool, qw, xq, m, None, rsum, scratch,
                                     out);
                }
                QuantMode::TensorStatic { a_scale, a_qmax } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("tensor_static needs f32"),
                    };
                    let n = qw.n;
                    xqb.resize(m * n, 0);
                    let inv = 1.0 / *a_scale;
                    let qm = *a_qmax as f32;
                    for (q, &v) in xqb[..m * n].iter_mut().zip(&x[..m * n]) {
                        *q = (v * inv).round().clamp(-qm, qm) as i8;
                    }
                    rs.clear();
                    rs.resize(m, *a_scale);
                    Self::int_matmul(pool, qw, xqb, m, Some(rs), rsum,
                                     scratch, out);
                }
                QuantMode::ChannelStatic { a_inv, a_qmax, recon_idx } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("channel_static needs f32"),
                    };
                    let n = qw.n;
                    xqb.resize(m * n, 0);
                    let qm = *a_qmax as f32;
                    // Static per-channel quantize (multipliers
                    // precomputed at load — zero per-token scale math)
                    // with the dimension-reconstruction gather fused
                    // into the same pass: position k of the GEMM input
                    // is original channel idx[k], quantized with that
                    // channel's own scale (matches qforward.py's
                    // quantize-then-gather order element for element).
                    match recon_idx {
                        Some(idx) => {
                            for i in 0..m {
                                let row = &x[i * n..(i + 1) * n];
                                let qr = &mut xqb[i * n..(i + 1) * n];
                                for (q, &ix) in qr.iter_mut().zip(idx) {
                                    let c = ix as usize;
                                    let v = (row[c] * a_inv[c]).round();
                                    *q = v.clamp(-qm, qm) as i8;
                                }
                            }
                        }
                        None => {
                            for i in 0..m {
                                let row = &x[i * n..(i + 1) * n];
                                let qr = &mut xqb[i * n..(i + 1) * n];
                                for c in 0..n {
                                    let v = (row[c] * a_inv[c]).round();
                                    qr[c] = v.clamp(-qm, qm) as i8;
                                }
                            }
                        }
                    }
                    // The activation dequant factors are folded into
                    // the weight columns at compile time, so no row
                    // scale: integer GEMM + Eq.-5 column epilogue only.
                    Self::int_matmul(pool, qw, xqb, m, None, rsum,
                                     scratch, out);
                }
                QuantMode::Dynamic { a_qmax, a_clip, hadamard } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("dynamic needs f32"),
                    };
                    let n = qw.n;
                    let xin: &[f32] = if *hadamard {
                        had.resize(m * n, 0.0);
                        had[..m * n].copy_from_slice(&x[..m * n]);
                        fwht_block64(had, m, n);
                        &had[..m * n]
                    } else {
                        &x[..m * n]
                    };
                    // The explicit per-token Quant pass (Table 6 cost).
                    xqb.resize(m * n, 0);
                    rs.resize(m, 0.0);
                    per_token_quant(xin, m, n, *a_qmax, *a_clip, xqb, rs);
                    Self::int_matmul(pool, qw, xqb, m, Some(rs), rsum,
                                     scratch, out);
                }
            },
        }
    }

    fn embed(&self, tokens: &[u32], out: &mut Vec<f32>) {
        let d = self.model.config.d_model;
        out.resize(tokens.len() * d, 0.0);
        for (i, &t) in tokens.iter().enumerate() {
            let row = &self.model.embed[t as usize * d..(t as usize + 1) * d];
            let or = &mut out[i * d..(i + 1) * d];
            for c in 0..d {
                or[c] = row[c] * self.model.outlier_gain[c];
            }
        }
    }

    /// RoPE in place on a (m, d) buffer interpreted as (m, H, hd);
    /// `positions[i]` is the absolute position of row i.
    fn rope(&self, buf: &mut [f32], m: usize, positions: &[usize]) {
        let cfg = &self.model.config;
        let (h, hd, d) = (cfg.n_heads, cfg.head_dim(), cfg.d_model);
        let theta = cfg.rope_theta;
        // The frequency depends only on the pair index p — hoist the
        // powf out of the (m × H) loops. Same inputs, so results stay
        // bitwise identical to the per-element form.
        let half = hd / 2;
        let inv_freq: Vec<f32> = (0..half)
            .map(|p| theta.powf(-(2.0 * p as f32) / hd as f32))
            .collect();
        for i in 0..m {
            let pos = positions[i] as f32;
            let row = &mut buf[i * d..(i + 1) * d];
            for head in 0..h {
                let hr = &mut row[head * hd..(head + 1) * hd];
                for p in 0..half {
                    let ang = pos * inv_freq[p];
                    let (sin, cos) = ang.sin_cos();
                    let a = hr[2 * p];
                    let b = hr[2 * p + 1];
                    hr[2 * p] = a * cos - b * sin;
                    hr[2 * p + 1] = a * sin + b * cos;
                }
            }
        }
    }

    /// Resolve the KV scales a cache needs: `None` for f32 storage, the
    /// bundle's calibrated per-layer scales for int8 —
    /// [`EngineError::MissingKvScales`] when the bundle has none.
    pub(super) fn kv_scales_for<'m>(&'m self, cache: &KvCache)
                                    -> Result<Option<&'m [KvLayerScales]>,
                                              EngineError> {
        match cache.dtype() {
            KvDtype::F32 => Ok(None),
            KvDtype::Int8 => self
                .model
                .kv
                .as_deref()
                .map(Some)
                .ok_or(EngineError::MissingKvScales),
        }
    }

    // ------------------------------------------------------------------
    // The unified ragged forward pass
    // ------------------------------------------------------------------

    /// Run one ragged batch: every span's token rows ride the same
    /// per-layer pipeline, attention fans out per span over each lane's
    /// cached prefix, and `ws.logits` receives `(plan.emitted_rows(),
    /// vocab)` — the rows each span selected, in span order (use
    /// [`BatchPlan::logits_rows`] to locate a span's slice).
    ///
    /// Each span appends `span.len` positions to `caches[span.lane]`
    /// starting at its current length — chunked prefill, whole-prompt
    /// admission, multi-turn continuation and single-token decode are
    /// all the same operation. Lanes must be pairwise distinct; lanes
    /// may mix KV dtypes.
    ///
    /// Capacity and KV-scale availability are validated for **every**
    /// span before any state is touched: an `Err` (naming the offending
    /// span index as `lane`) leaves all caches and `ws` unchanged, so
    /// the caller can drop the offending span and retry the rest.
    pub fn forward_batch(&self, plan: &BatchPlan,
                         caches: &mut [&mut KvCache], ws: &mut Workspace)
                         -> Result<(), EngineError> {
        let cfg = &self.model.config;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let spans = plan.spans();
        let m = plan.rows();
        if m == 0 {
            ws.logits.clear();
            return Ok(());
        }
        // Lanes must be in range and pairwise distinct — two spans
        // appending to the same cache in one call is a plan-construction
        // bug, not a runtime condition.
        for (si, sp) in spans.iter().enumerate() {
            assert!(sp.lane < caches.len(),
                    "span {si}: lane {} out of range ({} caches)",
                    sp.lane, caches.len());
            for other in &spans[si + 1..] {
                assert_ne!(sp.lane, other.lane,
                           "duplicate lane {} in BatchPlan", sp.lane);
            }
        }
        // Validate everything before touching any state (seed contract):
        // capacity for every span first — the per-sequence logical cap,
        // then the block reservation for pooled caches (auto-grow caches
        // allocate their own blocks at write time) — then KV scales for
        // every lane.
        let mut starts = Vec::with_capacity(spans.len());
        for (si, sp) in spans.iter().enumerate() {
            let c = &caches[sp.lane];
            let end = c.len + sp.len;
            if end > c.cap {
                return Err(EngineError::KvOverflow {
                    lane: si,
                    pos: end - 1,
                    cap: c.cap,
                });
            }
            if !c.auto_grow() && end > c.held_tokens() {
                return Err(EngineError::KvExhausted {
                    lane: si,
                    pos: end - 1,
                    reserved: c.held_tokens(),
                });
            }
            // Prefix sharing: every block this span writes must be
            // uniquely owned by now — the scheduler copies-on-write the
            // shared boundary block *before* building the plan, so a
            // shared block in the write range is a coordinator bug, not
            // a runtime condition.
            assert!(!c.write_range_shared(c.len, end),
                    "span {si}: write into shared KV block (CoW missed)");
            starts.push(c.len);
        }
        let mut lane_scales: Vec<Option<&[KvLayerScales]>> =
            vec![None; caches.len()];
        for sp in spans {
            lane_scales[sp.lane] = self.kv_scales_for(&caches[sp.lane])?;
        }

        // Per-row absolute position and attention context, fixed for the
        // whole call (every layer sees the same ragged geometry).
        let mut positions = Vec::with_capacity(m);
        let mut rows = Vec::with_capacity(m);
        for (si, sp) in spans.iter().enumerate() {
            for i in 0..sp.len {
                positions.push(starts[si] + i);
                rows.push(RowAttn { lane: sp.lane, klen: starts[si] + i + 1 });
            }
        }

        self.embed(plan.tokens(), &mut ws.x);
        ws.qbuf.resize(m * d, 0.0);
        ws.kbuf.resize(m * d, 0.0);
        ws.vbuf.resize(m * d, 0.0);
        ws.attn.resize(m * d, 0.0);
        ws.gate.resize(m * ff, 0.0);
        ws.up.resize(m * ff, 0.0);
        ws.ff.resize(m * ff, 0.0);
        ws.proj.resize(m * d, 0.0);

        for (l, layer) in self.model.layers.iter().enumerate() {
            // ---- attention ----
            if layer.attn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.attn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&self.pool, &layer.q, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&self.pool, &layer.k, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&self.pool, &layer.v, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.attn_norm.g, m, d, &mut ws.h);
                Self::linear(&self.pool, &layer.q, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&self.pool, &layer.k, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&self.pool, &layer.v, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            }
            self.rope(&mut ws.qbuf, m, &positions);
            self.rope(&mut ws.kbuf, m, &positions);
            // KV writes, span by span (each span owns its lane's
            // positions — distinct lanes make the writes disjoint).
            let mut row = 0usize;
            for (si, sp) in spans.iter().enumerate() {
                let cache = &mut caches[sp.lane];
                for i in 0..sp.len {
                    let r = row + i;
                    cache.write(l, starts[si] + i,
                                &ws.kbuf[r * d..(r + 1) * d],
                                &ws.vbuf[r * d..(r + 1) * d],
                                lane_scales[sp.lane].map(|s| &s[l]));
                }
                row += sp.len;
            }
            // Causal attention, per-span over cached K/V (parallel
            // across row blocks; bitwise thread- and batch-composition-
            // invariant — engine::attention).
            attend_batch(&self.pool, cfg, &*caches, &lane_scales, l,
                         &ws.qbuf, &rows, &mut ws.scores, &mut ws.qint,
                         &mut ws.attn[..m * d]);
            Self::linear(&self.pool, &layer.o, Act::F32(&ws.attn), m,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
            // ---- ffn ----
            if layer.ffn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.ffn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&self.pool, &layer.gate, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&self.pool, &layer.up, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.ffn_norm.g, m, d, &mut ws.h);
                Self::linear(&self.pool, &layer.gate, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&self.pool, &layer.up, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            }
            // SiLU·up — elementwise, parallel over row blocks (exp() is
            // a real fraction of prefill at small d). Elementwise, so
            // the fan-out threshold cannot change bits.
            if self.pool.threads() == 1 || m * ff < (1 << 15) {
                for i in 0..m * ff {
                    let g = ws.gate[i];
                    ws.ff[i] = g / (1.0 + (-g).exp()) * ws.up[i];
                }
            } else {
                let rows_per = m.div_ceil(self.pool.threads() * 2).max(1);
                let gb = &ws.gate;
                let ub = &ws.up;
                let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
                for (bi, fblock) in
                    ws.ff[..m * ff].chunks_mut(rows_per * ff).enumerate()
                {
                    tasks.push(Box::new(move || {
                        let off = bi * rows_per * ff;
                        for (k, fv) in fblock.iter_mut().enumerate() {
                            let g = gb[off + k];
                            *fv = g / (1.0 + (-g).exp()) * ub[off + k];
                        }
                    }));
                }
                self.pool.run(tasks);
            }
            Self::linear(&self.pool, &layer.down, Act::F32(&ws.ff), m,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
        }
        for (si, sp) in spans.iter().enumerate() {
            caches[sp.lane].len = starts[si] + sp.len;
        }
        // Final norm + LM head over the selected rows only: per-row math
        // is identical whichever rows are present, so skipping the
        // non-emitting prefill rows cannot change the emitted values —
        // it only skips the (rows × vocab) GEMM work the caller never
        // asked for.
        let sel = plan.selected_rows();
        let nsel = sel.len();
        ws.xsel.resize(nsel * d, 0.0);
        for (k, &r) in sel.iter().enumerate() {
            ws.xsel[k * d..(k + 1) * d]
                .copy_from_slice(&ws.x[r * d..(r + 1) * d]);
        }
        ws.h.resize(nsel * d, 0.0);
        Self::rmsnorm_f32(&ws.xsel, &self.model.final_norm, nsel, d,
                          &mut ws.h);
        ws.logits.resize(nsel * vocab, 0.0);
        par_gemm_f32(&self.pool, &ws.h, &self.model.lm_head_t, nsel, d,
                     vocab, &mut ws.logits);
        Ok(())
    }
}
