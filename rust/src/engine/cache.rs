//! Per-sequence KV caches with dtype-parametric storage.
//!
//! [`KvCache`] is the unit the scheduler's slab pool hands out: a
//! contiguous (L, cap, d) K/V plane pair per sequence, stored either in
//! f32 (the seed layout) or statically-quantized int8 (4× smaller, the
//! Table-3 scaling story — DESIGN.md §10). Quantization happens at write
//! time with the bundle's calibrated per-channel scales; the integer
//! attention path reads the int8 planes directly (`engine::attention`).

use crate::quant::kv::{self, KvDtype, KvLayerScales};

/// Dtype-parametric K/V storage: contiguous (L, cap, d) planes either in
/// f32 (seed layout) or statically-quantized int8 (4× smaller).
enum KvStore {
    F32 { k: Vec<f32>, v: Vec<f32> },
    I8 { k: Vec<i8>, v: Vec<i8> },
}

/// Per-sequence KV cache: layout (L, cap, d) with d = H·hd. Storage is
/// dtype-parametric ([`KvDtype`]): `F32` keeps the full-precision seed
/// behaviour, `Int8` stores per-channel statically-quantized values (the
/// engine quantizes at write time with the bundle's calibrated scales and
/// attends in the integer domain — `quant::kv`).
pub struct KvCache {
    store: KvStore,
    pub cap: usize,
    pub len: usize,
    pub n_layers: usize,
    d: usize,
}

impl KvCache {
    /// Full-precision cache (seed-compatible default).
    pub fn new(n_layers: usize, cap: usize, d: usize) -> Self {
        Self::with_dtype(KvDtype::F32, n_layers, cap, d)
    }

    /// Cache with an explicit storage dtype.
    pub fn with_dtype(dtype: KvDtype, n_layers: usize, cap: usize, d: usize)
                      -> Self {
        let n = n_layers * cap * d;
        let store = match dtype {
            KvDtype::F32 => KvStore::F32 { k: vec![0f32; n], v: vec![0f32; n] },
            KvDtype::Int8 => KvStore::I8 { k: vec![0i8; n], v: vec![0i8; n] },
        };
        KvCache { store, cap, len: 0, n_layers, d }
    }

    /// Storage element type of this cache.
    pub fn dtype(&self) -> KvDtype {
        match self.store {
            KvStore::F32 { .. } => KvDtype::F32,
            KvStore::I8 { .. } => KvDtype::Int8,
        }
    }

    #[inline]
    fn plane(&self, l: usize) -> std::ops::Range<usize> {
        l * self.cap * self.d..(l + 1) * self.cap * self.d
    }

    #[inline]
    pub(super) fn layer_k_f32(&self, l: usize) -> &[f32] {
        match &self.store {
            KvStore::F32 { k, .. } => &k[self.plane(l)],
            KvStore::I8 { .. } => unreachable!("f32 view of int8 KV cache"),
        }
    }

    #[inline]
    pub(super) fn layer_v_f32(&self, l: usize) -> &[f32] {
        match &self.store {
            KvStore::F32 { v, .. } => &v[self.plane(l)],
            KvStore::I8 { .. } => unreachable!("f32 view of int8 KV cache"),
        }
    }

    #[inline]
    pub(super) fn layer_k_i8(&self, l: usize) -> &[i8] {
        match &self.store {
            KvStore::I8 { k, .. } => &k[self.plane(l)],
            KvStore::F32 { .. } => unreachable!("int8 view of f32 KV cache"),
        }
    }

    #[inline]
    pub(super) fn layer_v_i8(&self, l: usize) -> &[i8] {
        match &self.store {
            KvStore::I8 { v, .. } => &v[self.plane(l)],
            KvStore::F32 { .. } => unreachable!("int8 view of f32 KV cache"),
        }
    }

    /// Store one K/V row, quantizing on the way in for int8 storage.
    /// Callers (the unified forward pass) validate capacity and scale
    /// availability up front and return `EngineError` — by the time a
    /// write happens it cannot fail.
    #[inline]
    pub(super) fn write(&mut self, l: usize, pos: usize, k_row: &[f32],
                        v_row: &[f32], scales: Option<&KvLayerScales>) {
        debug_assert!(pos < self.cap,
                      "KV write past validated capacity: {pos} >= {}",
                      self.cap);
        let d = self.d;
        let off = l * self.cap * d + pos * d;
        match &mut self.store {
            KvStore::F32 { k, v } => {
                k[off..off + d].copy_from_slice(k_row);
                v[off..off + d].copy_from_slice(v_row);
            }
            KvStore::I8 { k, v } => {
                let sc = scales.expect("int8 KV write validated scales");
                kv::quantize_row_i8(k_row, &sc.k_inv, &mut k[off..off + d]);
                kv::quantize_row_i8(v_row, &sc.v_inv, &mut v[off..off + d]);
            }
        }
    }

    /// Resident bytes of the K/V planes (Table 3 accounting): 4 bytes per
    /// element for f32 storage, 1 for int8.
    pub fn bytes(&self) -> usize {
        match &self.store {
            KvStore::F32 { k, v } => (k.len() + v.len()) * 4,
            KvStore::I8 { k, v } => k.len() + v.len(),
        }
    }

    /// Forget the cached prefix (storage is retained and overwritten).
    pub fn reset(&mut self) {
        self.len = 0;
    }
}
