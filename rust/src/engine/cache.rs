//! Per-sequence paged KV caches with dtype-parametric storage.
//!
//! Storage is **block-granular** (DESIGN.md §13): a [`KvBlock`] holds
//! `block_tokens` K/V rows for *all* layers — layout (L, B, d) per plane,
//! in f32 (the seed layout) or statically-quantized int8 (4× smaller,
//! the Table-3 scaling story). A [`KvCache`] is a *block table*: logical
//! token position `t` lives in block `t / B` at row `t % B`, so a
//! sequence only ever holds storage proportional to its actual length
//! rounded up to one block — the serving-side complement to the
//! quantization memory savings.
//!
//! Three cache modes share one type:
//! * **auto-grow slab** ([`KvCache::with_dtype`]): one eagerly-allocated
//!   block of `B == cap` tokens — byte-for-byte the pre-paging slab
//!   layout, used by the engine-level tests/benches/`generate` paths;
//! * **auto-grow paged** ([`KvCache::paged`]): blocks self-allocated
//!   lazily as `len` crosses a block boundary (engine-level paged runs);
//! * **pooled** ([`KvCache::pooled`]): blocks come exclusively from the
//!   coordinator's shared [`BlockPool`](crate::coordinator::BlockPool)
//!   via [`KvCache::push_block`]; writing past the reserved blocks is a
//!   validated engine error, never an allocation.
//!
//! Quantization happens at write time with the bundle's calibrated
//! per-channel scales; the integer attention path reads the int8 planes
//! directly (`engine::attention`).
//!
//! **Prefix sharing (DESIGN.md §14):** pooled block tables hold
//! `Arc<KvBlock>`, so N sequences whose prompts share a frozen prefix
//! can map the shared region of their tables onto the same physical
//! blocks. Attention only ever reads blocks, so sharing is invisible to
//! the compute path; writes demand unique ownership
//! ([`std::sync::Arc::get_mut`]) and the scheduler copies-on-write the
//! single partially-filled boundary block before any write can land in
//! a shared one ([`KvCache::cow_boundary`]). A write reaching a shared
//! block is a bug, not a recoverable error — it panics.

use std::sync::Arc;

use crate::quant::kv::{self, KvDtype, KvLayerScales};

/// Dtype-parametric K/V plane pair of one block: (L, B, d) each.
enum BlockStore {
    F32 { k: Vec<f32>, v: Vec<f32> },
    I8 { k: Vec<i8>, v: Vec<i8> },
}

/// One physical KV block: `block_tokens` K/V rows for every layer.
/// Blocks are the unit the coordinator's `BlockPool` hands out and
/// reclaims; outside the pool they are plain owned storage, so disjoint
/// per-sequence access needs no `unsafe`.
pub struct KvBlock {
    store: BlockStore,
}

impl KvBlock {
    /// A zeroed block of `block_tokens` rows × `n_layers` layers × `d`
    /// channels per plane.
    pub fn new(dtype: KvDtype, n_layers: usize, block_tokens: usize,
               d: usize) -> Self {
        let n = n_layers * block_tokens * d;
        let store = match dtype {
            KvDtype::F32 => BlockStore::F32 { k: vec![0f32; n],
                                              v: vec![0f32; n] },
            KvDtype::Int8 => BlockStore::I8 { k: vec![0i8; n],
                                              v: vec![0i8; n] },
        };
        KvBlock { store }
    }

    /// Storage element type of this block.
    pub fn dtype(&self) -> KvDtype {
        match self.store {
            BlockStore::F32 { .. } => KvDtype::F32,
            BlockStore::I8 { .. } => KvDtype::Int8,
        }
    }

    /// Elements per plane (`n_layers · block_tokens · d`).
    pub fn plane_elts(&self) -> usize {
        match &self.store {
            BlockStore::F32 { k, .. } => k.len(),
            BlockStore::I8 { k, .. } => k.len(),
        }
    }

    /// Resident bytes of the K/V planes (Table 3 accounting).
    pub fn bytes(&self) -> usize {
        match &self.store {
            BlockStore::F32 { k, v } => (k.len() + v.len()) * 4,
            BlockStore::I8 { k, v } => k.len() + v.len(),
        }
    }

    /// Copy the first `rows` K/V rows of every layer plane from `src`
    /// into `self` — the copy-on-write step for a partially-filled
    /// boundary block. Copying int8 planes verbatim preserves the
    /// already-quantized values bit-for-bit, so a CoW'd prefix stays
    /// bitwise identical to the shared original.
    fn copy_rows_from(&mut self, src: &KvBlock, rows: usize,
                      n_layers: usize, block_tokens: usize, d: usize) {
        let span = rows * d;
        match (&mut self.store, &src.store) {
            (BlockStore::F32 { k, v }, BlockStore::F32 { k: sk, v: sv }) => {
                for l in 0..n_layers {
                    let base = l * block_tokens * d;
                    k[base..base + span]
                        .copy_from_slice(&sk[base..base + span]);
                    v[base..base + span]
                        .copy_from_slice(&sv[base..base + span]);
                }
            }
            (BlockStore::I8 { k, v }, BlockStore::I8 { k: sk, v: sv }) => {
                for l in 0..n_layers {
                    let base = l * block_tokens * d;
                    k[base..base + span]
                        .copy_from_slice(&sk[base..base + span]);
                    v[base..base + span]
                        .copy_from_slice(&sv[base..base + span]);
                }
            }
            _ => panic!("CoW between mismatched KV dtypes"),
        }
    }
}

/// How a cache obtains (and gives back) its blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum CacheMode {
    /// Self-allocates blocks on write; never exhausts below `cap`.
    AutoGrow,
    /// Blocks are reserved by the coordinator's `BlockPool`; writing
    /// past them is a validated [`EngineError::KvExhausted`]
    /// (`crate::engine::EngineError`).
    Pooled,
    /// A pooled cache whose blocks were returned; giving it back again
    /// is a double free.
    Released,
}

/// Per-sequence KV cache: a block table over (L, B, d) K/V blocks with
/// `d = H·hd`. Storage is dtype-parametric ([`KvDtype`]): `F32` keeps
/// the full-precision seed behaviour, `Int8` stores per-channel
/// statically-quantized values (the engine quantizes at write time with
/// the bundle's calibrated scales and attends in the integer domain —
/// `quant::kv`).
pub struct KvCache {
    blocks: Vec<Arc<KvBlock>>,
    block_tokens: usize,
    /// Logical capacity in tokens (`max_seq` for serving caches).
    pub cap: usize,
    /// Tokens written so far (the causal prefix length).
    pub len: usize,
    /// Layer count L (every block carries all layers).
    pub n_layers: usize,
    d: usize,
    dtype: KvDtype,
    mode: CacheMode,
}

impl KvCache {
    /// Full-precision slab cache (seed-compatible default): one block of
    /// `cap` tokens, eagerly allocated.
    pub fn new(n_layers: usize, cap: usize, d: usize) -> Self {
        Self::with_dtype(KvDtype::F32, n_layers, cap, d)
    }

    /// Slab cache with an explicit storage dtype: one eagerly-allocated
    /// block of `cap` tokens — byte-identical to the pre-paging layout.
    pub fn with_dtype(dtype: KvDtype, n_layers: usize, cap: usize, d: usize)
                      -> Self {
        let cap = cap.max(1);
        KvCache {
            blocks: vec![Arc::new(KvBlock::new(dtype, n_layers, cap, d))],
            block_tokens: cap,
            cap,
            len: 0,
            n_layers,
            d,
            dtype,
            mode: CacheMode::AutoGrow,
        }
    }

    /// Paged auto-grow cache: no blocks yet; a fresh `block_tokens`-row
    /// block is self-allocated whenever a write crosses a block
    /// boundary. Bitwise-equivalent to the slab layout for every block
    /// size (property-tested in `tests/ragged_batch.rs`).
    pub fn paged(dtype: KvDtype, n_layers: usize, cap: usize, d: usize,
                 block_tokens: usize) -> Self {
        let cap = cap.max(1);
        KvCache {
            blocks: Vec::new(),
            block_tokens: block_tokens.clamp(1, cap),
            cap,
            len: 0,
            n_layers,
            d,
            dtype,
            mode: CacheMode::AutoGrow,
        }
    }

    /// Pooled cache: starts with zero blocks; every block must be pushed
    /// by the owning `BlockPool` ([`KvCache::push_block`]) before the
    /// corresponding positions are written. Writing past the reserved
    /// blocks is a validated engine error, never an allocation.
    pub fn pooled(dtype: KvDtype, n_layers: usize, cap: usize, d: usize,
                  block_tokens: usize) -> Self {
        let mut c = Self::paged(dtype, n_layers, cap, d, block_tokens);
        c.mode = CacheMode::Pooled;
        c
    }

    /// Storage element type of this cache.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    /// Tokens per block (B).
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Physical blocks currently held.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Token capacity of the blocks currently held (`n_blocks · B`) —
    /// what a pooled cache can store without another reservation.
    pub fn held_tokens(&self) -> usize {
        self.blocks.len() * self.block_tokens
    }

    /// `true` when the cache self-allocates blocks on write (slab and
    /// engine-level paged caches); `false` for pool-reserved caches.
    pub fn auto_grow(&self) -> bool {
        self.mode == CacheMode::AutoGrow
    }

    /// Attach one pool-owned block (coordinator `BlockPool::reserve`),
    /// possibly shared with other sequences or the prefix cache.
    /// Geometry and dtype must match the cache.
    pub fn push_block(&mut self, block: Arc<KvBlock>) {
        assert_eq!(block.dtype(), self.dtype, "block dtype mismatch");
        assert_eq!(block.plane_elts(),
                   self.n_layers * self.block_tokens * self.d,
                   "block geometry mismatch");
        self.blocks.push(block);
    }

    /// Detach every block for return to the pool (coordinator
    /// `BlockPool::release`). Panics on a second release — the paged
    /// analogue of the slab pool's double-free contract. Shared blocks
    /// survive in whoever else still references them; the pool only
    /// reclaims the ones whose last reference this was.
    pub fn take_blocks(&mut self) -> Vec<Arc<KvBlock>> {
        match self.mode {
            CacheMode::Pooled => {
                self.mode = CacheMode::Released;
                self.len = 0;
                std::mem::take(&mut self.blocks)
            }
            CacheMode::Released => {
                panic!("double free of KV sequence (blocks already \
                        returned)")
            }
            CacheMode::AutoGrow => {
                panic!("release of a non-pooled KV cache")
            }
        }
    }

    /// A second handle to block `b` (prefix-cache insertion): the trie
    /// keeps frozen full blocks alive after their sequences finish.
    pub fn block_arc(&self, b: usize) -> Arc<KvBlock> {
        Arc::clone(&self.blocks[b])
    }

    /// `true` when block `b` is referenced by more than one handle
    /// (another sequence or the prefix cache) — such a block must never
    /// be written.
    pub fn block_shared(&self, b: usize) -> bool {
        Arc::strong_count(&self.blocks[b]) > 1
    }

    /// Identity of block `b`'s physical storage — lets metrics count
    /// distinct physical blocks across sequences that share them.
    pub fn block_ptr(&self, b: usize) -> *const KvBlock {
        Arc::as_ptr(&self.blocks[b])
    }

    /// Held blocks currently shared with another handle.
    pub fn shared_blocks(&self) -> usize {
        self.blocks
            .iter()
            .filter(|b| Arc::strong_count(b) > 1)
            .count()
    }

    /// `true` when the next write (at position `len`) would land in a
    /// *shared* partially-filled block — the one case that needs
    /// copy-on-write. Full blocks below `len` are frozen (writes only
    /// ever target positions ≥ `len`), and blocks past the boundary are
    /// fresh pool reservations, so the boundary block is the only block
    /// that can ever be both shared and written.
    pub fn boundary_shared(&self) -> bool {
        let b = self.len / self.block_tokens;
        self.len % self.block_tokens != 0
            && b < self.blocks.len()
            && Arc::strong_count(&self.blocks[b]) > 1
    }

    /// `true` if any write in logical positions `[from, to)` would land
    /// in a shared block — the forward pass's pre-mutation check that
    /// the scheduler's CoW step actually ran.
    pub fn write_range_shared(&self, from: usize, to: usize) -> bool {
        if to <= from {
            return false;
        }
        let first = from / self.block_tokens;
        let last = (to - 1) / self.block_tokens;
        (first..=last.min(self.blocks.len().saturating_sub(1)))
            .any(|b| Arc::strong_count(&self.blocks[b]) > 1)
    }

    /// Copy-on-write the boundary block: copy the `len % B` frozen
    /// prefix rows into `fresh` (a uniquely-owned pool block) and swap
    /// it into the table. The shared original lives on in the prefix
    /// cache / other sequences; this lane's handle is dropped here.
    pub fn cow_boundary(&mut self, mut fresh: Arc<KvBlock>) {
        let b = self.len / self.block_tokens;
        let rows = self.len % self.block_tokens;
        assert!(rows > 0 && b < self.blocks.len(),
                "CoW with no partially-filled boundary block");
        debug_assert_eq!(fresh.dtype(), self.dtype, "block dtype mismatch");
        debug_assert_eq!(fresh.plane_elts(),
                         self.n_layers * self.block_tokens * self.d,
                         "block geometry mismatch");
        Arc::get_mut(&mut fresh)
            .expect("CoW target block must be uniquely owned")
            .copy_rows_from(&self.blocks[b], rows, self.n_layers,
                            self.block_tokens, self.d);
        self.blocks[b] = fresh;
    }

    /// Block-plane accessors: the (B, d) slice of block `b`, layer `l`.
    /// Attention iterates the cached prefix block-by-block through
    /// these; row `r` of the slice is logical position `b·B + r`.
    #[inline]
    fn plane(&self, l: usize) -> std::ops::Range<usize> {
        l * self.block_tokens * self.d..(l + 1) * self.block_tokens * self.d
    }

    #[inline]
    pub(super) fn block_k_f32(&self, b: usize, l: usize) -> &[f32] {
        match &self.blocks[b].store {
            BlockStore::F32 { k, .. } => &k[self.plane(l)],
            BlockStore::I8 { .. } => unreachable!("f32 view of int8 KV"),
        }
    }

    #[inline]
    pub(super) fn block_v_f32(&self, b: usize, l: usize) -> &[f32] {
        match &self.blocks[b].store {
            BlockStore::F32 { v, .. } => &v[self.plane(l)],
            BlockStore::I8 { .. } => unreachable!("f32 view of int8 KV"),
        }
    }

    #[inline]
    pub(super) fn block_k_i8(&self, b: usize, l: usize) -> &[i8] {
        match &self.blocks[b].store {
            BlockStore::I8 { k, .. } => &k[self.plane(l)],
            BlockStore::F32 { .. } => unreachable!("int8 view of f32 KV"),
        }
    }

    #[inline]
    pub(super) fn block_v_i8(&self, b: usize, l: usize) -> &[i8] {
        match &self.blocks[b].store {
            BlockStore::I8 { v, .. } => &v[self.plane(l)],
            BlockStore::F32 { .. } => unreachable!("int8 view of f32 KV"),
        }
    }

    /// One cached K row (layer `l`, logical position `t`) — calibration
    /// and debugging only; the hot paths read whole block planes.
    pub(super) fn k_row_f32(&self, l: usize, t: usize) -> &[f32] {
        let (b, r) = (t / self.block_tokens, t % self.block_tokens);
        let p = self.block_k_f32(b, l);
        &p[r * self.d..(r + 1) * self.d]
    }

    /// One cached V row (layer `l`, logical position `t`).
    pub(super) fn v_row_f32(&self, l: usize, t: usize) -> &[f32] {
        let (b, r) = (t / self.block_tokens, t % self.block_tokens);
        let p = self.block_v_f32(b, l);
        &p[r * self.d..(r + 1) * self.d]
    }

    /// Store one K/V row, quantizing on the way in for int8 storage.
    /// Callers (the unified forward pass) validate capacity, block
    /// reservation, and scale availability up front and return
    /// `EngineError` — by the time a write happens it can only allocate
    /// (auto-grow caches crossing a block boundary), never fail.
    #[inline]
    pub(super) fn write(&mut self, l: usize, pos: usize, k_row: &[f32],
                        v_row: &[f32], scales: Option<&KvLayerScales>) {
        debug_assert!(pos < self.cap,
                      "KV write past validated capacity: {pos} >= {}",
                      self.cap);
        let bt = self.block_tokens;
        let b = pos / bt;
        while b >= self.blocks.len() {
            assert!(self.auto_grow(),
                    "KV write at position {pos} past the reserved blocks \
                     ({} held)", self.held_tokens());
            self.blocks.push(Arc::new(KvBlock::new(self.dtype,
                                                   self.n_layers, bt,
                                                   self.d)));
        }
        let d = self.d;
        let off = l * bt * d + (pos % bt) * d;
        let block = Arc::get_mut(&mut self.blocks[b])
            .expect("write into shared KV block (CoW missed)");
        match &mut block.store {
            BlockStore::F32 { k, v } => {
                k[off..off + d].copy_from_slice(k_row);
                v[off..off + d].copy_from_slice(v_row);
            }
            BlockStore::I8 { k, v } => {
                let sc = scales.expect("int8 KV write validated scales");
                kv::quantize_row_i8(k_row, &sc.k_inv, &mut k[off..off + d]);
                kv::quantize_row_i8(v_row, &sc.v_inv, &mut v[off..off + d]);
            }
        }
    }

    /// Resident bytes of the held K/V blocks (Table 3 accounting): 4
    /// bytes per element for f32 storage, 1 for int8 — proportional to
    /// blocks held, not to `cap`.
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }

    /// Forget the cached prefix (held storage is retained and
    /// overwritten).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Roll the cache back to `new_len` tokens and detach every block
    /// that holds no surviving row — the speculative-decode rejection
    /// path (DESIGN.md §18): a verify span writes KV for all k drafted
    /// tokens optimistically, and the rejected suffix must be both
    /// logically and physically discarded. The boundary block at
    /// `new_len` is kept even when partially filled (its stale suffix
    /// rows are unreachable — reads stop at `len` — and will be
    /// overwritten in place). Works for every cache mode: pooled
    /// callers hand the returned blocks to `BlockPool::reclaim`,
    /// auto-grow callers just drop them.
    pub fn truncate(&mut self, new_len: usize) -> Vec<Arc<KvBlock>> {
        assert!(new_len <= self.len,
                "KV truncate cannot grow: {new_len} > {}", self.len);
        assert_ne!(self.mode, CacheMode::Released,
                   "truncate of a released KV cache");
        self.len = new_len;
        let keep = new_len.div_ceil(self.block_tokens);
        let mut surplus = Vec::new();
        while self.blocks.len() > keep {
            surplus.push(self.blocks.pop().expect("len checked"));
        }
        surplus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_cache_is_one_block() {
        let c = KvCache::with_dtype(KvDtype::F32, 2, 16, 8);
        assert_eq!(c.n_blocks(), 1);
        assert_eq!(c.block_tokens(), 16);
        assert_eq!(c.held_tokens(), 16);
        assert_eq!(c.bytes(), 2 * 16 * 8 * 2 * 4);
    }

    #[test]
    fn paged_cache_grows_lazily_on_write() {
        let mut c = KvCache::paged(KvDtype::F32, 2, 16, 8, 4);
        assert_eq!(c.n_blocks(), 0);
        assert_eq!(c.bytes(), 0);
        let row = vec![1f32; 8];
        for pos in 0..6 {
            for l in 0..2 {
                c.write(l, pos, &row, &row, None);
            }
        }
        c.len = 6;
        assert_eq!(c.n_blocks(), 2, "6 tokens at B=4 need 2 blocks");
        assert_eq!(c.held_tokens(), 8);
        // logical→physical translation round-trips the written values
        for t in 0..6 {
            assert_eq!(c.k_row_f32(1, t), &row[..]);
        }
    }

    #[test]
    #[should_panic(expected = "past the reserved blocks")]
    fn pooled_cache_never_self_allocates() {
        let mut c = KvCache::pooled(KvDtype::F32, 1, 16, 8, 4);
        let row = vec![0f32; 8];
        c.write(0, 0, &row, &row, None);
    }

    #[test]
    #[should_panic(expected = "double free of KV sequence")]
    fn double_release_panics() {
        let mut c = KvCache::pooled(KvDtype::F32, 1, 16, 8, 4);
        c.push_block(Arc::new(KvBlock::new(KvDtype::F32, 1, 4, 8)));
        let _ = c.take_blocks();
        let _ = c.take_blocks();
    }

    #[test]
    #[should_panic(expected = "write into shared KV block")]
    fn write_into_shared_block_panics() {
        let mut c = KvCache::pooled(KvDtype::F32, 1, 16, 8, 4);
        let block = Arc::new(KvBlock::new(KvDtype::F32, 1, 4, 8));
        c.push_block(Arc::clone(&block)); // shared with `block`
        let row = vec![0f32; 8];
        c.write(0, 0, &row, &row, None);
    }

    #[test]
    fn cow_boundary_copies_frozen_rows_and_unshares() {
        let mut donor = KvCache::paged(KvDtype::F32, 2, 16, 8, 4);
        let rows: Vec<Vec<f32>> =
            (0..3).map(|t| vec![t as f32 + 1.0; 8]).collect();
        for (t, row) in rows.iter().enumerate() {
            for l in 0..2 {
                donor.write(l, t, row, row, None);
            }
        }
        donor.len = 3;
        // Borrower shares the donor's partially-filled block.
        let mut c = KvCache::pooled(KvDtype::F32, 2, 16, 8, 4);
        c.push_block(donor.block_arc(0));
        c.len = 3;
        assert!(c.boundary_shared());
        assert!(c.write_range_shared(3, 4));
        assert_eq!(c.shared_blocks(), 1);
        c.cow_boundary(Arc::new(KvBlock::new(KvDtype::F32, 2, 4, 8)));
        assert!(!c.boundary_shared());
        assert_eq!(c.shared_blocks(), 0);
        assert_ne!(c.block_ptr(0), donor.block_ptr(0));
        // frozen rows survived the copy bit-for-bit
        for (t, row) in rows.iter().enumerate() {
            assert_eq!(c.k_row_f32(1, t), &row[..]);
            assert_eq!(c.v_row_f32(0, t), &row[..]);
        }
        // and the boundary is now writable
        let fresh = vec![9f32; 8];
        for l in 0..2 {
            c.write(l, 3, &fresh, &fresh, None);
        }
        c.len = 4;
        assert_eq!(c.k_row_f32(0, 3), &fresh[..]);
        assert_eq!(donor.k_row_f32(0, 2), &rows[2][..],
                   "donor block untouched by the borrower's CoW");
    }

    #[test]
    fn truncate_pops_whole_surplus_blocks_and_keeps_boundary() {
        let mut c = KvCache::paged(KvDtype::F32, 2, 32, 8, 4);
        let row = vec![2f32; 8];
        for pos in 0..11 {
            for l in 0..2 {
                c.write(l, pos, &row, &row, None);
            }
        }
        c.len = 11; // 3 blocks at B=4
        assert_eq!(c.n_blocks(), 3);
        // 11 → 5: block 2 (rows 8..11) is surplus; block 1 survives
        // as the partially-filled boundary block.
        let surplus = c.truncate(5);
        assert_eq!(surplus.len(), 1);
        assert_eq!(c.len, 5);
        assert_eq!(c.n_blocks(), 2);
        // surviving rows untouched, and the boundary is re-writable
        assert_eq!(c.k_row_f32(1, 4), &row[..]);
        let fresh = vec![7f32; 8];
        for l in 0..2 {
            c.write(l, 5, &fresh, &fresh, None);
        }
        c.len = 6;
        assert_eq!(c.v_row_f32(0, 5), &fresh[..]);
        // truncate to a block boundary drops the exact tail count
        let surplus = c.truncate(4);
        assert_eq!(surplus.len(), 1);
        assert_eq!(c.n_blocks(), 1);
        // and to zero returns everything
        let surplus = c.truncate(0);
        assert_eq!(surplus.len(), 1);
        assert_eq!(c.n_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "KV truncate cannot grow")]
    fn truncate_past_len_panics() {
        let mut c = KvCache::paged(KvDtype::F32, 1, 16, 8, 4);
        let row = vec![0f32; 8];
        c.write(0, 0, &row, &row, None);
        c.len = 1;
        let _ = c.truncate(2);
    }

    #[test]
    fn int8_blocks_are_4x_smaller() {
        let f = KvBlock::new(KvDtype::F32, 2, 16, 8);
        let q = KvBlock::new(KvDtype::Int8, 2, 16, 8);
        assert_eq!(f.bytes(), 4 * q.bytes());
    }
}
