//! Native quantized inference engine: loads `.qmod` bundles and executes
//! prefill / batched decode on the integer-kernel substrate. This is the
//! measured system behind the paper's speed tables (Fig. 3, Tables 2/3/6)
//! and the accuracy tables (1/4/5/7 via [`crate::eval`]).

pub mod memory;
pub mod model;
pub mod qmod;

pub use crate::quant::kv::{KvDtype, KvLayerScales};
pub use model::{Engine, EngineError, KvCache, Sampler, Workspace};
pub use qmod::{Linear, ModelConfig, Norm, QModel, QuantMode, QWeight};
