//! Native quantized inference engine: loads `.qmod` bundles and executes
//! the unified ragged-batch forward pass on the integer-kernel substrate.
//! This is the measured system behind the paper's speed tables (Fig. 3,
//! Tables 2/3/6) and the accuracy tables (1/4/5/7 via [`crate::eval`]).
//!
//! Module layout (DESIGN.md §12):
//! * [`forward`] — [`BatchPlan`] + [`Engine::forward_batch`]: the single
//!   per-layer pipeline every span (prefill chunk or decode lane) rides.
//! * `attention` — f32/int8-KV attention (block-by-block over the paged
//!   prefix) and the ragged per-span fan-out.
//! * [`cache`] — dtype-parametric paged [`KvCache`] storage: block
//!   tables over [`KvBlock`]s (DESIGN.md §13).
//! * [`sampler`] — the seeded [`Sampler`], the single token-selection
//!   entry point (greedy = `Sampler::greedy()`).
//! * [`model`] — [`Engine`] construction/calibration and the thin
//!   seed-compatible `prefill` / `decode_batch` wrappers.
//! * [`qmod`] — the `.qmod` bundle format; [`memory`] — Table-3
//!   accounting.

mod attention;
pub mod cache;
pub mod forward;
pub mod memory;
pub mod model;
pub mod qmod;
pub mod sampler;

pub use crate::quant::kv::{KvDtype, KvLayerScales};
pub use cache::{KvBlock, KvCache};
pub use forward::{BatchPlan, EngineError, Span, SpanLogits, Workspace};
pub use model::Engine;
pub use qmod::{Linear, ModelConfig, Norm, QModel, QuantMode, QWeight};
pub use sampler::Sampler;
