//! Memory accounting (Table 3): resident bytes for decoding one token.
//!
//! The paper reports peak GPU memory for Llama-2-7B (batch 1, seq 2048):
//! FP16 ≈ 13.9 GB, QuaRot 4.16 GB, RTN 3.90 GB, MergeQuant 3.87 GB — the
//! dynamic methods pay extra activation/scale buffers for their online
//! Quant step, MergeQuant does not. We account the same categories for
//! the engine (measured on the tiny models) *and* project them onto
//! Llama-2-7B dimensions with the same formulas, so the bench reports
//! both the measured and the paper-scale numbers.

use crate::quant::kv::KvDtype;

use super::qmod::{Linear, QModel, QuantMode};

#[derive(Clone, Debug, Default)]
pub struct MemoryBreakdown {
    pub weights: usize,
    pub kv_cache: usize,
    pub activations: usize,
    /// Extra buffers only the dynamic path needs (int copies + row scales
    /// + the pre-Hadamard staging buffer).
    pub dynamic_overhead: usize,
    pub recon_indices: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.weights + self.kv_cache + self.activations
            + self.dynamic_overhead + self.recon_indices
    }
}

/// Account a loaded model for (batch, seq) single-token decoding with the
/// given KV-cache storage dtype (f32 seed layout or static INT8).
pub fn account_model(model: &QModel, batch: usize, seq: usize, kv: KvDtype)
                     -> MemoryBreakdown {
    let cfg = &model.config;
    let (d, ff, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
    let mut mb = MemoryBreakdown {
        weights: model.weight_bytes(),
        kv_cache: cfg.n_layers * batch * seq * d * 2 * kv.bytes_per_elt(),
        ..Default::default()
    };
    // Unified forward-batch workspace (engine/forward.rs): one
    // row-stacked buffer set shared by prefill spans and decode lanes —
    // here sized for a pure-decode iteration (m = batch rows, one logits
    // row per lane). Seven f32 (m, d) buffers (x, h, q, k, v, attn,
    // proj), two i8 (m, d) merged-norm outputs, three f32 (m, ff) FFN
    // buffers, the (sel, d) logit-row gather and the (sel, vocab) logits
    // with sel = m.
    let m = batch;
    mb.activations =
        m * (7 * d * 4 + 2 * d + 3 * ff * 4) + m * (d + v) * 4;
    let mut has_dynamic = false;
    let mut has_hadamard = false;
    let mut max_n = 0usize;
    for l in &model.layers {
        mb.recon_indices += l.attn_norm.recon_idx.as_ref().map_or(0, |r| r.len() * 4);
        mb.recon_indices += l.ffn_norm.recon_idx.as_ref().map_or(0, |r| r.len() * 4);
        for lin in [&l.q, &l.k, &l.v, &l.o, &l.gate, &l.up, &l.down] {
            if let Linear::Quant { qw, mode } = lin {
                match mode {
                    QuantMode::Dynamic { hadamard, .. } => {
                        has_dynamic = true;
                        has_hadamard |= *hadamard;
                        max_n = max_n.max(qw.n);
                    }
                    QuantMode::TensorStatic { .. } => {
                        has_dynamic = true; // int copy buffer, no row scales
                        max_n = max_n.max(qw.n);
                    }
                    QuantMode::ChannelStatic { recon_idx, .. } => {
                        // Static path: int copy buffer only (quantize
                        // multipliers live with the weights, counted in
                        // weight_bytes); the activation gather indices
                        // are recon machinery like the norm gathers.
                        has_dynamic = true;
                        max_n = max_n.max(qw.n);
                        mb.recon_indices +=
                            recon_idx.as_ref().map_or(0, |r| r.len() * 4);
                    }
                    QuantMode::Static => {}
                }
            }
        }
    }
    if has_dynamic {
        // int8 activation copy + per-row scale
        mb.dynamic_overhead += m * max_n + m * 4;
    }
    if has_hadamard {
        mb.dynamic_overhead += m * max_n * 4;
    }
    mb
}

/// Project the same accounting onto arbitrary Llama dimensions (used to
/// reproduce the paper's absolute Table 3 numbers without the 7B weights).
pub struct ProjectedConfig {
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub vocab: usize,
}

pub const LLAMA2_7B: ProjectedConfig = ProjectedConfig {
    d_model: 4096,
    d_ff: 11008,
    n_layers: 32,
    vocab: 32000,
};

/// Projected resident KV bytes at arbitrary dimensions for a given
/// per-element byte width (2 = fp16 paper baseline, 1 = static INT8).
pub fn projected_kv_bytes(cfg: &ProjectedConfig, batch: usize, seq: usize,
                          bytes_per_elt: usize) -> usize {
    cfg.n_layers * batch * seq * cfg.d_model * 2 * bytes_per_elt
}

pub enum MethodKind {
    Fp16,
    /// per-channel static (MergeQuant): no dynamic buffers except out/down.
    MergeQuant,
    /// per-token dynamic on all activations (RTN).
    RtnDynamic,
    /// dynamic + online hadamard staging (QuaRot).
    QuarotDynamic,
}

pub fn project(cfg: &ProjectedConfig, kind: &MethodKind, batch: usize,
               seq: usize, w_bits: usize) -> MemoryBreakdown {
    let (d, ff, l, v) = (cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab);
    let per_layer_params = 4 * d * d + 3 * d * ff;
    let body = l * per_layer_params;
    let embed_head = 2 * v * d + d;
    let mut mb = MemoryBreakdown::default();
    match kind {
        MethodKind::Fp16 => {
            mb.weights = (body + embed_head) * 2; // fp16 bytes
        }
        _ => {
            // int-w_bits body + per-column fp16 scales + fp16 embed/head
            mb.weights = body * w_bits / 8
                + l * (4 * d + 3 * ff) * 2
                + embed_head * 2;
        }
    }
    mb.kv_cache = l * batch * seq * d * 2 * 2; // fp16 KV
    // Peak activations occur during the seq-long prefill: residual stream +
    // the widest intermediate, fp16, plus last-token logits.
    let m = batch * seq;
    mb.activations = m * (2 * d + ff) * 2 + batch * v * 2;
    match kind {
        MethodKind::Fp16 => {}
        MethodKind::MergeQuant => {
            // int copy buffer for the two per-token layers + row scales;
            // the merged norm emits int8 directly (m·d, not m·d·2 fp16).
            mb.dynamic_overhead = m * ff + m * 4 + m * d;
            mb.recon_indices = l * 2 * d * 4;
        }
        MethodKind::RtnDynamic => {
            // int copy buffer + row scales + fp16 norm outputs feeding the
            // online Quant step of every linear.
            mb.dynamic_overhead = m * ff + m * 4 + 2 * m * d;
        }
        MethodKind::QuarotDynamic => {
            mb.dynamic_overhead = m * ff + m * 4 + 2 * m * d
                + m * ff * 2; // hadamard staging fp16
        }
    }
    mb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp16_7b_close_to_13_5_gb() {
        let mb = project(&LLAMA2_7B, &MethodKind::Fp16, 1, 2048, 16);
        let gb = mb.total() as f64 / 1e9;
        assert!((12.0..15.5).contains(&gb), "{gb}");
    }

    #[test]
    fn w4_saving_factor_matches_paper_shape() {
        let fp = project(&LLAMA2_7B, &MethodKind::Fp16, 1, 2048, 16).total();
        let mq = project(&LLAMA2_7B, &MethodKind::MergeQuant, 1, 2048, 4)
            .total();
        let rtn = project(&LLAMA2_7B, &MethodKind::RtnDynamic, 1, 2048, 4)
            .total();
        let qr = project(&LLAMA2_7B, &MethodKind::QuarotDynamic, 1, 2048, 4)
            .total();
        let sf = fp as f64 / mq as f64;
        assert!((2.8..4.2).contains(&sf), "saving factor {sf}");
        // ordering: MergeQuant ≤ RTN ≤ QuaRot (paper Table 3)
        assert!(mq <= rtn && rtn <= qr);
    }

    #[test]
    fn breakdown_total_is_sum() {
        let mb = project(&LLAMA2_7B, &MethodKind::QuarotDynamic, 4, 512, 4);
        assert_eq!(mb.total(),
                   mb.weights + mb.kv_cache + mb.activations
                       + mb.dynamic_overhead + mb.recon_indices);
    }
}
