//! The [`Engine`]: construction, threading, calibration, and thin
//! seed-compatible wrappers over the unified ragged forward pass.
//!
//! All forward computation lives in `engine::forward`
//! ([`Engine::forward_batch`] + [`BatchPlan`]); attention in
//! `engine::attention`; KV storage in `engine::cache`; token selection
//! in `engine::sampler`. [`Engine::prefill`] and [`Engine::decode_batch`]
//! are one-plan wrappers kept for API compatibility — a prefill is a
//! single all-rows span, a batched decode is one single-row span per
//! lane. Results are **bitwise identical** for every thread count and
//! every ragged batch composition (DESIGN.md §7/§12).

use crate::quant::kv::{self, KvDtype, KvLayerScales};
use crate::quant::parallel::ThreadPool;

use super::cache::KvCache;
use super::forward::{BatchPlan, EngineError, SpanLogits, Workspace};
use super::qmod::QModel;
use super::sampler::Sampler;

/// The native quantized inference engine: a loaded `.qmod` bundle plus a
/// persistent intra-op worker pool.
pub struct Engine {
    pub model: QModel,
    /// Persistent intra-op worker pool; 1 thread ⇒ fully serial paths.
    pub(super) pool: ThreadPool,
}

impl Engine {
    /// Serial engine (1 compute thread) — the deterministic baseline.
    pub fn new(model: QModel) -> Self {
        Self::with_threads(model, 1)
    }

    /// Engine with an intra-op pool of `threads` compute threads
    /// (`0` ⇒ all available cores). Output is bitwise identical to the
    /// serial engine for any value.
    pub fn with_threads(model: QModel, threads: usize) -> Self {
        Engine {
            model,
            pool: ThreadPool::new(ThreadPool::resolve(threads)),
        }
    }

    /// Replace the worker pool (no-op when the resolved count is
    /// unchanged). Safe at any point between forward calls.
    pub fn set_threads(&mut self, threads: usize) {
        let t = ThreadPool::resolve(threads);
        if t != self.pool.threads() {
            self.pool = ThreadPool::new(t);
        }
    }

    /// Current compute-thread count (≥ 1).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn config(&self) -> &super::qmod::ModelConfig {
        &self.model.config
    }

    /// Draft engine for self-speculative decoding (DESIGN.md §18): the
    /// same bundle layer-truncated to `draft_layers` deep (`0` ⇒ full
    /// depth — a pure self-draft), with its own intra-op pool of
    /// `threads` workers and nothing mutable shared with the target.
    /// KV scales (if present) are truncated alongside the layers, so
    /// the draft lane serves int8 KV whenever the target can.
    pub fn draft(&self, draft_layers: usize, threads: usize) -> Engine {
        Engine::with_threads(self.model.truncated(draft_layers), threads)
    }

    // ------------------------------------------------------------------
    // Seed-compatible wrappers over forward_batch
    // ------------------------------------------------------------------

    /// Prefill one sequence **continuing from `cache.len`**; fills cache
    /// positions `cache.len .. cache.len+t` and returns logits (t, vocab)
    /// in `ws.logits`. With `cache.len == 0` this is a plain prefill; with
    /// a non-empty cache it implements *chunked prefill* (the scheduler
    /// bounds decode stalls with it) and multi-turn prompt reuse.
    ///
    /// One-span plan over [`Engine::forward_batch`] (all rows emit
    /// logits — the seed contract the perplexity eval and parity tests
    /// rely on). Capacity and KV-scale availability are validated
    /// **before** any state is touched: an `Err` leaves `cache` and `ws`
    /// unchanged.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache,
                   ws: &mut Workspace) -> Result<(), EngineError> {
        let mut plan = BatchPlan::new();
        plan.push_span(0, tokens, SpanLogits::All);
        self.forward_batch(&plan, &mut [cache], ws)
    }

    /// One decode step for a batch of sequences. `tokens[i]` is the next
    /// input token of sequence i; each sequence attends to its own cache
    /// (lanes may mix KV dtypes). Returns logits (B, vocab) in
    /// `ws.logits`.
    ///
    /// One single-row span per lane over [`Engine::forward_batch`]. All
    /// lanes are validated **before** any state is touched: an `Err`
    /// names the offending lane and leaves every cache unchanged.
    pub fn decode_batch(&self, tokens: &[u32], caches: &mut [&mut KvCache],
                        ws: &mut Workspace) -> Result<(), EngineError> {
        assert_eq!(caches.len(), tokens.len());
        let mut plan = BatchPlan::new();
        for (i, t) in tokens.iter().enumerate() {
            plan.push_span(i, std::slice::from_ref(t), SpanLogits::Last);
        }
        self.forward_batch(&plan, caches, ws)
    }

    // ------------------------------------------------------------------
    // Generation (one seeded implementation; greedy = Sampler::greedy())
    // ------------------------------------------------------------------

    /// Greedy generation helper (examples / integration tests), f32 KV.
    /// Sizes its own cache, so the only failure mode is a prompt longer
    /// than `max_seq` — surfaced as the typed
    /// [`EngineError::KvOverflow`], never a panic.
    pub fn generate(&self, prompt: &[u32], max_new: usize, max_seq: usize)
                    -> Result<Vec<u32>, EngineError> {
        self.generate_with(prompt, max_new, max_seq, KvDtype::F32)
    }

    /// Greedy generation over an explicit KV-cache dtype.
    pub fn generate_with(&self, prompt: &[u32], max_new: usize,
                         max_seq: usize, kv_dtype: KvDtype)
                         -> Result<Vec<u32>, EngineError> {
        self.generate_seeded(prompt, max_new, max_seq, kv_dtype,
                             &Sampler::greedy())
    }

    /// Sampled generation: the engine-level path behind the serving
    /// contract's `GenerationParams`, and the single implementation the
    /// greedy helpers above delegate to. Token *t* is drawn by
    /// `sampler.sample(logits, t)` — a pure function of the (bitwise
    /// thread-count-invariant) logits and the counter-based stream
    /// `(seed, t)` — so fixed-seed streams are bitwise identical for
    /// every thread count. A greedy sampler reproduces
    /// [`Engine::generate`] exactly.
    pub fn generate_seeded(&self, prompt: &[u32], max_new: usize,
                           max_seq: usize, kv_dtype: KvDtype,
                           sampler: &Sampler)
                           -> Result<Vec<u32>, EngineError> {
        let cfg = &self.model.config;
        let mut cache =
            KvCache::with_dtype(kv_dtype, cfg.n_layers, max_seq, cfg.d_model);
        let mut ws = Workspace::new();
        // prefill all but the last prompt token, then step
        self.prefill(prompt, &mut cache, &mut ws)?;
        let vocab = cfg.vocab;
        let last = &ws.logits[(prompt.len() - 1) * vocab..prompt.len() * vocab];
        let mut next = sampler.sample(last, 0);
        let mut out = vec![next];
        for step in 1..max_new as u64 {
            if cache.len + 1 >= max_seq {
                break;
            }
            let toks = [next];
            let mut caches = [&mut cache];
            self.decode_batch(&toks, &mut caches, &mut ws)?;
            next = sampler.sample(&ws.logits[..vocab], step);
            out.push(next);
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // KV-scale calibration
    // ------------------------------------------------------------------

    /// Attach probe-calibrated KV scales when the bundle carries none
    /// (pre-format-2 `.qmod`, fp16 baselines, synthetic models) so the
    /// int8-KV path serves everywhere. No-op for format-2 bundles — the
    /// single shared fallback behind the scheduler, CLI, benches and
    /// tests.
    pub fn ensure_kv_scales(&mut self) -> Result<(), EngineError> {
        if self.model.kv.is_some() {
            return Ok(());
        }
        let vocab = self.model.config.vocab as u32;
        let probe: Vec<u32> =
            (0..48u32).map(|i| (3 + i * 7) % vocab.max(1)).collect();
        let scales = self.calibrate_kv_scales(&probe)?;
        self.model.kv = Some(scales);
        Ok(())
    }

    /// Probe-based KV-scale calibration fallback: prefill `probe` through
    /// an f32 cache and derive per-channel K/V scales from the observed
    /// absmax. Per-head score scales approximate Q ranges by the K ranges
    /// (the two are projections of the same normed input; nothing binds
    /// their magnitudes, so this is a heuristic) with 3× clamp headroom —
    /// Q̂ saturates only if per-head |Q| exceeds 3× |K|, at the cost of
    /// ~1% extra score quantization error. The *real* path is build-time
    /// calibration in `python/compile` (format-2 bundles carry exact
    /// per-head Q statistics); prefer [`Engine::ensure_kv_scales`] unless
    /// a specific probe is needed.
    pub fn calibrate_kv_scales(&self, probe: &[u32])
                               -> Result<Vec<KvLayerScales>, EngineError> {
        let cfg = &self.model.config;
        let (d, h) = (cfg.d_model, cfg.n_heads);
        let hd = cfg.head_dim();
        let qmax = kv::KV_QMAX as f32;
        let mut cache = KvCache::new(cfg.n_layers, probe.len().max(1), d);
        let mut ws = Workspace::new();
        self.prefill(probe, &mut cache, &mut ws)?;
        let t = cache.len;
        let mut out = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            // Per-channel absmax over the cached rows, read through the
            // paged logical→physical translation (the probe cache is a
            // single slab block, but the row accessor works for any
            // block size).
            let k_absmax = |c: usize| {
                (0..t).fold(1e-6f32,
                            |a, r| a.max(cache.k_row_f32(l, r)[c].abs()))
            };
            let v_absmax = |c: usize| {
                (0..t).fold(1e-6f32,
                            |a, r| a.max(cache.v_row_f32(l, r)[c].abs()))
            };
            let kabs: Vec<f32> = (0..d).map(k_absmax).collect();
            let k_scale: Vec<f32> = kabs.iter().map(|a| a / qmax).collect();
            let v_scale: Vec<f32> =
                (0..d).map(|c| v_absmax(c) / qmax).collect();
            let qk_scale: Vec<f32> = (0..h)
                .map(|hh| {
                    (0..hd).fold(1e-12f32, |a, i| {
                        let c = hh * hd + i;
                        a.max(kabs[c] * k_scale[c])
                    }) * 3.0 / qmax
                })
                .collect();
            out.push(KvLayerScales::new(k_scale, v_scale, qk_scale));
        }
        Ok(out)
    }
}
