//! Quantized forward passes: prefill and batched decode with KV caches.
//!
//! Semantics mirror `python/compile/quant/qforward.py` exactly (validated
//! against the artifact goldens): same rounding, same clamp ranges, same
//! merged-norm → gather → integer-GEMM → epilogue pipeline. The static
//! MergeQuant path runs **zero** per-token quantization passes — the norm
//! emits integers (Eq. 4) and the epilogue is per-output-column (Eq. 5);
//! the dynamic baselines pay `quant::dynamic` passes per linear — exactly
//! the overhead the paper measures in Table 6.
//!
//! Execution is tiled and (optionally) multi-threaded: every GEMM runs on
//! the engine's persistent [`ThreadPool`] via `quant::parallel`, prefill
//! attention fans out over query-row blocks, and batched decode fans out
//! across batch lanes. Results are **bitwise identical** for every thread
//! count (DESIGN.md §7), so golden/parity tests hold regardless of the
//! configured parallelism.

use crate::quant::dynamic::per_token_quant;
use crate::quant::gemm::{gemm_i8_grouped, rowsum_i8};
use crate::quant::hadamard::fwht_block64;
use crate::quant::kv::{self, KvDtype, KvLayerScales};
use crate::quant::parallel::{
    par_gemm_f32, par_qlinear, ScopedTask, ThreadPool,
};
use crate::quant::reconstruct::reconstruct_i8;
use crate::util::rng::Rng;

use super::qmod::{Linear, Norm, QModel, QuantMode, QWeight};

const EPS: f32 = 1e-5;

/// Typed engine failures. Forward calls validate *before* touching any
/// cache state, so an `Err` leaves caches and workspace unmodified — the
/// coordinator surfaces these as per-request failures instead of dying
/// on a panic (DESIGN.md §6).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineError {
    /// Writing position `pos` would exceed the cache capacity `cap`.
    /// `lane` is the batch lane (0 for prefill / single-sequence calls).
    KvOverflow { lane: usize, pos: usize, cap: usize },
    /// An int8 KV cache was supplied but the bundle carries no calibrated
    /// KV scales (pre-format-2 `.qmod`).
    MissingKvScales,
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::KvOverflow { lane, pos, cap } => write!(
                f, "KV cache overflow on lane {lane}: position {pos} >= \
                    capacity {cap}"),
            EngineError::MissingKvScales => write!(
                f, "int8 KV cache requested but the bundle has no \
                    calibrated KV scales"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Reusable scratch buffers — no allocation on the decode hot path after
/// the first step.
#[derive(Default)]
pub struct Workspace {
    pub x: Vec<f32>,        // residual stream (m, d)
    pub h: Vec<f32>,        // f32 norm output (m, d)
    pub hq: Vec<i8>,        // quantized norm output (m, d)
    pub hq2: Vec<i8>,       // reconstructed quantized activations (m, d)
    pub qbuf: Vec<f32>,     // q/k/v projections (m, d)
    pub kbuf: Vec<f32>,
    pub vbuf: Vec<f32>,
    pub attn: Vec<f32>,     // attention output (m, d)
    pub gate: Vec<f32>,     // (m, ff)
    pub up: Vec<f32>,
    pub ff: Vec<f32>,       // silu(gate)·up (m, ff)
    pub proj: Vec<f32>,     // o/down projection output (m, d)
    pub xq: Vec<i8>,        // dynamic-quant activation buffer
    pub row_scale: Vec<f32>,
    pub row_sum: Vec<i32>,
    pub had: Vec<f32>,      // hadamard-transformed activations
    pub scratch_w: Vec<i8>, // unpacked weight row
    pub scores: Vec<f32>,   // attention score row (≤ max cache len)
    pub qint: Vec<i8>,      // quantized query head (int8-KV attention)
    pub logits: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current resident bytes across all scratch buffers (Table 3).
    pub fn bytes(&self) -> usize {
        self.x.len() * 4
            + self.h.len() * 4
            + self.hq.len()
            + self.hq2.len()
            + (self.qbuf.len() + self.kbuf.len() + self.vbuf.len()) * 4
            + (self.attn.len() + self.gate.len() + self.up.len()
                + self.ff.len() + self.proj.len()) * 4
            + self.xq.len()
            + self.row_scale.len() * 4
            + self.row_sum.len() * 4
            + self.had.len() * 4
            + self.scratch_w.len()
            + self.scores.len() * 4
            + self.qint.len()
            + self.logits.len() * 4
    }
}

/// Dtype-parametric K/V storage: contiguous (L, cap, d) planes either in
/// f32 (seed layout) or statically-quantized int8 (4× smaller).
enum KvStore {
    F32 { k: Vec<f32>, v: Vec<f32> },
    I8 { k: Vec<i8>, v: Vec<i8> },
}

/// Per-sequence KV cache: layout (L, cap, d) with d = H·hd. Storage is
/// dtype-parametric ([`KvDtype`]): `F32` keeps the full-precision seed
/// behaviour, `Int8` stores per-channel statically-quantized values (the
/// engine quantizes at write time with the bundle's calibrated scales and
/// attends in the integer domain — `quant::kv`).
pub struct KvCache {
    store: KvStore,
    pub cap: usize,
    pub len: usize,
    pub n_layers: usize,
    d: usize,
}

impl KvCache {
    /// Full-precision cache (seed-compatible default).
    pub fn new(n_layers: usize, cap: usize, d: usize) -> Self {
        Self::with_dtype(KvDtype::F32, n_layers, cap, d)
    }

    /// Cache with an explicit storage dtype.
    pub fn with_dtype(dtype: KvDtype, n_layers: usize, cap: usize, d: usize)
                      -> Self {
        let n = n_layers * cap * d;
        let store = match dtype {
            KvDtype::F32 => KvStore::F32 { k: vec![0f32; n], v: vec![0f32; n] },
            KvDtype::Int8 => KvStore::I8 { k: vec![0i8; n], v: vec![0i8; n] },
        };
        KvCache { store, cap, len: 0, n_layers, d }
    }

    /// Storage element type of this cache.
    pub fn dtype(&self) -> KvDtype {
        match self.store {
            KvStore::F32 { .. } => KvDtype::F32,
            KvStore::I8 { .. } => KvDtype::Int8,
        }
    }

    #[inline]
    fn plane(&self, l: usize) -> std::ops::Range<usize> {
        l * self.cap * self.d..(l + 1) * self.cap * self.d
    }

    #[inline]
    fn layer_k_f32(&self, l: usize) -> &[f32] {
        match &self.store {
            KvStore::F32 { k, .. } => &k[self.plane(l)],
            KvStore::I8 { .. } => unreachable!("f32 view of int8 KV cache"),
        }
    }

    #[inline]
    fn layer_v_f32(&self, l: usize) -> &[f32] {
        match &self.store {
            KvStore::F32 { v, .. } => &v[self.plane(l)],
            KvStore::I8 { .. } => unreachable!("f32 view of int8 KV cache"),
        }
    }

    #[inline]
    fn layer_k_i8(&self, l: usize) -> &[i8] {
        match &self.store {
            KvStore::I8 { k, .. } => &k[self.plane(l)],
            KvStore::F32 { .. } => unreachable!("int8 view of f32 KV cache"),
        }
    }

    #[inline]
    fn layer_v_i8(&self, l: usize) -> &[i8] {
        match &self.store {
            KvStore::I8 { v, .. } => &v[self.plane(l)],
            KvStore::F32 { .. } => unreachable!("int8 view of f32 KV cache"),
        }
    }

    /// Store one K/V row, quantizing on the way in for int8 storage.
    /// Callers (the engine forward passes) validate capacity and scale
    /// availability up front and return [`EngineError`] — by the time a
    /// write happens it cannot fail.
    #[inline]
    fn write(&mut self, l: usize, pos: usize, k_row: &[f32], v_row: &[f32],
             scales: Option<&KvLayerScales>) {
        debug_assert!(pos < self.cap,
                      "KV write past validated capacity: {pos} >= {}",
                      self.cap);
        let d = self.d;
        let off = l * self.cap * d + pos * d;
        match &mut self.store {
            KvStore::F32 { k, v } => {
                k[off..off + d].copy_from_slice(k_row);
                v[off..off + d].copy_from_slice(v_row);
            }
            KvStore::I8 { k, v } => {
                let sc = scales.expect("int8 KV write validated scales");
                kv::quantize_row_i8(k_row, &sc.k_inv, &mut k[off..off + d]);
                kv::quantize_row_i8(v_row, &sc.v_inv, &mut v[off..off + d]);
            }
        }
    }

    /// Resident bytes of the K/V planes (Table 3 accounting): 4 bytes per
    /// element for f32 storage, 1 for int8.
    pub fn bytes(&self) -> usize {
        match &self.store {
            KvStore::F32 { k, v } => (k.len() + v.len()) * 4,
            KvStore::I8 { k, v } => k.len() + v.len(),
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }
}

enum Act<'a> {
    F32(&'a [f32]),
    I8(&'a [i8]),
}

pub struct Engine {
    pub model: QModel,
    /// Persistent intra-op worker pool; 1 thread ⇒ fully serial paths.
    pool: ThreadPool,
}

impl Engine {
    /// Serial engine (1 compute thread) — the deterministic baseline.
    pub fn new(model: QModel) -> Self {
        Self::with_threads(model, 1)
    }

    /// Engine with an intra-op pool of `threads` compute threads
    /// (`0` ⇒ all available cores). Output is bitwise identical to the
    /// serial engine for any value.
    pub fn with_threads(model: QModel, threads: usize) -> Self {
        Engine {
            model,
            pool: ThreadPool::new(ThreadPool::resolve(threads)),
        }
    }

    /// Replace the worker pool (no-op when the resolved count is
    /// unchanged). Safe at any point between forward calls.
    pub fn set_threads(&mut self, threads: usize) {
        let t = ThreadPool::resolve(threads);
        if t != self.pool.threads() {
            self.pool = ThreadPool::new(t);
        }
    }

    /// Current compute-thread count (≥ 1).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn config(&self) -> &super::qmod::ModelConfig {
        &self.model.config
    }

    // ------------------------------------------------------------------
    // Primitive ops
    // ------------------------------------------------------------------

    fn rmsnorm_f32(x: &[f32], g: &[f32], m: usize, d: usize, out: &mut [f32]) {
        for i in 0..m {
            let row = &x[i * d..(i + 1) * d];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + EPS).sqrt();
            let or = &mut out[i * d..(i + 1) * d];
            for c in 0..d {
                or[c] = row[c] * inv * g[c];
            }
        }
    }

    /// Merged-multiplier norm emitting integers (Eq. 4), then the
    /// dimension-reconstruction gather (App. C.1). Result lands in `hq2`.
    fn rmsnorm_quant(x: &[f32], norm: &Norm, m: usize, d: usize,
                     hq: &mut [i8], hq2: &mut [i8]) {
        let qmax = norm.quant_qmax.unwrap() as f32;
        for i in 0..m {
            let row = &x[i * d..(i + 1) * d];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + EPS).sqrt();
            let qr = &mut hq[i * d..(i + 1) * d];
            for c in 0..d {
                let v = (row[c] * inv * norm.g[c]).round();
                qr[c] = v.clamp(-qmax, qmax) as i8;
            }
        }
        if let Some(idx) = &norm.recon_idx {
            reconstruct_i8(&hq[..m * d], idx, m, d, &mut hq2[..m * d]);
        } else {
            hq2[..m * d].copy_from_slice(&hq[..m * d]);
        }
    }

    /// Integer GEMM + rescale epilogue. Group-0 fast path goes through the
    /// fused tiled kernel (`quant::parallel::par_qlinear`): packed-int4
    /// weights when `m` amortizes the unpack, epilogue applied inside each
    /// tile so the i32 accumulator never hits memory. The grouped general
    /// path (Table 5 W3-group) stays serial.
    #[allow(clippy::too_many_arguments)]
    fn int_matmul(pool: &ThreadPool, qw: &QWeight, xq: &[i8], m: usize,
                  row_scale: Option<&[f32]>, rsum: &mut Vec<i32>,
                  scratch: &mut Vec<i8>, out: &mut [f32]) {
        let (n, j) = (qw.n, qw.j);
        if qw.group != 0 {
            gemm_i8_grouped(&xq[..m * n], &qw.wt, m, n, j, qw.group,
                            &qw.scale, qw.zero.as_deref(), row_scale,
                            &mut out[..m * j]);
            return;
        }
        let rowsum: Option<&[i32]> = match &qw.zero {
            Some(_) => {
                rowsum_i8(&xq[..m * n], m, n, rsum);
                Some(rsum.as_slice())
            }
            None => None,
        };
        par_qlinear(pool, &xq[..m * n], &qw.wt, qw.packed.as_deref(), m, n,
                    j, &qw.scale, qw.zero.as_deref(), rowsum, row_scale,
                    scratch, &mut out[..m * j]);
    }

    /// Apply one linear to m rows; writes (m, j) into `out`. Scratch
    /// buffers are passed individually so callers can split a Workspace.
    #[allow(clippy::too_many_arguments)]
    fn linear(pool: &ThreadPool, lin: &Linear, input: Act, m: usize,
              xqb: &mut Vec<i8>, rs: &mut Vec<f32>, rsum: &mut Vec<i32>,
              had: &mut Vec<f32>, scratch: &mut Vec<i8>, out: &mut [f32]) {
        match lin {
            Linear::Fp { wt, n, j } => {
                let x = match input {
                    Act::F32(x) => x,
                    Act::I8(_) => unreachable!("fp linear needs f32 input"),
                };
                par_gemm_f32(pool, &x[..m * n], wt, m, *n, *j,
                             &mut out[..m * j]);
            }
            Linear::Quant { qw, mode } => match mode {
                QuantMode::Static => {
                    let xq = match input {
                        Act::I8(xq) => xq,
                        Act::F32(_) => unreachable!("static linear needs i8"),
                    };
                    Self::int_matmul(pool, qw, xq, m, None, rsum, scratch,
                                     out);
                }
                QuantMode::TensorStatic { a_scale, a_qmax } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("tensor_static needs f32"),
                    };
                    let n = qw.n;
                    xqb.resize(m * n, 0);
                    let inv = 1.0 / *a_scale;
                    let qm = *a_qmax as f32;
                    for (q, &v) in xqb[..m * n].iter_mut().zip(&x[..m * n]) {
                        *q = (v * inv).round().clamp(-qm, qm) as i8;
                    }
                    rs.clear();
                    rs.resize(m, *a_scale);
                    Self::int_matmul(pool, qw, xqb, m, Some(rs), rsum,
                                     scratch, out);
                }
                QuantMode::Dynamic { a_qmax, a_clip, hadamard } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("dynamic needs f32"),
                    };
                    let n = qw.n;
                    let xin: &[f32] = if *hadamard {
                        had.resize(m * n, 0.0);
                        had[..m * n].copy_from_slice(&x[..m * n]);
                        fwht_block64(had, m, n);
                        &had[..m * n]
                    } else {
                        &x[..m * n]
                    };
                    // The explicit per-token Quant pass (Table 6 cost).
                    xqb.resize(m * n, 0);
                    rs.resize(m, 0.0);
                    per_token_quant(xin, m, n, *a_qmax, *a_clip, xqb, rs);
                    Self::int_matmul(pool, qw, xqb, m, Some(rs), rsum,
                                     scratch, out);
                }
            },
        }
    }

    fn embed(&self, tokens: &[u32], out: &mut Vec<f32>) {
        let d = self.model.config.d_model;
        out.resize(tokens.len() * d, 0.0);
        for (i, &t) in tokens.iter().enumerate() {
            let row = &self.model.embed[t as usize * d..(t as usize + 1) * d];
            let or = &mut out[i * d..(i + 1) * d];
            for c in 0..d {
                or[c] = row[c] * self.model.outlier_gain[c];
            }
        }
    }

    /// RoPE in place on a (m, d) buffer interpreted as (m, H, hd);
    /// `positions[i]` is the absolute position of row i.
    fn rope(&self, buf: &mut [f32], m: usize, positions: &[usize]) {
        let cfg = &self.model.config;
        let (h, hd, d) = (cfg.n_heads, cfg.head_dim(), cfg.d_model);
        let theta = cfg.rope_theta;
        for i in 0..m {
            let pos = positions[i] as f32;
            let row = &mut buf[i * d..(i + 1) * d];
            for head in 0..h {
                let hr = &mut row[head * hd..(head + 1) * hd];
                for p in 0..hd / 2 {
                    let inv = theta.powf(-(2.0 * p as f32) / hd as f32);
                    let ang = pos * inv;
                    let (sin, cos) = ang.sin_cos();
                    let a = hr[2 * p];
                    let b = hr[2 * p + 1];
                    hr[2 * p] = a * cos - b * sin;
                    hr[2 * p + 1] = a * sin + b * cos;
                }
            }
        }
    }

    /// One attention head-batched pass for a single query row against a
    /// cached K/V region of length `klen`. q: (d,), out: (d,).
    #[allow(clippy::too_many_arguments)]
    fn attend_one(&self, q: &[f32], kcache: &[f32], vcache: &[f32],
                  cache_stride: usize, klen: usize, scores: &mut Vec<f32>,
                  out: &mut [f32]) {
        let cfg = &self.model.config;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        scores.resize(klen, 0.0);
        for head in 0..h {
            let qh = &q[head * hd..(head + 1) * hd];
            // scores
            let mut maxv = f32::NEG_INFINITY;
            for t in 0..klen {
                let kh = &kcache[t * cache_stride + head * hd
                    ..t * cache_stride + (head + 1) * hd];
                let s = crate::quant::gemm::dot_f32(qh, kh) * scale;
                scores[t] = s;
                maxv = maxv.max(s);
            }
            // softmax
            let mut denom = 0f32;
            for s in scores[..klen].iter_mut() {
                *s = (*s - maxv).exp();
                denom += *s;
            }
            let inv = 1.0 / denom;
            // weighted value sum
            let oh = &mut out[head * hd..(head + 1) * hd];
            oh.fill(0.0);
            for t in 0..klen {
                let w = scores[t] * inv;
                let vh = &vcache[t * cache_stride + head * hd
                    ..t * cache_stride + (head + 1) * hd];
                for c in 0..hd {
                    oh[c] += w * vh[c];
                }
            }
        }
    }

    /// Resolve the KV scales a cache needs: `None` for f32 storage, the
    /// bundle's calibrated per-layer scales for int8 —
    /// [`EngineError::MissingKvScales`] when the bundle has none.
    fn kv_scales_for<'m>(&'m self, cache: &KvCache)
                         -> Result<Option<&'m [KvLayerScales]>, EngineError> {
        match cache.dtype() {
            KvDtype::F32 => Ok(None),
            KvDtype::Int8 => self
                .model
                .kv
                .as_deref()
                .map(Some)
                .ok_or(EngineError::MissingKvScales),
        }
    }

    /// One query row attended over layer `l` of `cache`, dispatching on
    /// the cache dtype: f32 storage runs the seed `attend_one`, int8
    /// storage runs the integer-domain path (`quant::kv::attend_one_i8`).
    /// Both are per-row order-fixed, so the §7 bitwise-determinism
    /// guarantee holds for either dtype.
    #[allow(clippy::too_many_arguments)]
    fn attend_cached(&self, cache: &KvCache, kvsc: Option<&[KvLayerScales]>,
                     l: usize, q: &[f32], klen: usize,
                     scores: &mut Vec<f32>, qq: &mut Vec<i8>,
                     out: &mut [f32]) {
        let cfg = &self.model.config;
        match cache.dtype() {
            KvDtype::F32 => self.attend_one(q, cache.layer_k_f32(l),
                                            cache.layer_v_f32(l), cfg.d_model,
                                            klen, scores, out),
            KvDtype::Int8 => {
                let sc = &kvsc.expect("validated int8 KV scales")[l];
                kv::attend_one_i8(q, cache.layer_k_i8(l), cache.layer_v_i8(l),
                                  sc, cfg.d_model, klen, cfg.n_heads, scores,
                                  qq, out);
            }
        }
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Prefill one sequence **continuing from `cache.len`**; fills cache
    /// positions `cache.len .. cache.len+t` and returns logits (t, vocab)
    /// in `ws.logits`. With `cache.len == 0` this is a plain prefill; with
    /// a non-empty cache it implements *chunked prefill* (the scheduler
    /// bounds decode stalls with it) and multi-turn prompt reuse.
    ///
    /// Capacity and KV-scale availability are validated **before** any
    /// state is touched: an `Err` leaves `cache` and `ws` unchanged.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache,
                   ws: &mut Workspace) -> Result<(), EngineError> {
        let cfg = &self.model.config;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let t = tokens.len();
        let m = t;
        let start = cache.len;
        if start + t > cache.cap {
            return Err(EngineError::KvOverflow {
                lane: 0,
                pos: start + t - 1,
                cap: cache.cap,
            });
        }
        let kvsc = self.kv_scales_for(cache)?;
        let positions: Vec<usize> = (start..start + t).collect();

        self.embed(tokens, &mut ws.x);
        ws.qbuf.resize(m * d, 0.0);
        ws.kbuf.resize(m * d, 0.0);
        ws.vbuf.resize(m * d, 0.0);
        ws.attn.resize(m * d, 0.0);
        ws.gate.resize(m * ff, 0.0);
        ws.up.resize(m * ff, 0.0);
        ws.ff.resize(m * ff, 0.0);
        ws.proj.resize(m * d, 0.0);

        for (l, layer) in self.model.layers.iter().enumerate() {
            // ---- attention ----
            if layer.attn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.attn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&self.pool, &layer.q, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&self.pool, &layer.k, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&self.pool, &layer.v, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.attn_norm.g, m, d, &mut ws.h);
                Self::linear(&self.pool, &layer.q, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&self.pool, &layer.k, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&self.pool, &layer.v, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            }
            self.rope(&mut ws.qbuf, m, &positions);
            self.rope(&mut ws.kbuf, m, &positions);
            for i in 0..t {
                cache.write(l, start + i, &ws.kbuf[i * d..(i + 1) * d],
                            &ws.vbuf[i * d..(i + 1) * d],
                            kvsc.map(|s| &s[l]));
            }
            // Causal attention over cached K/V — parallel across blocks
            // of query rows. Each task owns a disjoint slice of `attn`
            // and a private score buffer; per-row math is identical to
            // the serial path, so results are bitwise independent of the
            // thread count (DESIGN.md §7) for both KV dtypes.
            let cache_ref: &KvCache = cache;
            if self.pool.threads() == 1 {
                for i in 0..t {
                    self.attend_cached(cache_ref, kvsc, l,
                                       &ws.qbuf[i * d..(i + 1) * d],
                                       start + i + 1, &mut ws.scores,
                                       &mut ws.qint,
                                       &mut ws.attn[i * d..(i + 1) * d]);
                }
            } else {
                // Oversubscribe 4× — later rows attend to longer
                // prefixes, so equal-size blocks are unequal work.
                let rows = t.div_ceil(self.pool.threads() * 4).max(1);
                let qb = &ws.qbuf;
                let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
                for (bi, ablock) in
                    ws.attn[..t * d].chunks_mut(rows * d).enumerate()
                {
                    tasks.push(Box::new(move || {
                        let mut scores = Vec::new();
                        let mut qq = Vec::new();
                        for (ri, arow) in ablock.chunks_mut(d).enumerate() {
                            let i = bi * rows + ri;
                            self.attend_cached(cache_ref, kvsc, l,
                                               &qb[i * d..(i + 1) * d],
                                               start + i + 1, &mut scores,
                                               &mut qq, arow);
                        }
                    }));
                }
                self.pool.run(tasks);
            }
            Self::linear(&self.pool, &layer.o, Act::F32(&ws.attn), m,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
            // ---- ffn ----
            if layer.ffn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.ffn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&self.pool, &layer.gate, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&self.pool, &layer.up, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.ffn_norm.g, m, d, &mut ws.h);
                Self::linear(&self.pool, &layer.gate, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&self.pool, &layer.up, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            }
            // SiLU·up — elementwise, parallel over row blocks (exp() is
            // a real fraction of prefill at small d).
            if self.pool.threads() == 1 || m * ff < (1 << 15) {
                for i in 0..m * ff {
                    let g = ws.gate[i];
                    ws.ff[i] = g / (1.0 + (-g).exp()) * ws.up[i];
                }
            } else {
                let rows = m.div_ceil(self.pool.threads() * 2).max(1);
                let gb = &ws.gate;
                let ub = &ws.up;
                let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
                for (bi, fblock) in
                    ws.ff[..m * ff].chunks_mut(rows * ff).enumerate()
                {
                    tasks.push(Box::new(move || {
                        let off = bi * rows * ff;
                        for (k, fv) in fblock.iter_mut().enumerate() {
                            let g = gb[off + k];
                            *fv = g / (1.0 + (-g).exp()) * ub[off + k];
                        }
                    }));
                }
                self.pool.run(tasks);
            }
            Self::linear(&self.pool, &layer.down, Act::F32(&ws.ff), m,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
        }
        cache.len = start + t;
        // final norm + lm head
        ws.h.resize(m * d, 0.0);
        Self::rmsnorm_f32(&ws.x, &self.model.final_norm, m, d, &mut ws.h);
        ws.logits.resize(m * vocab, 0.0);
        par_gemm_f32(&self.pool, &ws.h, &self.model.lm_head_t, m, d, vocab,
                     &mut ws.logits);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Batched decode (continuous batching: one step over many sequences)
    // ------------------------------------------------------------------

    /// One decode step for a batch of sequences. `tokens[i]` is the next
    /// input token of sequence i; each sequence attends to its own cache
    /// (lanes may mix KV dtypes). Returns logits (B, vocab) in
    /// `ws.logits`.
    ///
    /// All lanes are validated **before** any state is touched: an `Err`
    /// names the offending lane and leaves every cache unchanged.
    pub fn decode_batch(&self, tokens: &[u32], caches: &mut [&mut KvCache],
                        ws: &mut Workspace) -> Result<(), EngineError> {
        let cfg = &self.model.config;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let b = tokens.len();
        assert_eq!(caches.len(), b);
        let m = b;
        for (i, c) in caches.iter().enumerate() {
            if c.len >= c.cap {
                return Err(EngineError::KvOverflow {
                    lane: i,
                    pos: c.len,
                    cap: c.cap,
                });
            }
        }
        let lane_scales: Vec<Option<&[KvLayerScales]>> = caches
            .iter()
            .map(|c| self.kv_scales_for(c))
            .collect::<Result<_, _>>()?;
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();

        self.embed(tokens, &mut ws.x);
        ws.qbuf.resize(m * d, 0.0);
        ws.kbuf.resize(m * d, 0.0);
        ws.vbuf.resize(m * d, 0.0);
        ws.attn.resize(m * d, 0.0);
        ws.gate.resize(m * ff, 0.0);
        ws.up.resize(m * ff, 0.0);
        ws.ff.resize(m * ff, 0.0);
        ws.proj.resize(m * d, 0.0);

        for (l, layer) in self.model.layers.iter().enumerate() {
            if layer.attn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.attn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&self.pool, &layer.q, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&self.pool, &layer.k, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&self.pool, &layer.v, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.attn_norm.g, m, d, &mut ws.h);
                Self::linear(&self.pool, &layer.q, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&self.pool, &layer.k, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&self.pool, &layer.v, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            }
            self.rope(&mut ws.qbuf, m, &positions);
            self.rope(&mut ws.kbuf, m, &positions);
            for (i, cache) in caches.iter_mut().enumerate() {
                let pos = positions[i];
                cache.write(l, pos, &ws.kbuf[i * d..(i + 1) * d],
                            &ws.vbuf[i * d..(i + 1) * d],
                            lane_scales[i].map(|s| &s[l]));
            }
            // Attention — parallel across batch lanes: each lane reads
            // its own cache and writes its own `attn` row, so lanes are
            // fully independent (DESIGN.md §7) for both KV dtypes.
            if self.pool.threads() == 1 || b == 1 {
                for (i, cache) in caches.iter().enumerate() {
                    self.attend_cached(cache, lane_scales[i], l,
                                       &ws.qbuf[i * d..(i + 1) * d],
                                       positions[i] + 1, &mut ws.scores,
                                       &mut ws.qint,
                                       &mut ws.attn[i * d..(i + 1) * d]);
                }
            } else {
                let qb = &ws.qbuf;
                let lanes: &[&mut KvCache] = &*caches;
                let lsc = &lane_scales;
                let mut tasks: Vec<ScopedTask<'_>> = Vec::new();
                for (i, (cache, arow)) in lanes
                    .iter()
                    .zip(ws.attn[..m * d].chunks_mut(d))
                    .enumerate()
                {
                    let klen = positions[i] + 1;
                    tasks.push(Box::new(move || {
                        let mut scores = Vec::new();
                        let mut qq = Vec::new();
                        self.attend_cached(cache, lsc[i], l,
                                           &qb[i * d..(i + 1) * d], klen,
                                           &mut scores, &mut qq, arow);
                    }));
                }
                self.pool.run(tasks);
            }
            Self::linear(&self.pool, &layer.o, Act::F32(&ws.attn), m,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
            if layer.ffn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.ffn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&self.pool, &layer.gate, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&self.pool, &layer.up, Act::I8(&ws.hq2), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.ffn_norm.g, m, d, &mut ws.h);
                Self::linear(&self.pool, &layer.gate, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&self.pool, &layer.up, Act::F32(&ws.h), m,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            }
            for i in 0..m * ff {
                let g = ws.gate[i];
                ws.ff[i] = g / (1.0 + (-g).exp()) * ws.up[i];
            }
            Self::linear(&self.pool, &layer.down, Act::F32(&ws.ff), m,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
        }
        for cache in caches.iter_mut() {
            cache.len += 1;
        }
        ws.h.resize(m * d, 0.0);
        Self::rmsnorm_f32(&ws.x, &self.model.final_norm, m, d, &mut ws.h);
        ws.logits.resize(m * vocab, 0.0);
        par_gemm_f32(&self.pool, &ws.h, &self.model.lm_head_t, m, d, vocab,
                     &mut ws.logits);
        Ok(())
    }

    /// Greedy generation helper (examples / integration tests), f32 KV.
    /// Sizes its own cache, so the only failure mode is a prompt longer
    /// than `max_seq` — kept panicking for call-site brevity.
    pub fn generate(&self, prompt: &[u32], max_new: usize, max_seq: usize)
                    -> Vec<u32> {
        self.generate_with(prompt, max_new, max_seq, KvDtype::F32)
            .expect("generate: prompt exceeds max_seq")
    }

    /// Greedy generation over an explicit KV-cache dtype.
    pub fn generate_with(&self, prompt: &[u32], max_new: usize,
                         max_seq: usize, kv_dtype: KvDtype)
                         -> Result<Vec<u32>, EngineError> {
        self.generate_seeded(prompt, max_new, max_seq, kv_dtype,
                             &Sampler::greedy())
    }

    /// Sampled generation: the engine-level path behind the serving
    /// contract's `GenerationParams`. Token *t* is drawn by
    /// `sampler.sample(logits, t)` — a pure function of the (bitwise
    /// thread-count-invariant) logits and the counter-based stream
    /// `(seed, t)` — so fixed-seed streams are bitwise identical for
    /// every thread count. A greedy sampler reproduces
    /// [`Engine::generate`] exactly.
    pub fn generate_seeded(&self, prompt: &[u32], max_new: usize,
                           max_seq: usize, kv_dtype: KvDtype,
                           sampler: &Sampler)
                           -> Result<Vec<u32>, EngineError> {
        let cfg = &self.model.config;
        let mut cache =
            KvCache::with_dtype(kv_dtype, cfg.n_layers, max_seq, cfg.d_model);
        let mut ws = Workspace::new();
        // prefill all but the last prompt token, then step
        self.prefill(prompt, &mut cache, &mut ws)?;
        let vocab = cfg.vocab;
        let last = &ws.logits[(prompt.len() - 1) * vocab..prompt.len() * vocab];
        let mut next = sampler.sample(last, 0);
        let mut out = vec![next];
        for step in 1..max_new as u64 {
            if cache.len + 1 >= max_seq {
                break;
            }
            let toks = [next];
            let mut caches = [&mut cache];
            self.decode_batch(&toks, &mut caches, &mut ws)?;
            next = sampler.sample(&ws.logits[..vocab], step);
            out.push(next);
        }
        Ok(out)
    }

    /// Attach probe-calibrated KV scales when the bundle carries none
    /// (pre-format-2 `.qmod`, fp16 baselines, synthetic models) so the
    /// int8-KV path serves everywhere. No-op for format-2 bundles — the
    /// single shared fallback behind the scheduler, CLI, benches and
    /// tests.
    pub fn ensure_kv_scales(&mut self) -> Result<(), EngineError> {
        if self.model.kv.is_some() {
            return Ok(());
        }
        let vocab = self.model.config.vocab as u32;
        let probe: Vec<u32> =
            (0..48u32).map(|i| (3 + i * 7) % vocab.max(1)).collect();
        let scales = self.calibrate_kv_scales(&probe)?;
        self.model.kv = Some(scales);
        Ok(())
    }

    /// Probe-based KV-scale calibration fallback: prefill `probe` through
    /// an f32 cache and derive per-channel K/V scales from the observed
    /// absmax. Per-head score scales approximate Q ranges by the K ranges
    /// (the two are projections of the same normed input; nothing binds
    /// their magnitudes, so this is a heuristic) with 3× clamp headroom —
    /// Q̂ saturates only if per-head |Q| exceeds 3× |K|, at the cost of
    /// ~1% extra score quantization error. The *real* path is build-time
    /// calibration in `python/compile` (format-2 bundles carry exact
    /// per-head Q statistics); prefer [`Engine::ensure_kv_scales`] unless
    /// a specific probe is needed.
    pub fn calibrate_kv_scales(&self, probe: &[u32])
                               -> Result<Vec<KvLayerScales>, EngineError> {
        let cfg = &self.model.config;
        let (d, h) = (cfg.d_model, cfg.n_heads);
        let hd = cfg.head_dim();
        let qmax = kv::KV_QMAX as f32;
        let mut cache = KvCache::new(cfg.n_layers, probe.len().max(1), d);
        let mut ws = Workspace::new();
        self.prefill(probe, &mut cache, &mut ws)?;
        let t = cache.len;
        let mut out = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let (kc, vc) = (cache.layer_k_f32(l), cache.layer_v_f32(l));
            let absmax = |plane: &[f32], c: usize| {
                (0..t).fold(1e-6f32, |a, r| a.max(plane[r * d + c].abs()))
            };
            let kabs: Vec<f32> = (0..d).map(|c| absmax(kc, c)).collect();
            let k_scale: Vec<f32> = kabs.iter().map(|a| a / qmax).collect();
            let v_scale: Vec<f32> =
                (0..d).map(|c| absmax(vc, c) / qmax).collect();
            let qk_scale: Vec<f32> = (0..h)
                .map(|hh| {
                    (0..hd).fold(1e-12f32, |a, i| {
                        let c = hh * hd + i;
                        a.max(kabs[c] * k_scale[c])
                    }) * 3.0 / qmax
                })
                .collect();
            out.push(KvLayerScales::new(k_scale, v_scale, qk_scale));
        }
        Ok(out)
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Seeded temperature / top-k / top-p token sampler (DESIGN.md §11).
///
/// `sample(logits, step)` is a **pure function** of its inputs: the RNG
/// is counter-based — draw `step` uses the stream keyed by
/// `(seed, step)`, never sequential state — so token streams cannot
/// depend on thread count, batch composition, or scheduling order.
/// `temperature == 0` short-circuits to [`argmax`] and is bitwise
/// identical to the seed greedy path (no RNG is touched at all).
#[derive(Clone, Debug, PartialEq)]
pub struct Sampler {
    temperature: f32,
    top_k: usize,
    top_p: f32,
    seed: u64,
}

impl Sampler {
    /// `top_k == 0` disables the top-k cut; `top_p == 1.0` disables the
    /// nucleus cut.
    pub fn new(temperature: f32, top_k: usize, top_p: f32, seed: u64)
               -> Self {
        Sampler { temperature, top_k, top_p, seed }
    }

    /// The deterministic argmax sampler (the `temperature == 0` case).
    pub fn greedy() -> Self {
        Sampler::new(0.0, 0, 1.0, 0)
    }

    /// `true` when sampling reduces to argmax (no RNG involved).
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Counter-based stream key: the SplitMix64 finalizer
    /// ([`crate::util::rng::mix64`]) over an odd-constant mix of
    /// `(seed, step)`. For a fixed seed, `step ↦ key` is injective
    /// (odd multiply then a bijective finalizer), giving one
    /// independent RNG stream per draw.
    fn stream_key(seed: u64, step: u64) -> u64 {
        crate::util::rng::mix64(
            seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Draw the `step`-th token from `logits`.
    pub fn sample(&self, logits: &[f32], step: u64) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits) as u32;
        }
        let inv_t = 1.0 / self.temperature as f64;
        // Pure temperature sampling (no top-k, no nucleus): exact
        // softmax walked in index order — no candidate ranking, no sort,
        // no allocation on the per-token hot path. Two sequential exp
        // passes (normalizer, then the walk), bitwise reproducible.
        if self.top_k == 0 && self.top_p >= 1.0 {
            let maxl = logits[argmax(logits)] as f64;
            let w = |l: f32| ((l as f64 - maxl) * inv_t).exp();
            let total: f64 = logits.iter().map(|&l| w(l)).sum();
            let mut rng = Rng::new(Self::stream_key(self.seed, step));
            let mut u = rng.f64() * total;
            for (i, &l) in logits.iter().enumerate() {
                u -= w(l);
                if u < 0.0 {
                    return i as u32;
                }
            }
            return (logits.len() - 1) as u32;
        }
        // Candidates ranked by (logit desc, index asc) — a total order,
        // so the ranking is deterministic even under ties. With a top-k
        // cut the boundary is selected in O(V) first and only the k
        // survivors are sorted (the full-vocab sort would dominate the
        // per-token cost at real vocab sizes); the selected set equals
        // the first k of the full sort because the order is total, so
        // streams are identical either way.
        let by_desc = |a: &u32, b: &u32| {
            logits[*b as usize]
                .total_cmp(&logits[*a as usize])
                .then(a.cmp(b))
        };
        let mut order: Vec<u32> = (0..logits.len() as u32).collect();
        if self.top_k > 0 && self.top_k < order.len() {
            let _ = order.select_nth_unstable_by(self.top_k - 1, by_desc);
            order.truncate(self.top_k);
        }
        order.sort_unstable_by(by_desc);
        // Tempered softmax over the candidate set (f64 accumulation;
        // strictly sequential, hence bitwise reproducible).
        let maxl = logits[order[0] as usize] as f64;
        let mut weights: Vec<f64> = order
            .iter()
            .map(|&i| ((logits[i as usize] as f64 - maxl) * inv_t).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        // Nucleus cut: smallest prefix with cumulative mass >= top_p
        // (candidates are already probability-sorted).
        if self.top_p < 1.0 {
            let mut cum = 0.0;
            let mut keep = weights.len();
            for (i, w) in weights.iter().enumerate() {
                cum += w / total;
                if cum >= self.top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
            weights.truncate(keep);
        }
        let kept: f64 = weights.iter().sum();
        let mut rng = Rng::new(Self::stream_key(self.seed, step));
        let mut u = rng.f64() * kept;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return order[i];
            }
        }
        // f64 rounding can leave u just above zero — last candidate.
        order[weights.len() - 1]
    }
}
