//! Quantized forward passes: prefill and batched decode with KV caches.
//!
//! Semantics mirror `python/compile/quant/qforward.py` exactly (validated
//! against the artifact goldens): same rounding, same clamp ranges, same
//! merged-norm → gather → integer-GEMM → epilogue pipeline. The static
//! MergeQuant path runs **zero** per-token quantization passes — the norm
//! emits integers (Eq. 4) and the epilogue is per-output-column (Eq. 5);
//! the dynamic baselines pay `quant::dynamic` passes per linear — exactly
//! the overhead the paper measures in Table 6.

use crate::quant::dynamic::per_token_quant;
use crate::quant::gemm::{
    epilogue_asym, epilogue_sym, gemm_f32, gemm_i8, gemm_i8_grouped,
    gemm_i8_packed4, rowsum_i8,
};
use crate::quant::hadamard::fwht_block64;
use crate::quant::reconstruct::reconstruct_i8;

use super::qmod::{Linear, Norm, QModel, QuantMode, QWeight};

const EPS: f32 = 1e-5;

/// Reusable scratch buffers — no allocation on the decode hot path after
/// the first step.
#[derive(Default)]
pub struct Workspace {
    pub x: Vec<f32>,        // residual stream (m, d)
    pub h: Vec<f32>,        // f32 norm output (m, d)
    pub hq: Vec<i8>,        // quantized norm output (m, d)
    pub hq2: Vec<i8>,       // reconstructed quantized activations (m, d)
    pub qbuf: Vec<f32>,     // q/k/v projections (m, d)
    pub kbuf: Vec<f32>,
    pub vbuf: Vec<f32>,
    pub attn: Vec<f32>,     // attention output (m, d)
    pub gate: Vec<f32>,     // (m, ff)
    pub up: Vec<f32>,
    pub ff: Vec<f32>,       // silu(gate)·up (m, ff)
    pub proj: Vec<f32>,     // o/down projection output (m, d)
    pub acc: Vec<i32>,      // integer GEMM accumulator
    pub xq: Vec<i8>,        // dynamic-quant activation buffer
    pub row_scale: Vec<f32>,
    pub row_sum: Vec<i32>,
    pub had: Vec<f32>,      // hadamard-transformed activations
    pub scratch_w: Vec<i8>, // unpacked weight row
    pub scores: Vec<f32>,   // attention score row (≤ max cache len)
    pub logits: Vec<f32>,
}

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current resident bytes across all scratch buffers (Table 3).
    pub fn bytes(&self) -> usize {
        self.x.len() * 4
            + self.h.len() * 4
            + self.hq.len()
            + self.hq2.len()
            + (self.qbuf.len() + self.kbuf.len() + self.vbuf.len()) * 4
            + (self.attn.len() + self.gate.len() + self.up.len()
                + self.ff.len() + self.proj.len()) * 4
            + self.acc.len() * 4
            + self.xq.len()
            + self.row_scale.len() * 4
            + self.row_sum.len() * 4
            + self.had.len() * 4
            + self.scratch_w.len()
            + self.scores.len() * 4
            + self.logits.len() * 4
    }
}

/// Per-sequence KV cache: layout (L, cap, d) with d = H·hd.
pub struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    pub cap: usize,
    pub len: usize,
    pub n_layers: usize,
    d: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, cap: usize, d: usize) -> Self {
        KvCache {
            k: vec![0f32; n_layers * cap * d],
            v: vec![0f32; n_layers * cap * d],
            cap,
            len: 0,
            n_layers,
            d,
        }
    }

    #[inline]
    fn layer_k(&self, l: usize) -> &[f32] {
        &self.k[l * self.cap * self.d..(l + 1) * self.cap * self.d]
    }

    #[inline]
    fn layer_v(&self, l: usize) -> &[f32] {
        &self.v[l * self.cap * self.d..(l + 1) * self.cap * self.d]
    }

    #[inline]
    fn write(&mut self, l: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        assert!(pos < self.cap, "KV cache overflow: {pos} >= {}", self.cap);
        let off = l * self.cap * self.d + pos * self.d;
        self.k[off..off + self.d].copy_from_slice(k_row);
        self.v[off..off + self.d].copy_from_slice(v_row);
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }
}

enum Act<'a> {
    F32(&'a [f32]),
    I8(&'a [i8]),
}

pub struct Engine {
    pub model: QModel,
}

impl Engine {
    pub fn new(model: QModel) -> Self {
        Engine { model }
    }

    pub fn config(&self) -> &super::qmod::ModelConfig {
        &self.model.config
    }

    // ------------------------------------------------------------------
    // Primitive ops
    // ------------------------------------------------------------------

    fn rmsnorm_f32(x: &[f32], g: &[f32], m: usize, d: usize, out: &mut [f32]) {
        for i in 0..m {
            let row = &x[i * d..(i + 1) * d];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + EPS).sqrt();
            let or = &mut out[i * d..(i + 1) * d];
            for c in 0..d {
                or[c] = row[c] * inv * g[c];
            }
        }
    }

    /// Merged-multiplier norm emitting integers (Eq. 4), then the
    /// dimension-reconstruction gather (App. C.1). Result lands in `hq2`.
    fn rmsnorm_quant(x: &[f32], norm: &Norm, m: usize, d: usize,
                     hq: &mut [i8], hq2: &mut [i8]) {
        let qmax = norm.quant_qmax.unwrap() as f32;
        for i in 0..m {
            let row = &x[i * d..(i + 1) * d];
            let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let inv = 1.0 / (ms + EPS).sqrt();
            let qr = &mut hq[i * d..(i + 1) * d];
            for c in 0..d {
                let v = (row[c] * inv * norm.g[c]).round();
                qr[c] = v.clamp(-qmax, qmax) as i8;
            }
        }
        if let Some(idx) = &norm.recon_idx {
            reconstruct_i8(&hq[..m * d], idx, m, d, &mut hq2[..m * d]);
        } else {
            hq2[..m * d].copy_from_slice(&hq[..m * d]);
        }
    }

    /// Integer GEMM + rescale epilogue (group-0 fast path, grouped general).
    #[allow(clippy::too_many_arguments)]
    fn int_matmul(qw: &QWeight, xq: &[i8], m: usize, row_scale: Option<&[f32]>,
                  acc: &mut Vec<i32>, rsum: &mut Vec<i32>,
                  scratch: &mut Vec<i8>, out: &mut [f32]) {
        let (n, j) = (qw.n, qw.j);
        if qw.group != 0 {
            gemm_i8_grouped(&xq[..m * n], &qw.wt, m, n, j, qw.group,
                            &qw.scale, qw.zero.as_deref(), row_scale,
                            &mut out[..m * j]);
            return;
        }
        acc.resize(m * j, 0);
        // Small m (decode GEMV): the per-row nibble unpack would double the
        // work per weight element, so use the i8 mirror; large m amortizes
        // the unpack across rows and enjoys the halved weight footprint.
        match &qw.packed {
            Some(p) if m >= 8 => gemm_i8_packed4(&xq[..m * n], p, m, n, j,
                                                 scratch, &mut acc[..m * j]),
            _ => gemm_i8(&xq[..m * n], &qw.wt, m, n, j, &mut acc[..m * j]),
        }
        match &qw.zero {
            Some(z) => {
                rowsum_i8(&xq[..m * n], m, n, rsum);
                epilogue_asym(&acc[..m * j], rsum, z, &qw.scale, row_scale,
                              m, j, &mut out[..m * j]);
            }
            None => epilogue_sym(&acc[..m * j], &qw.scale, row_scale, m, j,
                                 &mut out[..m * j]),
        }
    }

    /// Apply one linear to m rows; writes (m, j) into `out`. Scratch
    /// buffers are passed individually so callers can split a Workspace.
    #[allow(clippy::too_many_arguments)]
    fn linear(lin: &Linear, input: Act, m: usize, acc: &mut Vec<i32>,
              xqb: &mut Vec<i8>, rs: &mut Vec<f32>, rsum: &mut Vec<i32>,
              had: &mut Vec<f32>, scratch: &mut Vec<i8>, out: &mut [f32]) {
        match lin {
            Linear::Fp { wt, n, j } => {
                let x = match input {
                    Act::F32(x) => x,
                    Act::I8(_) => unreachable!("fp linear needs f32 input"),
                };
                gemm_f32(&x[..m * n], wt, m, *n, *j, &mut out[..m * j]);
            }
            Linear::Quant { qw, mode } => match mode {
                QuantMode::Static => {
                    let xq = match input {
                        Act::I8(xq) => xq,
                        Act::F32(_) => unreachable!("static linear needs i8"),
                    };
                    Self::int_matmul(qw, xq, m, None, acc, rsum, scratch, out);
                }
                QuantMode::TensorStatic { a_scale, a_qmax } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("tensor_static needs f32"),
                    };
                    let n = qw.n;
                    xqb.resize(m * n, 0);
                    let inv = 1.0 / *a_scale;
                    let qm = *a_qmax as f32;
                    for (q, &v) in xqb[..m * n].iter_mut().zip(&x[..m * n]) {
                        *q = (v * inv).round().clamp(-qm, qm) as i8;
                    }
                    rs.clear();
                    rs.resize(m, *a_scale);
                    Self::int_matmul(qw, xqb, m, Some(rs), acc, rsum, scratch,
                                     out);
                }
                QuantMode::Dynamic { a_qmax, a_clip, hadamard } => {
                    let x = match input {
                        Act::F32(x) => x,
                        _ => unreachable!("dynamic needs f32"),
                    };
                    let n = qw.n;
                    let xin: &[f32] = if *hadamard {
                        had.resize(m * n, 0.0);
                        had[..m * n].copy_from_slice(&x[..m * n]);
                        fwht_block64(had, m, n);
                        &had[..m * n]
                    } else {
                        &x[..m * n]
                    };
                    // The explicit per-token Quant pass (Table 6 cost).
                    xqb.resize(m * n, 0);
                    rs.resize(m, 0.0);
                    per_token_quant(xin, m, n, *a_qmax, *a_clip, xqb, rs);
                    Self::int_matmul(qw, xqb, m, Some(rs), acc, rsum, scratch,
                                     out);
                }
            },
        }
    }

    fn embed(&self, tokens: &[u32], out: &mut Vec<f32>) {
        let d = self.model.config.d_model;
        out.resize(tokens.len() * d, 0.0);
        for (i, &t) in tokens.iter().enumerate() {
            let row = &self.model.embed[t as usize * d..(t as usize + 1) * d];
            let or = &mut out[i * d..(i + 1) * d];
            for c in 0..d {
                or[c] = row[c] * self.model.outlier_gain[c];
            }
        }
    }

    /// RoPE in place on a (m, d) buffer interpreted as (m, H, hd);
    /// `positions[i]` is the absolute position of row i.
    fn rope(&self, buf: &mut [f32], m: usize, positions: &[usize]) {
        let cfg = &self.model.config;
        let (h, hd, d) = (cfg.n_heads, cfg.head_dim(), cfg.d_model);
        let theta = cfg.rope_theta;
        for i in 0..m {
            let pos = positions[i] as f32;
            let row = &mut buf[i * d..(i + 1) * d];
            for head in 0..h {
                let hr = &mut row[head * hd..(head + 1) * hd];
                for p in 0..hd / 2 {
                    let inv = theta.powf(-(2.0 * p as f32) / hd as f32);
                    let ang = pos * inv;
                    let (sin, cos) = ang.sin_cos();
                    let a = hr[2 * p];
                    let b = hr[2 * p + 1];
                    hr[2 * p] = a * cos - b * sin;
                    hr[2 * p + 1] = a * sin + b * cos;
                }
            }
        }
    }

    /// One attention head-batched pass for a single query row against a
    /// cached K/V region of length `klen`. q: (d,), out: (d,).
    #[allow(clippy::too_many_arguments)]
    fn attend_one(&self, q: &[f32], kcache: &[f32], vcache: &[f32],
                  cache_stride: usize, klen: usize, scores: &mut Vec<f32>,
                  out: &mut [f32]) {
        let cfg = &self.model.config;
        let (h, hd) = (cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        scores.resize(klen, 0.0);
        for head in 0..h {
            let qh = &q[head * hd..(head + 1) * hd];
            // scores
            let mut maxv = f32::NEG_INFINITY;
            for t in 0..klen {
                let kh = &kcache[t * cache_stride + head * hd
                    ..t * cache_stride + (head + 1) * hd];
                let s = crate::quant::gemm::dot_f32(qh, kh) * scale;
                scores[t] = s;
                maxv = maxv.max(s);
            }
            // softmax
            let mut denom = 0f32;
            for s in scores[..klen].iter_mut() {
                *s = (*s - maxv).exp();
                denom += *s;
            }
            let inv = 1.0 / denom;
            // weighted value sum
            let oh = &mut out[head * hd..(head + 1) * hd];
            oh.fill(0.0);
            for t in 0..klen {
                let w = scores[t] * inv;
                let vh = &vcache[t * cache_stride + head * hd
                    ..t * cache_stride + (head + 1) * hd];
                for c in 0..hd {
                    oh[c] += w * vh[c];
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // Prefill
    // ------------------------------------------------------------------

    /// Prefill one sequence **continuing from `cache.len`**; fills cache
    /// positions `cache.len .. cache.len+t` and returns logits (t, vocab)
    /// in `ws.logits`. With `cache.len == 0` this is a plain prefill; with
    /// a non-empty cache it implements *chunked prefill* (the scheduler
    /// bounds decode stalls with it) and multi-turn prompt reuse.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache,
                   ws: &mut Workspace) {
        let cfg = &self.model.config;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let t = tokens.len();
        let m = t;
        let start = cache.len;
        let positions: Vec<usize> = (start..start + t).collect();

        self.embed(tokens, &mut ws.x);
        ws.qbuf.resize(m * d, 0.0);
        ws.kbuf.resize(m * d, 0.0);
        ws.vbuf.resize(m * d, 0.0);
        ws.attn.resize(m * d, 0.0);
        ws.gate.resize(m * ff, 0.0);
        ws.up.resize(m * ff, 0.0);
        ws.ff.resize(m * ff, 0.0);
        ws.proj.resize(m * d, 0.0);

        for (l, layer) in self.model.layers.iter().enumerate() {
            // ---- attention ----
            if layer.attn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.attn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&layer.q, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&layer.k, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&layer.v, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.attn_norm.g, m, d, &mut ws.h);
                Self::linear(&layer.q, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&layer.k, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&layer.v, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            }
            self.rope(&mut ws.qbuf, m, &positions);
            self.rope(&mut ws.kbuf, m, &positions);
            for i in 0..t {
                cache.write(l, start + i, &ws.kbuf[i * d..(i + 1) * d],
                            &ws.vbuf[i * d..(i + 1) * d]);
            }
            // causal attention, row-wise over cached K/V
            for i in 0..t {
                self.attend_one(&ws.qbuf[i * d..(i + 1) * d],
                                cache.layer_k(l), cache.layer_v(l),
                                d, start + i + 1, &mut ws.scores,
                                &mut ws.attn[i * d..(i + 1) * d]);
            }
            Self::linear(&layer.o, Act::F32(&ws.attn), m, &mut ws.acc,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
            // ---- ffn ----
            if layer.ffn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.ffn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&layer.gate, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&layer.up, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.ffn_norm.g, m, d, &mut ws.h);
                Self::linear(&layer.gate, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&layer.up, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            }
            for i in 0..m * ff {
                let g = ws.gate[i];
                ws.ff[i] = g / (1.0 + (-g).exp()) * ws.up[i]; // SiLU·up
            }
            Self::linear(&layer.down, Act::F32(&ws.ff), m, &mut ws.acc,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
        }
        cache.len = start + t;
        // final norm + lm head
        ws.h.resize(m * d, 0.0);
        Self::rmsnorm_f32(&ws.x, &self.model.final_norm, m, d, &mut ws.h);
        ws.logits.resize(m * vocab, 0.0);
        gemm_f32(&ws.h, &self.model.lm_head_t, m, d, vocab, &mut ws.logits);
    }

    // ------------------------------------------------------------------
    // Batched decode (continuous batching: one step over many sequences)
    // ------------------------------------------------------------------

    /// One decode step for a batch of sequences. `tokens[i]` is the next
    /// input token of sequence i; each sequence attends to its own cache.
    /// Returns logits (B, vocab) in `ws.logits`.
    pub fn decode_batch(&self, tokens: &[u32], caches: &mut [&mut KvCache],
                        ws: &mut Workspace) {
        let cfg = &self.model.config;
        let (d, ff, vocab) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let b = tokens.len();
        assert_eq!(caches.len(), b);
        let m = b;
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();

        self.embed(tokens, &mut ws.x);
        ws.qbuf.resize(m * d, 0.0);
        ws.kbuf.resize(m * d, 0.0);
        ws.vbuf.resize(m * d, 0.0);
        ws.attn.resize(m * d, 0.0);
        ws.gate.resize(m * ff, 0.0);
        ws.up.resize(m * ff, 0.0);
        ws.ff.resize(m * ff, 0.0);
        ws.proj.resize(m * d, 0.0);

        for (l, layer) in self.model.layers.iter().enumerate() {
            if layer.attn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.attn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&layer.q, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&layer.k, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&layer.v, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.attn_norm.g, m, d, &mut ws.h);
                Self::linear(&layer.q, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.qbuf);
                Self::linear(&layer.k, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.kbuf);
                Self::linear(&layer.v, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.vbuf);
            }
            self.rope(&mut ws.qbuf, m, &positions);
            self.rope(&mut ws.kbuf, m, &positions);
            for (i, cache) in caches.iter_mut().enumerate() {
                let pos = positions[i];
                cache.write(l, pos, &ws.kbuf[i * d..(i + 1) * d],
                            &ws.vbuf[i * d..(i + 1) * d]);
            }
            for (i, cache) in caches.iter().enumerate() {
                self.attend_one(&ws.qbuf[i * d..(i + 1) * d],
                                cache.layer_k(l), cache.layer_v(l),
                                d, positions[i] + 1, &mut ws.scores,
                                &mut ws.attn[i * d..(i + 1) * d]);
            }
            Self::linear(&layer.o, Act::F32(&ws.attn), m, &mut ws.acc,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
            if layer.ffn_norm.quant_qmax.is_some() {
                ws.hq.resize(m * d, 0);
                ws.hq2.resize(m * d, 0);
                Self::rmsnorm_quant(&ws.x, &layer.ffn_norm, m, d,
                                    &mut ws.hq, &mut ws.hq2);
                Self::linear(&layer.gate, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&layer.up, Act::I8(&ws.hq2), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            } else {
                ws.h.resize(m * d, 0.0);
                Self::rmsnorm_f32(&ws.x, &layer.ffn_norm.g, m, d, &mut ws.h);
                Self::linear(&layer.gate, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.gate);
                Self::linear(&layer.up, Act::F32(&ws.h), m, &mut ws.acc,
                             &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                             &mut ws.had, &mut ws.scratch_w, &mut ws.up);
            }
            for i in 0..m * ff {
                let g = ws.gate[i];
                ws.ff[i] = g / (1.0 + (-g).exp()) * ws.up[i];
            }
            Self::linear(&layer.down, Act::F32(&ws.ff), m, &mut ws.acc,
                         &mut ws.xq, &mut ws.row_scale, &mut ws.row_sum,
                         &mut ws.had, &mut ws.scratch_w, &mut ws.proj);
            for (xv, pv) in ws.x.iter_mut().zip(&ws.proj) {
                *xv += pv;
            }
        }
        for cache in caches.iter_mut() {
            cache.len += 1;
        }
        ws.h.resize(m * d, 0.0);
        Self::rmsnorm_f32(&ws.x, &self.model.final_norm, m, d, &mut ws.h);
        ws.logits.resize(m * vocab, 0.0);
        gemm_f32(&ws.h, &self.model.lm_head_t, m, d, vocab, &mut ws.logits);
    }

    /// Greedy generation helper (examples / integration tests).
    pub fn generate(&self, prompt: &[u32], max_new: usize, max_seq: usize)
                    -> Vec<u32> {
        let cfg = &self.model.config;
        let mut cache = KvCache::new(cfg.n_layers, max_seq, cfg.d_model);
        let mut ws = Workspace::new();
        // prefill all but the last prompt token, then step
        self.prefill(prompt, &mut cache, &mut ws);
        let vocab = cfg.vocab;
        let last = &ws.logits[(prompt.len() - 1) * vocab..prompt.len() * vocab];
        let mut next = argmax(last) as u32;
        let mut out = vec![next];
        for _ in 1..max_new {
            if cache.len + 1 >= max_seq {
                break;
            }
            let toks = [next];
            let mut caches = [&mut cache];
            self.decode_batch(&toks, &mut caches, &mut ws);
            next = argmax(&ws.logits[..vocab]) as u32;
            out.push(next);
        }
        out
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}
