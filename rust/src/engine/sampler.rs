//! Token selection: the seeded temperature / top-k / top-p sampler.
//!
//! [`Sampler`] is the **single** token-selection entry point of the
//! engine and the serving layer: greedy decoding is `Sampler::greedy()`
//! (or any `temperature == 0` sampler), which short-circuits to
//! [`Sampler::argmax`] without touching the RNG — bitwise identical to
//! the seed greedy path. Every other temperature draws from a
//! counter-based per-request stream (DESIGN.md §11).

use crate::util::rng::Rng;

/// Index of the largest logit (first under ties). The greedy
/// `temperature == 0` selection rule; exposed as an associated function
/// so tests and benches share the exact tie-breaking the engine uses.
fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Seeded temperature / top-k / top-p token sampler (DESIGN.md §11).
///
/// `sample(logits, step)` is a **pure function** of its inputs: the RNG
/// is counter-based — draw `step` uses the stream keyed by
/// `(seed, step)`, never sequential state — so token streams cannot
/// depend on thread count, batch composition, or scheduling order.
/// `temperature == 0` short-circuits to [`Sampler::argmax`] and is
/// bitwise identical to the seed greedy path (no RNG is touched at all).
///
/// **Resume-at-step contract** (DESIGN.md §15): because there is no
/// sequential RNG state, a stream interrupted after `k` draws resumes
/// bitwise-identically by constructing a fresh `Sampler` from the same
/// params and calling `sample(logits, k)` onward — the scheduler's
/// preemption path relies on this to make victim eviction invisible in
/// the token stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Sampler {
    temperature: f32,
    top_k: usize,
    top_p: f32,
    seed: u64,
}

impl Sampler {
    /// `top_k == 0` disables the top-k cut; `top_p == 1.0` disables the
    /// nucleus cut.
    pub fn new(temperature: f32, top_k: usize, top_p: f32, seed: u64)
               -> Self {
        Sampler { temperature, top_k, top_p, seed }
    }

    /// The deterministic argmax sampler (the `temperature == 0` case).
    pub fn greedy() -> Self {
        Sampler::new(0.0, 0, 1.0, 0)
    }

    /// `true` when sampling reduces to argmax (no RNG involved).
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Index of the largest logit (first under ties) — the greedy
    /// selection rule, shared by `temperature == 0` sampling and by
    /// tests/benches that need raw argmax over a logits row.
    pub fn argmax(logits: &[f32]) -> usize {
        argmax(logits)
    }

    /// Counter-based stream key: the SplitMix64 finalizer
    /// ([`crate::util::rng::mix64`]) over an odd-constant mix of
    /// `(seed, step)`. For a fixed seed, `step ↦ key` is injective
    /// (odd multiply then a bijective finalizer), giving one
    /// independent RNG stream per draw.
    fn stream_key(seed: u64, step: u64) -> u64 {
        crate::util::rng::mix64(
            seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Draw the `step`-th token from `logits`.
    pub fn sample(&self, logits: &[f32], step: u64) -> u32 {
        if self.temperature <= 0.0 {
            return argmax(logits) as u32;
        }
        let inv_t = 1.0 / self.temperature as f64;
        // Pure temperature sampling (no top-k, no nucleus): exact
        // softmax walked in index order — no candidate ranking, no sort,
        // no allocation on the per-token hot path. Two sequential exp
        // passes (normalizer, then the walk), bitwise reproducible.
        if self.top_k == 0 && self.top_p >= 1.0 {
            let maxl = logits[argmax(logits)] as f64;
            let w = |l: f32| ((l as f64 - maxl) * inv_t).exp();
            let total: f64 = logits.iter().map(|&l| w(l)).sum();
            let mut rng = Rng::new(Self::stream_key(self.seed, step));
            let mut u = rng.f64() * total;
            for (i, &l) in logits.iter().enumerate() {
                u -= w(l);
                if u < 0.0 {
                    return i as u32;
                }
            }
            return (logits.len() - 1) as u32;
        }
        // Candidates ranked by (logit desc, index asc) — a total order,
        // so the ranking is deterministic even under ties. With a top-k
        // cut the boundary is selected in O(V) first and only the k
        // survivors are sorted (the full-vocab sort would dominate the
        // per-token cost at real vocab sizes); the selected set equals
        // the first k of the full sort because the order is total, so
        // streams are identical either way.
        let by_desc = |a: &u32, b: &u32| {
            logits[*b as usize]
                .total_cmp(&logits[*a as usize])
                .then(a.cmp(b))
        };
        let mut order: Vec<u32> = (0..logits.len() as u32).collect();
        if self.top_k > 0 && self.top_k < order.len() {
            let _ = order.select_nth_unstable_by(self.top_k - 1, by_desc);
            order.truncate(self.top_k);
        }
        order.sort_unstable_by(by_desc);
        // Tempered softmax over the candidate set (f64 accumulation;
        // strictly sequential, hence bitwise reproducible).
        let maxl = logits[order[0] as usize] as f64;
        let mut weights: Vec<f64> = order
            .iter()
            .map(|&i| ((logits[i as usize] as f64 - maxl) * inv_t).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        // Nucleus cut: smallest prefix with cumulative mass >= top_p
        // (candidates are already probability-sorted).
        if self.top_p < 1.0 {
            let mut cum = 0.0;
            let mut keep = weights.len();
            for (i, w) in weights.iter().enumerate() {
                cum += w / total;
                if cum >= self.top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
            weights.truncate(keep);
        }
        let kept: f64 = weights.iter().sum();
        let mut rng = Rng::new(Self::stream_key(self.seed, step));
        let mut u = rng.f64() * kept;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return order[i];
            }
        }
        // f64 rounding can leave u just above zero — last candidate.
        order[weights.len() - 1]
    }
}
