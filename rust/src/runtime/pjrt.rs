//! PJRT runtime: load AOT-lowered HLO text (produced by
//! `python/compile/aot.py` from the JAX/Pallas layers) and execute it on
//! the CPU PJRT client via the `xla` crate. Pattern follows
//! /opt/xla-example/load_hlo (HLO *text* interchange — serialized protos
//! from jax ≥ 0.5 are rejected by xla_extension 0.5.1).
//!
//! Role in the system: parity oracle for the native [`crate::engine`]
//! (the exported JAX graphs and the Rust engine must agree on the same
//! bundles) and a second execution backend for the coordinator.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

/// A compiled HLO executable plus bookkeeping.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// PJRT client wrapper with an executable registry.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, Executable>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file under a registry name.
    pub fn load_hlo(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.executables
            .insert(name.to_string(), Executable { exe, name: name.into() });
        Ok(())
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute with f32/i32 literals; returns the flattened elements of
    /// each tuple output. The AOT path lowers with `return_tuple=True`, so
    /// the single on-device result is a tuple.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal])
                   -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("executable {name} not loaded"))?;
        let result = exe.exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        Ok(outs)
    }

    /// Convenience: run on f32 buffers (tokens passed as i32 literal).
    pub fn execute_prefill_logits(&self, name: &str, tokens: &[i32],
                                  batch: usize, seq: usize)
                                  -> Result<Vec<f32>> {
        let lit = xla::Literal::vec1(tokens)
            .reshape(&[batch as i64, seq as i64])?;
        let outs = self.execute(name, &[lit])?;
        let logits = outs[0].to_vec::<f32>()?;
        Ok(logits)
    }
}

/// Build a literal from an f32 slice with a shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn literal_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}
