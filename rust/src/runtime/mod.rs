//! PJRT runtime backend: load AOT-lowered HLO text (produced by
//! `python/compile/aot.py` from the JAX/Pallas layers) and execute it as
//! the engine's parity oracle (DESIGN.md §1 layer 2, §9 validation).
//!
//! The real implementation ([`pjrt`]) needs the external `xla` crate
//! (xla-rs / xla_extension 0.5.1), which is not in the vendored registry
//! — it is gated behind the `pjrt` cargo feature. Default builds get
//! [`stub`]: the same `Runtime` API surface, erroring at construction
//! with an actionable message, so the CLI, tests and examples compile
//! and the artifact-parity tests skip gracefully.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;
