//! No-PJRT stub: same `Runtime` surface as `runtime/pjrt.rs`, but every
//! constructor fails with a pointer at the `pjrt` feature. Keeps default
//! (offline, no-xla) builds compiling end to end.

use std::path::Path;

use anyhow::{bail, Result};

const HINT: &str = "PJRT backend unavailable: build with `--features pjrt` \
                    (requires the external `xla` crate, see rust/Cargo.toml)";

/// PJRT client wrapper (stub — construction always fails).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always errors in the stub build; the real backend lives behind the
    /// `pjrt` feature.
    pub fn cpu() -> Result<Self> {
        bail!("{HINT}");
    }

    /// Platform name of the PJRT client (unreachable in the stub).
    pub fn platform(&self) -> String {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Load + compile an HLO text file (unreachable in the stub).
    pub fn load_hlo(&mut self, _name: &str, _path: &Path) -> Result<()> {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Whether an executable is registered (unreachable in the stub).
    pub fn has(&self, _name: &str) -> bool {
        unreachable!("stub Runtime cannot be constructed")
    }

    /// Execute a prefill graph on token input (unreachable in the stub).
    pub fn execute_prefill_logits(&self, _name: &str, _tokens: &[i32],
                                  _batch: usize, _seq: usize)
                                  -> Result<Vec<f32>> {
        unreachable!("stub Runtime cannot be constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_with_feature_hint() {
        let e = Runtime::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("pjrt"), "{e}");
    }
}
