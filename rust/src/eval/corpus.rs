//! Loaders for the exported token streams and task files.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Read a little-endian i32 token stream (`*.i32` artifact files).
pub fn load_tokens(path: &Path) -> Result<Vec<u32>> {
    let raw = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    anyhow::ensure!(raw.len() % 4 == 0, "token file not multiple of 4");
    Ok(raw
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u32)
        .collect())
}

/// Read a `*.f32` blob (golden logits).
pub fn load_f32(path: &Path) -> Result<Vec<f32>> {
    let raw = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn load_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Json::parse(&text).map_err(anyhow::Error::msg)
}

/// Validation stream of one corpus from the artifacts tree.
pub fn val_stream(artifacts: &Path, corpus: &str) -> Result<Vec<u32>> {
    load_tokens(&artifacts.join("corpora").join(format!("{corpus}.val.i32")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_roundtrip(){
        let dir = std::env::temp_dir().join("mq_corpus_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.i32");
        let vals: Vec<i32> = vec![0, 5, 511, 100000];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        let toks = load_tokens(&p).unwrap();
        assert_eq!(toks, vec![0u32, 5, 511, 100000]);
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join("mq_corpus_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.i32");
        std::fs::write(&p, [1u8, 2, 3]).unwrap();
        assert!(load_tokens(&p).is_err());
    }
}
