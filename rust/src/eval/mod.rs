//! Evaluation harness: perplexity over the exported corpora and the five
//! zero-shot choice tasks, scored exactly like lm-eval-harness
//! (length-normalized log-likelihood). Powers Tables 1/4/5/7 and Fig. 1.

pub mod corpus;

use crate::engine::{Engine, KvCache, Workspace};
use crate::util::json::Json;

/// log-softmax of one row, returning logp[target].
fn logp_target(logits: &[f32], target: usize) -> f64 {
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let denom: f64 =
        logits.iter().map(|&v| ((v - maxv) as f64).exp()).sum();
    (logits[target] - maxv) as f64 - denom.ln()
}

/// Perplexity over a token stream with non-overlapping windows of `seq`
/// (mirrors `python/compile/model.py::perplexity`).
pub fn perplexity(engine: &Engine, tokens: &[u32], seq: usize) -> f64 {
    let cfg = engine.config();
    let vocab = cfg.vocab;
    let n = (tokens.len() - 1) / seq;
    let mut ws = Workspace::new();
    let mut cache = KvCache::new(cfg.n_layers, seq, cfg.d_model);
    let mut total = 0f64;
    let mut count = 0usize;
    for w in 0..n {
        let x = &tokens[w * seq..(w + 1) * seq];
        cache.reset();
        engine.prefill(x, &mut cache, &mut ws).expect("eval window fits cache");
        for i in 0..seq {
            let target = tokens[w * seq + i + 1] as usize;
            let row = &ws.logits[i * vocab..(i + 1) * vocab];
            total -= logp_target(row, target);
            count += 1;
        }
    }
    (total / count.max(1) as f64).exp()
}

/// One item of a choice task.
pub struct ChoiceItem {
    pub prefix: Vec<u32>,
    pub choices: Vec<Vec<u32>>,
    pub answer: usize,
}

pub fn parse_task(json: &Json) -> anyhow::Result<Vec<ChoiceItem>> {
    let arr = json.as_arr().ok_or_else(|| anyhow::anyhow!("task not array"))?;
    let mut out = Vec::new();
    for it in arr {
        let prefix = it
            .req("prefix")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap() as u32)
            .collect();
        let choices = it
            .req("choices")
            .map_err(anyhow::Error::msg)?
            .as_arr()
            .unwrap()
            .iter()
            .map(|ch| {
                ch.as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_usize().unwrap() as u32)
                    .collect()
            })
            .collect();
        let answer = it.req_usize("answer").map_err(anyhow::Error::msg)?;
        out.push(ChoiceItem { prefix, choices, answer });
    }
    Ok(out)
}

/// Accuracy under length-normalized log-likelihood scoring.
pub fn choice_accuracy(engine: &Engine, items: &[ChoiceItem]) -> f64 {
    let cfg = engine.config();
    let vocab = cfg.vocab;
    let mut ws = Workspace::new();
    let mut correct = 0usize;
    for it in items {
        let mut best = f64::NEG_INFINITY;
        let mut best_i = 0usize;
        for (ci, ch) in it.choices.iter().enumerate() {
            let mut toks = it.prefix.clone();
            toks.extend_from_slice(ch);
            let mut cache =
                KvCache::new(cfg.n_layers, toks.len(), cfg.d_model);
            engine.prefill(&toks, &mut cache, &mut ws).expect("choice fits cache");
            let mut ll = 0f64;
            for pos in it.prefix.len() - 1..toks.len() - 1 {
                let row = &ws.logits[pos * vocab..(pos + 1) * vocab];
                ll += logp_target(row, toks[pos + 1] as usize);
            }
            let score = ll / ch.len().max(1) as f64;
            if score > best {
                best = score;
                best_i = ci;
            }
        }
        if best_i == it.answer {
            correct += 1;
        }
    }
    correct as f64 / items.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logp_target_is_log_softmax() {
        let logits = [1.0f32, 2.0, 3.0];
        let lp = logp_target(&logits, 2);
        let denom: f64 = logits.iter().map(|&v| (v as f64).exp()).sum();
        let want = (3.0f64).exp().ln() - denom.ln();
        assert!((lp - want).abs() < 1e-9);
    }

    #[test]
    fn parse_task_roundtrip() {
        let j = Json::parse(
            r#"[{"prefix":[1,2],"choices":[[3,4],[5,6]],"answer":1}]"#,
        )
        .unwrap();
        let items = parse_task(&j).unwrap();
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].prefix, vec![1, 2]);
        assert_eq!(items[0].choices[1], vec![5, 6]);
        assert_eq!(items[0].answer, 1);
    }
}
