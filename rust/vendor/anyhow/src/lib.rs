//! Minimal in-tree `anyhow` shim — just the subset the MergeQuant runtime
//! uses (DESIGN.md §2 substitution table):
//!
//! * [`Error`]: a boxed, contextualized error message. Context added via
//!   [`Context`] is prepended `"context: cause"`, so both `{}` and the
//!   `{:#}` alternate form print the full chain like real anyhow.
//! * [`Result`]: `std::result::Result<T, Error>`.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` (any
//!   `Display` error) and on `Option`.
//! * [`bail!`]: early-return with a formatted error.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion used by `?`.

use std::fmt;

/// Boxed error with a flattened context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything printable (used by `map_err(Error::msg)`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer, anyhow-style (`"context: cause"`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Include one level of source, the common case for io errors
        // wrapped by parsers.
        match e.source() {
            Some(src) => Error { msg: format!("{e}: {src}") },
            None => Error::msg(&e),
        }
    }
}

/// `std::result::Result` specialised to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for `Result` and `Option` (anyhow's
/// `Context` trait, shimmed).
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Return early with a formatted [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

/// Construct an [`Error`] from a format string (anyhow's `anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = io_fail().with_context(|| "loading bundle");
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.starts_with("loading bundle: "), "{msg}");
        assert!(msg.contains("disk on fire"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn bail_formats() {
        fn f(x: u32) -> Result<()> {
            if x > 3 {
                bail!("x too big: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(9).unwrap_err().to_string(), "x too big: 9");
    }
}
