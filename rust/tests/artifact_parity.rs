//! Parity tests binding the three layers together (require `make
//! artifacts`; they skip when the tree is absent so `cargo test` stays
//! green on a fresh checkout):
//!
//! * engine-vs-JAX goldens: the Rust engine on a `.qmod` bundle must
//!   reproduce the JAX quantized forward's logits;
//! * greedy-decode golden: token-exact agreement on a fixed prompt;
//! * engine-vs-PJRT: the AOT HLO (L2/L1 via Pallas) and the native engine
//!   agree on the same tokens.

use mergequant::artifacts_dir;
use mergequant::engine::{Engine, KvCache, KvDtype, QModel, Workspace};
use mergequant::eval::corpus::{load_f32, load_json, load_tokens};

fn goldens_available() -> bool {
    artifacts_dir().join("goldens").join("goldens.json").exists()
}

fn load_engine(method: &str) -> Engine {
    let p = artifacts_dir()
        .join("models")
        .join("tiny-llama-s")
        .join(format!("{method}.qmod"));
    Engine::new(QModel::load(&p).expect("bundle"))
}

fn golden_tokens() -> (Vec<u32>, usize, usize) {
    let g = load_json(&artifacts_dir().join("goldens").join("goldens.json"))
        .unwrap();
    let shape = g.get("tokens_shape").unwrap().as_arr().unwrap();
    let (b, t) = (shape[0].as_usize().unwrap(), shape[1].as_usize().unwrap());
    let toks =
        load_tokens(&artifacts_dir().join("goldens").join("tokens.i32"))
            .unwrap();
    (toks, b, t)
}

fn engine_logits(engine: &Engine, toks: &[u32], b: usize, t: usize)
                 -> Vec<f32> {
    let cfg = engine.config().clone();
    let mut out = Vec::new();
    let mut ws = Workspace::new();
    for bi in 0..b {
        let mut cache = KvCache::new(cfg.n_layers, t, cfg.d_model);
        engine.prefill(&toks[bi * t..(bi + 1) * t], &mut cache, &mut ws)
            .expect("golden prefill");
        out.extend_from_slice(&ws.logits[..t * cfg.vocab]);
    }
    out
}

fn check_method(method: &str, rtol: f32) {
    let g = load_json(&artifacts_dir().join("goldens").join("goldens.json"))
        .unwrap();
    let entry = match g.get("logits").and_then(|l| l.get(method)) {
        Some(e) => e,
        None => return, // method not exported
    };
    let file = entry.get("file").unwrap().as_str().unwrap();
    let want =
        load_f32(&artifacts_dir().join("goldens").join(file)).unwrap();
    let (toks, b, t) = golden_tokens();
    let engine = load_engine(if method == "fp32" { "fp16" } else { method });
    let got = engine_logits(&engine, &toks, b, t);
    assert_eq!(got.len(), want.len(), "{method} logits size");
    let mut worst = 0f32;
    let scale = want.iter().fold(0f32, |a, &v| a.max(v.abs()));
    for (a, b) in got.iter().zip(&want) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst <= rtol * scale.max(1.0),
            "{method}: worst |diff| {worst} vs scale {scale}");
}

#[test]
fn engine_matches_jax_fp32_golden() {
    if !goldens_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    check_method("fp32", 2e-3);
}

#[test]
fn engine_matches_jax_quant_goldens() {
    if !goldens_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    for method in ["mergequant", "mergequant_nh", "rtn", "smoothquant",
                   "quarot"] {
        check_method(method, 5e-3);
    }
}

#[test]
fn greedy_decode_matches_golden() {
    if !goldens_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let g = load_json(&artifacts_dir().join("goldens").join("goldens.json"))
        .unwrap();
    let greedy = g.get("greedy").unwrap();
    let prompt: Vec<u32> = greedy.get("prompt").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap() as u32).collect();
    let want: Vec<u32> = greedy.get("completion").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap() as u32).collect();
    let engine = load_engine("fp16");
    let got = engine.generate(&prompt, want.len(),
                              prompt.len() + want.len() + 4).unwrap();
    assert_eq!(got, want, "greedy decode must be token-exact");
}

#[test]
fn int8_kv_greedy_decode_matches_f32_kv_on_bundle() {
    // Acceptance bar for the statically-quantized KV cache (DESIGN.md
    // §10): greedy-decode *token parity* between the f32-KV and int8-KV
    // paths on the trained mergequant bundle.
    if !goldens_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let g = load_json(&artifacts_dir().join("goldens").join("goldens.json"))
        .unwrap();
    let prompt: Vec<u32> = g.get("greedy").unwrap().get("prompt").unwrap()
        .as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap() as u32).collect();
    let mut engine = load_engine("mergequant");
    // Pre-format-2 artifact tree: probe-calibrate so the int8 path is
    // still exercised (no-op on format-2 bundles).
    engine.ensure_kv_scales().unwrap();
    let max_seq = prompt.len() + 36;
    let f32_toks = engine
        .generate_with(&prompt, 32, max_seq, KvDtype::F32)
        .unwrap();
    let i8_toks = engine
        .generate_with(&prompt, 32, max_seq, KvDtype::Int8)
        .unwrap();
    assert_eq!(f32_toks, i8_toks,
               "int8-KV greedy decode must be token-identical to f32-KV \
                on the trained bundle");
}

#[test]
fn engine_matches_pjrt_runtime() {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    if !goldens_available()
        || !artifacts_dir().join("hlo").join("hlo.json").exists()
    {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta =
        load_json(&artifacts_dir().join("hlo").join("hlo.json")).unwrap();
    let name = "tiny-llama-s.prefill.fp32";
    let info = meta.get(name).unwrap();
    let (b, t) = (info.get("batch").unwrap().as_usize().unwrap(),
                  info.get("seq").unwrap().as_usize().unwrap());
    let mut rt = mergequant::runtime::Runtime::cpu().unwrap();
    rt.load_hlo(name, &artifacts_dir().join("hlo")
        .join(format!("{name}.hlo.txt"))).unwrap();
    let tokens: Vec<i32> = (0..b * t).map(|i| 3 + (i as i32 * 13) % 500)
        .collect();
    let pjrt_logits =
        rt.execute_prefill_logits(name, &tokens, b, t).unwrap();
    let engine = load_engine("fp16");
    let toks_u32: Vec<u32> = tokens.iter().map(|&v| v as u32).collect();
    let got = engine_logits(&engine, &toks_u32, b, t);
    assert_eq!(got.len(), pjrt_logits.len());
    let scale = pjrt_logits.iter().fold(0f32, |a, &v| a.max(v.abs()));
    let mut worst = 0f32;
    for (a, b) in got.iter().zip(&pjrt_logits) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 2e-3 * scale.max(1.0),
            "engine vs PJRT worst diff {worst} (scale {scale})");
}

#[test]
fn quantized_decode_hlo_loads() {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    let path = artifacts_dir().join("hlo")
        .join("tiny-llama-s.decode.mergequant.hlo.txt");
    if !path.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = mergequant::runtime::Runtime::cpu().unwrap();
    rt.load_hlo("decode.mq", &path).unwrap();
    assert!(rt.has("decode.mq"));
}
