//! Shared test support: the seeded shared-prefix fleet trace generator
//! (DESIGN.md §14) used by `ragged_batch`, `coordinator_props` and
//! `prefix_sharing`, plus the CI-matrix env knobs. A trace is plain
//! data with `Debug` — the proptest harness prints the failing trace
//! verbatim, so every failure is its own reproducer.

// Each test binary compiles this module independently and uses only a
// subset of it.
#![allow(dead_code)]

use mergequant::coordinator::{
    Event, GenerationParams, Request, Response, Scheduler,
};
use mergequant::engine::KvDtype;
use mergequant::util::proptest::Shrink;
use mergequant::util::rng::Rng;

/// Thread counts for determinism sweeps; `MQ_TEST_THREADS` feeds an
/// extra count in from the CI matrix (DESIGN.md §7).
pub fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1, 4];
    if let Some(extra) = std::env::var("MQ_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        if extra > 0 && !counts.contains(&extra) {
            counts.push(extra);
        }
    }
    counts
}

/// KV dtypes for determinism sweeps; `MQ_TEST_KV` restricts the axis
/// (DESIGN.md §10).
pub fn kv_dtypes() -> Vec<KvDtype> {
    match std::env::var("MQ_TEST_KV").as_deref() {
        Ok("int8") => vec![KvDtype::Int8],
        Ok("f32") => vec![KvDtype::F32],
        _ => vec![KvDtype::F32, KvDtype::Int8],
    }
}

/// Scheduler-level paging granularities for the shared-prefix suite
/// (all non-zero: 0 would be the slab layout, which cannot share).
/// `MQ_TEST_KV_BLOCK` feeds an extra size in from the CI matrix.
pub fn sched_kv_blocks() -> Vec<usize> {
    let mut sizes = vec![24, 32, 48];
    if let Some(extra) = std::env::var("MQ_TEST_KV_BLOCK")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        if extra > 0 && !sizes.contains(&extra) {
            sizes.push(extra);
        }
    }
    sizes
}

/// One lane of a shared-prefix fleet: a request whose prompt reuses the
/// first `prefix_take` tokens of the fleet's shared system prompt and
/// then diverges into a private suffix.
#[derive(Clone, Debug)]
pub struct Lane {
    pub id: u64,
    pub prompt: Vec<u32>,
    /// Tokens of [`FleetTrace::prefix`] this prompt starts with —
    /// deliberately not always a block multiple, so divergence lands
    /// mid-block as often as on a boundary.
    pub prefix_take: usize,
    pub max_new: usize,
    /// Scheduler tick at which the lane is submitted (staggered
    /// admission: later lanes find earlier lanes' prefixes cached).
    pub submit_at: usize,
    /// Tick at which `cancel()` fires — strictly after `submit_at`, so
    /// the lane can be torn out mid-prefill or mid-share (`None` ⇒
    /// runs to completion).
    pub cancel_at: Option<usize>,
    /// Scheduling class (DESIGN.md §15): higher preempts strictly lower
    /// under block pressure. Neutral fleets use 0 everywhere, which
    /// degrades to plain FIFO admission.
    pub priority: u8,
    /// Observational latency deadline in ms (`None` ⇒ no deadline).
    pub deadline_ms: Option<u64>,
}

/// A seeded shared-prefix fleet over one system prompt: staggered
/// admission, mid-block divergence, and mid-share cancellation events.
#[derive(Clone, Debug)]
pub struct FleetTrace {
    /// The fleet's shared system prompt.
    pub prefix: Vec<u32>,
    pub lanes: Vec<Lane>,
}

impl Shrink for FleetTrace {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.lanes.len() > 1 {
            // drop halves and drop one lane
            out.push(FleetTrace {
                prefix: self.prefix.clone(),
                lanes: self.lanes[..self.lanes.len() / 2].to_vec(),
            });
            out.push(FleetTrace {
                prefix: self.prefix.clone(),
                lanes: self.lanes[self.lanes.len() / 2..].to_vec(),
            });
            let mut fewer = self.lanes.clone();
            fewer.pop();
            out.push(FleetTrace { prefix: self.prefix.clone(),
                                  lanes: fewer });
        }
        // drop the cancellation events, keeping the lane mix
        if self.lanes.iter().any(|l| l.cancel_at.is_some()) {
            let lanes = self
                .lanes
                .iter()
                .cloned()
                .map(|mut l| {
                    l.cancel_at = None;
                    l
                })
                .collect();
            out.push(FleetTrace { prefix: self.prefix.clone(), lanes });
        }
        out
    }
}

/// Draw a fleet: a 8–27-token shared prefix and 2–5 lanes, each taking
/// a random (block-unaligned in general) cut of it plus a private
/// suffix; ~1 in 4 lanes carries a cancellation event.
pub fn gen_fleet(r: &mut Rng) -> FleetTrace {
    let plen = r.usize(8, 28);
    let prefix: Vec<u32> =
        (0..plen).map(|_| 3 + r.usize(0, 90) as u32).collect();
    let lanes = (0..r.usize(2, 6))
        .map(|i| {
            let take = r.usize(1, plen + 1);
            let mut prompt: Vec<u32> = prefix[..take].to_vec();
            for _ in 0..r.usize(0, 7) {
                prompt.push(3 + r.usize(0, 90) as u32);
            }
            let submit_at = r.usize(0, 6);
            let cancel_at = (r.usize(0, 4) == 0)
                .then(|| submit_at + 1 + r.usize(0, 8));
            Lane {
                id: i as u64,
                prompt,
                prefix_take: take,
                max_new: r.usize(1, 8),
                submit_at,
                cancel_at,
                priority: 0,
                deadline_ms: None,
            }
        })
        .collect();
    FleetTrace { prefix, lanes }
}

/// Draw an adversarial bursty mixed-priority fleet (DESIGN.md §15):
/// 6–10 lanes arriving in two bursts (tick 0 and ~tick 3) with
/// priorities drawn from {0, 1, 2, 3}, some with impossible
/// (`Some(0)`) or generous deadlines, and ~1 in 5 carrying a
/// cancellation — the workload shape that exercises weighted-fair
/// admission, preemption, and SLO accounting together.
pub fn gen_burst_fleet(r: &mut Rng) -> FleetTrace {
    let plen = r.usize(8, 20);
    let prefix: Vec<u32> =
        (0..plen).map(|_| 3 + r.usize(0, 90) as u32).collect();
    let lanes = (0..r.usize(6, 11))
        .map(|i| {
            let take = r.usize(1, plen + 1);
            let mut prompt: Vec<u32> = prefix[..take].to_vec();
            for _ in 0..r.usize(0, 9) {
                prompt.push(3 + r.usize(0, 90) as u32);
            }
            // Two arrival bursts; the second lands while the first is
            // mid-decode, so admission competes with live lanes.
            let submit_at =
                if r.usize(0, 2) == 0 { 0 } else { 3 + r.usize(0, 2) };
            let cancel_at = (r.usize(0, 5) == 0)
                .then(|| submit_at + 1 + r.usize(0, 8));
            let deadline_ms = match r.usize(0, 4) {
                0 => Some(0),          // impossible: always a violation
                1 => Some(60_000),     // generous: never a violation
                _ => None,
            };
            Lane {
                id: i as u64,
                prompt,
                prefix_take: take,
                max_new: r.usize(1, 10),
                submit_at,
                cancel_at,
                priority: r.usize(0, 4) as u8,
                deadline_ms,
            }
        })
        .collect();
    FleetTrace { prefix, lanes }
}

/// Drive `sched` through the trace: submissions and cancellations fire
/// at their scheduled ticks, then the scheduler runs dry. Returns the
/// terminal responses sorted by lane id.
pub fn drive_fleet(sched: &mut Scheduler, trace: &FleetTrace)
                   -> Vec<Response> {
    let horizon = trace
        .lanes
        .iter()
        .map(|l| l.cancel_at.unwrap_or(l.submit_at))
        .max()
        .unwrap_or(0);
    let mut out = Vec::new();
    let mut tick = 0usize;
    while tick <= horizon || sched.has_work() {
        for l in &trace.lanes {
            if l.submit_at == tick {
                let params = GenerationParams {
                    priority: l.priority,
                    deadline_ms: l.deadline_ms,
                    ..GenerationParams::greedy(l.max_new)
                };
                sched
                    .submit(Request::with_params(
                        l.id, l.prompt.clone(), params))
                    .expect("fleet exceeds queue_cap");
            }
            if l.cancel_at == Some(tick) {
                sched.cancel(l.id);
            }
        }
        sched.step();
        for ev in sched.take_events() {
            if let Event::Done { response } | Event::Error { response } = ev
            {
                out.push(response);
            }
        }
        tick += 1;
        assert!(tick < 100_000, "fleet livelock");
    }
    out.sort_by_key(|r| r.id);
    out
}
