//! Shared-prefix paged KV determinism suite (DESIGN.md §14) — the CI
//! matrix target for copy-on-write block sharing + the radix prefix
//! cache.
//!
//! The pinned claim: turning `prefix_cache` on changes *when* tokens
//! arrive (prefill skipped for matched prefixes), never *what* tokens
//! are generated. Every lane of a shared-prefix fleet — staggered
//! admission, divergence mid-block, cancellation mid-share — streams
//! bitwise identically to a cold-start unshared replay of the same
//! prompt, across {threads}×{kv f32,int8}×{kv_block}×{chunking}.
//! Cancellation truncates but never alters: a cancelled lane's stream
//! is a prefix of its cold replay.
//!
//! CI matrix knobs: `MQ_TEST_THREADS`, `MQ_TEST_KV`, `MQ_TEST_KV_BLOCK`
//! (DESIGN.md §7/§10/§13).

mod common;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    FinishReason, Request, Scheduler, SchedulerConfig,
};
use mergequant::engine::{Engine, KvDtype};
use mergequant::util::proptest::check;

use common::{drive_fleet, gen_fleet, FleetTrace};

fn fleet_scheduler(prefix_on: bool, threads: usize, kv: KvDtype,
                   kv_block: usize, chunk: usize) -> Scheduler {
    let engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 1, 96), threads);
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 8,
            kv_block,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: chunk,
            threads,
            kv_dtype: kv,
            prefix_cache: prefix_on,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

/// Cold-start unshared replay: the lane's prompt alone through a fresh
/// prefix-off scheduler — the golden stream sharing must reproduce.
fn solo_stream(threads: usize, kv: KvDtype, kv_block: usize,
               prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut sched = fleet_scheduler(false, threads, kv, kv_block, 0);
    sched.submit(Request::new(0, prompt.to_vec(), max_new)).unwrap();
    let rs = sched.run_to_completion();
    assert!(rs[0].error.is_none(), "golden failed: {:?}", rs[0].error);
    rs[0].tokens.clone()
}

fn check_fleet_against_goldens(trace: &FleetTrace, threads: usize,
                               kv: KvDtype, kv_block: usize,
                               goldens: &[Vec<u32>], chunk: usize)
                               -> Result<(), String> {
    let mut sched = fleet_scheduler(true, threads, kv, kv_block, chunk);
    let rs = drive_fleet(&mut sched, trace);
    if rs.len() != trace.lanes.len() {
        return Err(format!("{} responses for {} lanes (kv {kv:?}, \
                            threads {threads}, kv_block {kv_block}, \
                            chunk {chunk})",
                           rs.len(), trace.lanes.len()));
    }
    for (r, golden) in rs.iter().zip(goldens) {
        if let Some(e) = &r.error {
            return Err(format!("lane {} failed: {e}", r.id));
        }
        if r.finish == FinishReason::Cancelled {
            // Cancellation truncates the stream, never rewrites it.
            if r.tokens.len() > golden.len()
                || r.tokens[..] != golden[..r.tokens.len()]
            {
                return Err(format!(
                    "cancelled lane {} diverged from its cold replay: \
                     {:?} not a prefix of {:?} (kv {kv:?}, threads \
                     {threads}, kv_block {kv_block}, chunk {chunk})",
                    r.id, r.tokens, golden));
            }
        } else if &r.tokens != golden {
            return Err(format!(
                "lane {} diverged from its cold replay: {:?} != {:?} \
                 (kv {kv:?}, threads {threads}, kv_block {kv_block}, \
                 chunk {chunk})",
                r.id, r.tokens, golden));
        }
    }
    // The index deliberately retains blocks past completion; every
    // block is either free or pinned by the trie at drain.
    if sched.kv_available() + sched.prefix_cached_blocks()
        != sched.kv_capacity()
    {
        return Err(format!(
            "drain leak: {} free + {} cached != {} capacity",
            sched.kv_available(), sched.prefix_cached_blocks(),
            sched.kv_capacity()));
    }
    Ok(())
}

#[test]
fn shared_prefix_fleets_bitwise_match_cold_replay() {
    for kv in common::kv_dtypes() {
        for &threads in &common::thread_counts() {
            for kv_block in common::sched_kv_blocks() {
                check(4099 + threads as u64 + kv_block as u64, 3,
                      gen_fleet, |trace| {
                    let goldens: Vec<Vec<u32>> = trace
                        .lanes
                        .iter()
                        .map(|l| solo_stream(threads, kv, kv_block,
                                             &l.prompt, l.max_new))
                        .collect();
                    for chunk in [0usize, 5] {
                        check_fleet_against_goldens(
                            trace, threads, kv, kv_block, &goldens,
                            chunk)?;
                    }
                    Ok(())
                });
            }
        }
    }
}

#[test]
fn full_hit_admission_prefills_exactly_one_row() {
    // A prompt whose frozen blocks are fully cached skips its entire
    // prefill except the final token (the lookup cap): the admission's
    // prefill span is ONE row, so TTFT collapses to one decode-sized
    // engine call — asserted through the row metrics, not wall time.
    let mut sched = fleet_scheduler(true, 1, KvDtype::F32, 8, 0);
    let prompt: Vec<u32> = (0..24).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, prompt.clone(), 4)).unwrap();
    let first = sched.run_to_completion();
    let rows_cold = sched.metrics.prefill_rows;
    let calls_cold = sched.metrics.forward_calls;
    assert_eq!(rows_cold, 24, "cold admission prefills every row");

    sched.submit(Request::new(2, prompt, 4)).unwrap();
    let second = sched.run_to_completion();
    assert_eq!(second[0].tokens, first[0].tokens,
               "prefix hit changed the stream");
    assert_eq!(sched.metrics.prefill_rows - rows_cold, 1,
               "full hit must prefill only the final prompt token");
    assert_eq!(sched.metrics.forward_calls - calls_cold, 4,
               "full-hit TTFT is one decode-sized call: 4 calls for 4 \
                tokens");
    assert_eq!(sched.metrics.prefix_hits, 1);
    assert_eq!(sched.metrics.prefix_lookups, 2);
    assert_eq!(sched.metrics.prefix_matched_tokens, 23,
               "23 of 24 tokens attached from cache (3 blocks: 2 full \
                + the boundary)");
}

#[test]
fn mid_block_divergence_borrows_boundary_and_stays_bitwise() {
    // Lane B shares A's prompt up to token 23 — inside A's second
    // 16-token block. The trie hands back the full block as B's
    // partially-filled boundary; the scheduler must CoW it before B's
    // first write, and B's stream must equal its cold replay.
    let prompt_a: Vec<u32> = (0..40).map(|t| 3 + (t * 7) % 90).collect();
    let mut prompt_b = prompt_a[..23].to_vec();
    prompt_b.extend((0..9).map(|t| 5 + (t * 11) % 90));
    let golden_b = solo_stream(1, KvDtype::F32, 16, &prompt_b, 6);

    let mut sched = fleet_scheduler(true, 1, KvDtype::F32, 16, 0);
    sched.submit(Request::new(1, prompt_a, 6)).unwrap();
    let _ = sched.run_to_completion();
    sched.submit(Request::new(2, prompt_b, 6)).unwrap();
    let rs = sched.run_to_completion();
    assert_eq!(rs[0].tokens, golden_b,
               "mid-block divergence corrupted the stream");
    assert_eq!(sched.metrics.prefix_hits, 1);
    assert_eq!(sched.metrics.prefix_matched_tokens, 23,
               "16 (full block) + 7 rows of the borrowed boundary");
    assert!(sched.metrics.prefix_bytes_saved > 0,
            "sharing must be visible while both tables overlap");
    assert_eq!(sched.kv_available() + sched.prefix_cached_blocks(),
               sched.kv_capacity());
}

#[test]
fn cancellation_mid_share_frees_private_blocks_keeps_prefix() {
    // Three lanes share a 32-token prefix; the middle one is cancelled
    // mid-decode. Its private blocks must come back (the shared ones
    // stay pinned by the survivors + trie), survivors must stream
    // exactly their cold replays, and the pool must balance at drain.
    let prefix: Vec<u32> = (0..32).map(|t| 3 + (t * 5) % 90).collect();
    let prompts: Vec<Vec<u32>> = (0..3u32)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend((0..4).map(|t| 7 + (t * 13 + i) % 90));
            p
        })
        .collect();
    let goldens: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| solo_stream(1, KvDtype::F32, 16, p, 8))
        .collect();

    let mut sched = fleet_scheduler(true, 1, KvDtype::F32, 16, 0);
    // Stagger: lane 0 prefills cold and populates the index, then
    // lanes 1 and 2 admit against it and share its prefix blocks.
    sched.submit(Request::new(0, prompts[0].clone(), 8)).unwrap();
    sched.step();
    sched.step();
    sched.submit(Request::new(1, prompts[1].clone(), 8)).unwrap();
    sched.submit(Request::new(2, prompts[2].clone(), 8)).unwrap();
    for _ in 0..3 {
        sched.step();
    }
    sched.cancel(1); // a sharing lane, torn out mid-decode
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), 3);
    for r in &rs {
        assert!(r.error.is_none(), "lane {} error {:?}", r.id, r.error);
    }
    assert_eq!(rs[1].finish, FinishReason::Cancelled);
    assert!(rs[1].tokens[..] == goldens[1][..rs[1].tokens.len()],
            "cancelled lane rewrote its stream");
    for i in [0usize, 2] {
        assert_eq!(rs[i].tokens, goldens[i],
                   "survivor lane {i} diverged after the cancellation");
    }
    assert!(sched.metrics.prefix_shared_blocks > 0,
            "the fleet must actually have shared blocks");
    assert_eq!(sched.kv_available() + sched.prefix_cached_blocks(),
               sched.kv_capacity(),
               "cancellation mid-share leaked blocks");
    // The retained prefix still serves: a fourth lane full-hits.
    let lookups = sched.metrics.prefix_lookups;
    sched.submit(Request::new(9, prompts[0].clone(), 8)).unwrap();
    let again = sched.run_to_completion();
    assert_eq!(again[0].tokens, goldens[0]);
    assert_eq!(sched.metrics.prefix_lookups, lookups + 1);
    assert_eq!(sched.metrics.prefix_hits, 3,
               "lanes 1 and 2 hit lane 0's prefix, then the \
                re-submission hits again after the cancellation");
}

#[test]
fn capacity_bound_evicts_lru_and_report_carries_hit_rate() {
    // A 4-block index cap forces LRU leaf eviction while serving; the
    // metrics line must expose the hit-rate for the serve_e2e CI step.
    let engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 1, 96), 1);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 8,
            kv_block: 8,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: true,
            prefix_cache_blocks: 4,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    for i in 0..6u64 {
        // six distinct 16-token prompts: 2 full blocks each, 12 > cap 4
        let prompt: Vec<u32> =
            (0..16).map(|t| 3 + (t * 3 + i as u32 * 17) % 90).collect();
        sched.submit(Request::new(i, prompt, 2)).unwrap();
        let rs = sched.run_to_completion();
        assert!(rs[0].error.is_none());
    }
    assert!(sched.prefix_cached_blocks() <= 4,
            "index exceeded its configured capacity");
    assert!(sched.metrics.prefix_evicted_blocks >= 8,
            "LRU eviction must have cycled the index");
    assert_eq!(sched.kv_available() + sched.prefix_cached_blocks(),
               sched.kv_capacity());
    let report = sched.metrics.report();
    assert!(report.contains("prefix_hit_rate="), "{report}");
    assert!(report.contains("prefix_cached_blocks="), "{report}");
}
