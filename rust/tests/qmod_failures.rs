//! Failure-injection tests for the `.qmod` loader: corrupted inputs must
//! produce errors, never panics or silent garbage.

use std::io::Write;

use mergequant::engine::QModel;

fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("mq_qmod_failures");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(bytes).unwrap();
    p
}

#[test]
fn missing_file_is_error() {
    let err = QModel::load(std::path::Path::new("/nonexistent/x.qmod"));
    assert!(err.is_err());
}

#[test]
fn bad_magic_is_error() {
    let p = tmp("bad_magic.qmod", b"NOTQMOD-----------------");
    let e = QModel::load(&p);
    assert!(e.is_err());
    assert!(format!("{:#}", e.unwrap_err()).contains("magic"));
}

#[test]
fn truncated_meta_is_error() {
    // valid magic, meta_len says 1000 but file ends
    let mut bytes = b"QMOD1\n".to_vec();
    bytes.extend(1000u32.to_le_bytes());
    bytes.extend(b"{\"partial\":");
    let p = tmp("trunc.qmod", &bytes);
    let res = std::panic::catch_unwind(|| QModel::load(&p));
    // must be Err or a caught panic (slice OOB) — but never silent success
    match res {
        Ok(inner) => assert!(inner.is_err()),
        Err(_) => panic!("loader panicked on truncated file"),
    }
}

#[test]
fn garbage_meta_is_error() {
    let meta = b"this is not json at all";
    let mut bytes = b"QMOD1\n".to_vec();
    bytes.extend((meta.len() as u32).to_le_bytes());
    bytes.extend(meta);
    let p = tmp("garbage_meta.qmod", &bytes);
    assert!(QModel::load(&p).is_err());
}

#[test]
fn valid_json_missing_fields_is_error() {
    let meta = br#"{"format":1,"method":"x"}"#;
    let mut bytes = b"QMOD1\n".to_vec();
    bytes.extend((meta.len() as u32).to_le_bytes());
    bytes.extend(meta);
    let p = tmp("missing_fields.qmod", &bytes);
    let e = QModel::load(&p);
    assert!(e.is_err());
    assert!(format!("{:#}", e.unwrap_err()).contains("config"));
}
