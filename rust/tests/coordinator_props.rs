//! Property tests on coordinator invariants (homegrown proptest harness):
//! every request answered exactly once, batch caps respected, KV blocks
//! never leaked (block-granular paged allocation, DESIGN.md §13), FIFO
//! admission, backpressure correctness.

mod common;

use std::collections::HashSet;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    BlockPool, FinishReason, GenerationParams, Request, Scheduler,
    SchedulerConfig,
};
use mergequant::engine::{Engine, KvDtype};
use mergequant::util::proptest::check;
use mergequant::util::rng::Rng;

fn make_scheduler(max_batch: usize, slabs: usize) -> Scheduler {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch,
            kv_slabs: slabs,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

/// Workload: list of (prompt_len, max_new).
fn gen_workload(r: &mut Rng) -> Vec<(usize, usize)> {
    let n = r.usize(1, 12);
    (0..n)
        .map(|_| (r.usize(1, 20), r.usize(1, 10)))
        .collect()
}

#[test]
fn every_request_answered_exactly_once() {
    check(101, 12, gen_workload, |workload| {
        let mut sched = make_scheduler(4, 4);
        for (i, &(plen, mnew)) in workload.iter().enumerate() {
            let prompt: Vec<u32> = (0..plen as u32).map(|t| 3 + t % 90).collect();
            sched
                .submit(Request::new(i as u64, prompt, mnew))
                .map_err(|_| "queue full unexpectedly".to_string())?;
        }
        let responses = sched.run_to_completion();
        if responses.len() != workload.len() {
            return Err(format!("{} responses for {} requests",
                               responses.len(), workload.len()));
        }
        let ids: HashSet<u64> = responses.iter().map(|r| r.id).collect();
        if ids.len() != workload.len() {
            return Err("duplicate response ids".into());
        }
        for r in &responses {
            let (plen, mnew) = workload[r.id as usize];
            if r.prompt_len != plen {
                return Err(format!("prompt_len {} != {}", r.prompt_len, plen));
            }
            if r.tokens.len() > mnew {
                return Err(format!("generated {} > max_new {}",
                                   r.tokens.len(), mnew));
            }
        }
        Ok(())
    });
}

#[test]
fn active_set_never_exceeds_max_batch() {
    check(202, 8, gen_workload, |workload| {
        let max_batch = 3;
        let mut sched = make_scheduler(max_batch, 3);
        for (i, &(plen, mnew)) in workload.iter().enumerate() {
            let prompt: Vec<u32> = (0..plen as u32).map(|t| 3 + t % 90).collect();
            let _ = sched.submit(Request::new(i as u64, prompt, mnew));
        }
        while sched.has_work() {
            sched.step();
            if sched.active_len() > max_batch {
                return Err(format!("active {} > max_batch {max_batch}",
                                   sched.active_len()));
            }
        }
        Ok(())
    });
}

#[test]
fn fifo_first_token_order() {
    // With one admission per iteration, earlier submissions must get their
    // first token (TTFT) no later than later submissions.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 2,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 1,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    for i in 0..6u64 {
        let prompt: Vec<u32> = (0..8).map(|t| 3 + t % 90).collect();
        sched.submit(Request::new(i, prompt, 4)).unwrap();
    }
    let mut responses = sched.run_to_completion();
    responses.sort_by_key(|r| r.id);
    for w in responses.windows(2) {
        assert!(w[0].ttft <= w[1].ttft,
                "FIFO violated: id {} ttft {:?} > id {} ttft {:?}",
                w[0].id, w[0].ttft, w[1].id, w[1].ttft);
    }
}

#[test]
fn oversized_prompts_rejected_not_hung() {
    let mut sched = make_scheduler(2, 2);
    // prompt longer than max_seq (48)
    let prompt: Vec<u32> = (0..64).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, prompt, 4)).unwrap();
    sched.submit(Request::new(2, vec![3, 4, 5], 4)).unwrap();
    let responses = sched.run_to_completion();
    assert_eq!(responses.len(), 2);
    let r1 = responses.iter().find(|r| r.id == 1).unwrap();
    assert!(r1.tokens.is_empty(), "oversized prompt must yield no tokens");
    let r2 = responses.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(r2.tokens.len(), 4);
}

#[test]
fn kv_overflow_is_per_request_failure_not_worker_death() {
    // Regression for the old hard `assert!` in `engine/model.rs`: a KV
    // overflow must surface as a typed per-request failure (error field
    // set, empty tokens) while the scheduler keeps serving everything
    // before AND after the bad request.
    let mut sched = make_scheduler(2, 2);
    let oversized: Vec<u32> = (0..64).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, vec![3, 4], 3)).unwrap();
    sched.submit(Request::new(2, oversized, 4)).unwrap();
    sched.submit(Request::new(3, vec![5, 6, 7], 3)).unwrap();
    let responses = sched.run_to_completion();
    assert_eq!(responses.len(), 3, "every request answered exactly once");
    let bad = responses.iter().find(|r| r.id == 2).unwrap();
    assert!(bad.tokens.is_empty());
    let msg = bad.error.as_deref().expect("typed error surfaced");
    assert!(msg.contains("KV cache overflow"), "got error {msg:?}");
    for id in [1u64, 3] {
        let r = responses.iter().find(|r| r.id == id).unwrap();
        assert_eq!(r.tokens.len(), 3, "request {id} served normally");
        assert!(r.error.is_none());
    }
    assert_eq!(sched.metrics.failed, 1);
    // The blocks freed by the failure are reusable: serve another request.
    sched.submit(Request::new(4, vec![8, 9], 2)).unwrap();
    let more = sched.run_to_completion();
    assert_eq!(more.len(), 1);
    assert_eq!(more[0].tokens.len(), 2);
}

#[test]
fn kv_overflow_mid_chunked_prefill_fails_cleanly() {
    // An oversized prompt routed through *chunked* prefill is oversized
    // for max_seq — it must fail with the typed overflow error, its
    // blocks must come back, and later requests must still be served.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 2,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 32,
            max_prefills_per_iter: 1,
            queue_cap: 64,
            prefill_chunk: 8,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    let oversized: Vec<u32> = (0..40).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, oversized, 4)).unwrap();
    sched.submit(Request::new(2, vec![3, 4, 5], 4)).unwrap();
    let responses = sched.run_to_completion();
    assert_eq!(responses.len(), 2);
    let bad = responses.iter().find(|r| r.id == 1).unwrap();
    assert!(bad.tokens.is_empty());
    assert!(bad.error.as_deref().unwrap().contains("KV cache overflow"));
    let ok = responses.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(ok.tokens.len(), 4);
    assert!(ok.error.is_none());
}

#[test]
fn int8_kv_scheduler_serves_full_workload() {
    // The whole coordinator path on statically-quantized int8 KV blocks:
    // same invariants (answered exactly once, token budgets respected).
    check(404, 8, gen_workload, |workload| {
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        let mut sched = Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 4,
                kv_slabs: 4,
                kv_block: 16,
                kv_blocks: 0,
                max_seq: 48,
                max_prefills_per_iter: 2,
                queue_cap: 64,
                prefill_chunk: 0,
                threads: 1,
                kv_dtype: KvDtype::Int8,
                prefix_cache: false,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        );
        for (i, &(plen, mnew)) in workload.iter().enumerate() {
            let prompt: Vec<u32> =
                (0..plen as u32).map(|t| 3 + t % 90).collect();
            sched
                .submit(Request::new(i as u64, prompt, mnew))
                .map_err(|_| "queue full unexpectedly".to_string())?;
        }
        let responses = sched.run_to_completion();
        if responses.len() != workload.len() {
            return Err(format!("{} responses for {} requests",
                               responses.len(), workload.len()));
        }
        for r in &responses {
            if let Some(e) = &r.error {
                return Err(format!("request {} failed: {e}", r.id));
            }
            let (_, mnew) = workload[r.id as usize];
            if r.tokens.is_empty() || r.tokens.len() > mnew {
                return Err(format!("bad token count {}", r.tokens.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn backpressure_queue_cap() {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 1,
            kv_slabs: 1,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 32,
            max_prefills_per_iter: 1,
            queue_cap: 2,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    assert!(sched.submit(Request::new(1, vec![3], 2)).is_ok());
    assert!(sched.submit(Request::new(2, vec![3], 2)).is_ok());
    // queue full now
    assert!(sched.submit(Request::new(3, vec![3], 2)).is_err());
    let responses = sched.run_to_completion();
    assert_eq!(responses.len(), 2);
}

#[test]
fn stop_token_terminates_generation() {
    let mut sched = make_scheduler(2, 2);
    // First find what the model generates unconstrained.
    sched.submit(Request::new(1, vec![3, 4, 5], 8)).unwrap();
    let unconstrained = sched.run_to_completion()[0].tokens.clone();
    if unconstrained.len() > 2 {
        let stop = unconstrained[1];
        let mut sched2 = make_scheduler(2, 2);
        let params = GenerationParams {
            stop_tokens: vec![stop],
            ..GenerationParams::greedy(8)
        };
        sched2
            .submit(Request::with_params(9, vec![3, 4, 5], params))
            .unwrap();
        let r = sched2.run_to_completion();
        assert!(r[0].tokens.len() <= 2,
                "generation must stop at the stop token");
        assert_eq!(r[0].finish, FinishReason::Stop);
    }
}

#[test]
fn multiple_stop_tokens_any_terminates() {
    let mut sched = make_scheduler(2, 2);
    sched.submit(Request::new(1, vec![3, 4, 5], 8)).unwrap();
    let unconstrained = sched.run_to_completion()[0].tokens.clone();
    if unconstrained.len() > 3 {
        // Either of two later tokens must cut the stream at the earlier.
        let params = GenerationParams {
            stop_tokens: vec![unconstrained[2], unconstrained[1]],
            ..GenerationParams::greedy(8)
        };
        let mut sched2 = make_scheduler(2, 2);
        sched2
            .submit(Request::with_params(9, vec![3, 4, 5], params))
            .unwrap();
        let r = sched2.run_to_completion();
        assert!(r[0].tokens.len() <= 2,
                "earliest stop token must win ({:?})", r[0].tokens);
    }
}

#[test]
fn cancellation_answers_once_and_returns_blocks() {
    // Cancel a mix of pending and active requests mid-run: every request
    // still gets exactly one terminal response, cancelled ones finish
    // with `Cancelled`, and every KV block comes back to the pool.
    let mut sched = make_scheduler(2, 2);
    for i in 0..6u64 {
        let prompt: Vec<u32> = (0..8).map(|t| 3 + t % 90).collect();
        sched.submit(Request::new(i, prompt, 30)).unwrap();
    }
    // Let the first two become active (max_batch 2), the rest pend.
    sched.step();
    assert!(sched.active_len() > 0);
    sched.cancel(0); // active
    sched.cancel(3); // pending
    sched.cancel(99); // unknown — must be ignored
    let mut responses = sched.run_to_completion();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 6, "every request answered exactly once");
    for r in &responses {
        match r.id {
            0 | 3 => {
                assert_eq!(r.finish, FinishReason::Cancelled,
                           "id {} finish {:?}", r.id, r.finish);
                assert!(r.error.is_none());
            }
            _ => {
                assert_eq!(r.finish, FinishReason::Length);
                assert_eq!(r.tokens.len(), 30);
            }
        }
    }
    assert_eq!(sched.metrics.cancelled, 2);
    assert_eq!(sched.kv_available(), sched.kv_capacity(),
               "cancellation leaked KV blocks");
    // The freed capacity is immediately reusable.
    sched.submit(Request::new(50, vec![5, 6], 3)).unwrap();
    let more = sched.run_to_completion();
    assert_eq!(more.len(), 1);
    assert_eq!(more[0].tokens.len(), 3);
}

#[test]
fn prompt_filling_cache_finishes_cache_full_not_error() {
    // A prompt of exactly max_seq tokens fills its logical capacity
    // during prefill: the first token is still sampled, then the
    // sequence must end gracefully with `CacheFull` — not trip a
    // KvOverflow error on the next decode iteration.
    let mut sched = make_scheduler(2, 2); // max_seq 48
    let prompt: Vec<u32> = (0..48).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, prompt, 4)).unwrap();
    let r = sched.run_to_completion();
    assert_eq!(r.len(), 1);
    assert!(r[0].error.is_none(), "unexpected error: {:?}", r[0].error);
    assert_eq!(r[0].finish, FinishReason::CacheFull);
    assert_eq!(r[0].tokens.len(), 1);
    assert_eq!(sched.metrics.failed, 0);
}

#[test]
fn cancel_mid_chunked_prefill_frees_blocks() {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 1,
            kv_slabs: 1,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 64,
            max_prefills_per_iter: 1,
            queue_cap: 64,
            prefill_chunk: 8,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    let long: Vec<u32> = (0..40).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, long, 4)).unwrap();
    sched.step(); // first chunk in flight — request holds reserved blocks
    sched.cancel(1);
    let responses = sched.run_to_completion();
    assert_eq!(responses.len(), 1);
    assert_eq!(responses[0].finish, FinishReason::Cancelled);
    assert!(responses[0].tokens.is_empty());
    assert_eq!(sched.kv_available(), sched.kv_capacity(),
               "prefilling blocks not returned");
    // Pool is usable again.
    sched.submit(Request::new(2, vec![3, 4, 5], 2)).unwrap();
    assert_eq!(sched.run_to_completion()[0].tokens.len(), 2);
}

#[test]
fn empty_prompt_is_per_request_failure_not_panic() {
    // The server layer rejects empty prompts synchronously, but direct
    // `Scheduler::submit` users must get a per-request failure too (the
    // seed panicked on `prompt.len() - 1`); neighbours are unaffected.
    let mut sched = make_scheduler(2, 2);
    sched.submit(Request::new(1, Vec::new(), 4)).unwrap();
    sched.submit(Request::new(2, vec![3, 4, 5], 4)).unwrap();
    let responses = sched.run_to_completion();
    assert_eq!(responses.len(), 2);
    let bad = responses.iter().find(|r| r.id == 1).unwrap();
    assert!(bad.tokens.is_empty());
    assert!(bad.error.as_deref().unwrap().contains("empty prompt"));
    let ok = responses.iter().find(|r| r.id == 2).unwrap();
    assert_eq!(ok.tokens.len(), 4);
    assert!(ok.error.is_none());
    assert_eq!(sched.metrics.failed, 1);
    assert_eq!(sched.kv_available(), sched.kv_capacity());
}

#[test]
fn one_engine_call_per_iteration_with_admission_and_decode() {
    // The tentpole contract (DESIGN.md §12): an iteration with ≥1
    // admission and ≥1 active decode lane issues exactly ONE
    // forward_batch engine call — the admission's prefill span and every
    // decode lane ride the same ragged batch.
    let mut sched = make_scheduler(4, 4);
    sched.submit(Request::new(1, vec![3, 4, 5, 6], 20)).unwrap();
    sched.step();
    assert_eq!(sched.active_len(), 1, "first request active");
    assert_eq!(sched.metrics.forward_calls, 1);
    sched.submit(Request::new(2, vec![7, 8, 9], 20)).unwrap();
    let before_fwd = sched.metrics.forward_calls;
    let before_decode_rows = sched.metrics.decode_rows;
    let before_prefill_rows = sched.metrics.prefill_rows;
    sched.step(); // admits id 2 (prefill span) + decodes id 1 — one call
    assert_eq!(sched.metrics.forward_calls, before_fwd + 1,
               "admission + decode must share one engine call");
    assert_eq!(sched.active_len(), 2);
    assert_eq!(sched.metrics.decode_rows, before_decode_rows + 1);
    assert_eq!(sched.metrics.prefill_rows, before_prefill_rows + 3);
    // Pure-decode iteration: still exactly one call.
    sched.step();
    assert_eq!(sched.metrics.forward_calls, before_fwd + 2);
    // An idle scheduler issues none.
    sched.cancel(1);
    sched.cancel(2);
    while sched.has_work() {
        sched.step();
    }
    let idle_fwd = sched.metrics.forward_calls;
    sched.step();
    assert_eq!(sched.metrics.forward_calls, idle_fwd,
               "no work ⇒ no engine call");
}

#[test]
fn multiple_chunked_prefills_ride_concurrently() {
    // The seed restriction (at most one `Prefilling` in flight) is
    // lifted: with prefill-span budget 2, two long prompts progress
    // through chunked prefill in the same iterations — and the token
    // streams still match the unchunked run exactly.
    let build = |chunk: usize| {
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 4,
                kv_slabs: 4,
                kv_block: 16,
                kv_blocks: 0,
                max_seq: 96,
                max_prefills_per_iter: 2,
                queue_cap: 64,
                prefill_chunk: chunk,
                threads: 1,
                kv_dtype: KvDtype::F32,
                prefix_cache: false,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        )
    };
    let prompts: Vec<Vec<u32>> = (0..2)
        .map(|i| (0..40).map(|t| 3 + (t * 3 + i) % 90).collect())
        .collect();
    let mut sched = build(8);
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(Request::new(i as u64, p.clone(), 5)).unwrap();
    }
    sched.step();
    assert_eq!(sched.prefilling_len(), 2,
               "both long prompts must be mid-prefill concurrently");
    let mut chunked = sched.run_to_completion();
    chunked.sort_by_key(|r| r.id);

    let mut sched2 = build(0);
    for (i, p) in prompts.iter().enumerate() {
        sched2.submit(Request::new(i as u64, p.clone(), 5)).unwrap();
    }
    let mut whole = sched2.run_to_completion();
    whole.sort_by_key(|r| r.id);
    for (a, b) in chunked.iter().zip(&whole) {
        assert!(a.error.is_none(), "chunked request failed: {:?}", a.error);
        assert_eq!(a.tokens, b.tokens,
                   "concurrent chunked prefill changed tokens (id {})",
                   a.id);
    }
}

#[test]
fn metrics_consistency() {
    check(303, 6, gen_workload, |workload| {
        let mut sched = make_scheduler(4, 4);
        for (i, &(plen, mnew)) in workload.iter().enumerate() {
            let prompt: Vec<u32> = (0..plen as u32).map(|t| 3 + t % 90).collect();
            let _ = sched.submit(Request::new(i as u64, prompt, mnew));
        }
        let responses = sched.run_to_completion();
        let m = &sched.metrics;
        if m.requests_completed as usize != responses.len() {
            return Err("requests_completed mismatch".into());
        }
        let gen_total: u64 =
            responses.iter().map(|r| r.tokens.len() as u64).sum();
        if m.generated_tokens != gen_total {
            return Err(format!("generated_tokens {} != {gen_total}",
                               m.generated_tokens));
        }
        if m.prefill_calls as usize != responses.len() {
            return Err("prefill_calls mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn chunked_prefill_same_results_and_bounded_stall() {
    // Same workload with and without chunking must produce identical
    // token streams; chunking must increase prefill calls (smaller units).
    let build = |chunk: usize| {
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 2,
                kv_slabs: 2,
                kv_block: 16,
                kv_blocks: 0,
                max_seq: 96,
                max_prefills_per_iter: 1,
                queue_cap: 64,
                prefill_chunk: chunk,
                threads: 1,
                kv_dtype: KvDtype::F32,
                prefix_cache: false,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        )
    };
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| (0..40 + i * 7).map(|t| 3 + (t * 3 + i) % 90).collect())
        .collect();
    let mut outs = Vec::new();
    let mut prefill_calls = Vec::new();
    for chunk in [0usize, 8] {
        let mut sched = build(chunk);
        for (i, p) in prompts.iter().enumerate() {
            sched.submit(Request::new(i as u64, p.clone(), 6)).unwrap();
        }
        let mut rs = sched.run_to_completion();
        rs.sort_by_key(|r| r.id);
        outs.push(rs.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>());
        prefill_calls.push(sched.metrics.prefill_calls);
    }
    assert_eq!(outs[0], outs[1], "chunking changed generated tokens");
    assert!(prefill_calls[1] > prefill_calls[0],
            "chunked mode must split prefills ({:?})", prefill_calls);
}

// ---------------------------------------------------------------------
// Paged KV: block-allocator properties + scheduler-level equivalence
// (DESIGN.md §13)
// ---------------------------------------------------------------------

/// Churn script: per step either reserve a random sequence up to a new
/// token total, admit a new sequence, or release one.
fn gen_churn(r: &mut Rng) -> Vec<(usize, usize)> {
    let n = r.usize(4, 40);
    (0..n).map(|_| (r.usize(0, 3), r.usize(1, 40))).collect()
}

#[test]
fn block_pool_churn_never_leaks_and_accounts_exactly() {
    check(909, 24, gen_churn, |script| {
        let block_tokens = 8;
        let total = 6;
        let max_seq = 40;
        let mut pool = BlockPool::new(total, block_tokens, 2, max_seq, 16);
        let mut live: Vec<(mergequant::engine::KvCache, usize)> = Vec::new();
        for &(op, arg) in script {
            match op {
                0 => {
                    // admit a new sequence
                    live.push((pool.new_sequence(), 0));
                }
                1 if !live.is_empty() => {
                    // grow a sequence to `arg` tokens (≤ max_seq)
                    let i = arg % live.len();
                    let want = (arg % max_seq).max(1);
                    let before = pool.free_blocks();
                    let need = want.div_ceil(block_tokens)
                        .saturating_sub(live[i].0.n_blocks());
                    match pool.reserve(&mut live[i].0, want) {
                        Ok(()) => {
                            if need > before {
                                return Err("reserve succeeded past the \
                                            free list".into());
                            }
                            if pool.free_blocks() != before - need {
                                return Err("reserve took a wrong block \
                                            count".into());
                            }
                            live[i].1 = live[i].1.max(want);
                        }
                        Err(_) => {
                            if need <= before {
                                return Err("reserve failed with blocks \
                                            free".into());
                            }
                            if pool.free_blocks() != before {
                                return Err("failed reserve must hand out \
                                            nothing".into());
                            }
                        }
                    }
                }
                _ if !live.is_empty() => {
                    let i = arg % live.len();
                    let (mut c, _) = live.swap_remove(i);
                    pool.release(&mut c);
                }
                _ => {}
            }
            // Global invariants after every op.
            let held: usize =
                live.iter().map(|(c, _)| c.n_blocks()).sum();
            if held + pool.free_blocks() != pool.total_blocks() {
                return Err(format!(
                    "block leak: {held} held + {} free != {} total",
                    pool.free_blocks(), pool.total_blocks()));
            }
            if pool.blocks_alloc() - pool.blocks_freed()
                != pool.allocated_blocks() as u64
            {
                return Err("alloc/free counters drifted from the \
                            allocation".into());
            }
            if pool.allocated_tokens()
                != pool.allocated_blocks() * pool.block_tokens()
            {
                return Err("token accounting inexact".into());
            }
        }
        for (mut c, _) in live {
            pool.release(&mut c);
        }
        if pool.free_blocks() != pool.total_blocks() {
            return Err("churn leaked blocks".into());
        }
        Ok(())
    });
}

#[test]
fn paged_scheduler_streams_match_slab_scheduler() {
    // The tentpole determinism claim at the serving level: the same
    // workload through a paged arena (any block size) produces exactly
    // the token streams of the slab-equivalent configuration (kv_block
    // 0 ⇒ one block per sequence), for both KV dtypes.
    let run = |kv_block: usize, kv: KvDtype| -> Vec<Vec<u32>> {
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        let mut sched = Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 3,
                kv_slabs: 3,
                kv_block,
                kv_blocks: 0,
                max_seq: 48,
                max_prefills_per_iter: 2,
                queue_cap: 64,
                prefill_chunk: 5,
                threads: 1,
                kv_dtype: kv,
                prefix_cache: false,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        );
        for i in 0..5u64 {
            let prompt: Vec<u32> =
                (0..9 + i).map(|t| 3 + (t as u32 * 7 + i as u32) % 90)
                    .collect();
            sched.submit(Request::new(i, prompt, 8)).unwrap();
        }
        let mut rs = sched.run_to_completion();
        rs.sort_by_key(|r| r.id);
        assert_eq!(sched.kv_available(), sched.kv_capacity(),
                   "paged run leaked blocks (kv_block {kv_block})");
        rs.into_iter()
            .inspect(|r| assert!(r.error.is_none(), "{:?}", r.error))
            .map(|r| r.tokens)
            .collect()
    };
    for kv in [KvDtype::F32, KvDtype::Int8] {
        let slab = run(0, kv);
        for kv_block in [16usize, 48] {
            assert_eq!(run(kv_block, kv), slab,
                       "kv_block {kv_block} changed token streams \
                        (kv {kv:?})");
        }
    }
}

#[test]
fn decode_lanes_finish_cache_full_fifo_under_block_pressure() {
    // Tight arena: 5 blocks × 8 tokens (40), max_seq 32. Two lanes grow
    // until the pool runs dry; the later lane (higher lane index) must
    // be the one cut off with CacheFull — deterministically — while the
    // earlier lane keeps generating, and nothing errors or leaks.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 0,
            kv_block: 8,
            kv_blocks: 5,
            max_seq: 32,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    let prompt: Vec<u32> = (0..8).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, prompt.clone(), 30)).unwrap();
    sched.submit(Request::new(2, prompt, 30)).unwrap();
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), 2);
    for r in &rs {
        assert!(r.error.is_none(), "block pressure must not error: {:?}",
                r.error);
    }
    assert_eq!(rs[1].finish, FinishReason::CacheFull,
               "the higher lane index must be cut first");
    assert!(rs[1].tokens.len() < rs[0].tokens.len(),
            "FIFO priority: lane 0 ({} toks) must outlive lane 1 ({})",
            rs[0].tokens.len(), rs[1].tokens.len());
    assert_eq!(sched.metrics.failed, 0);
    assert_eq!(sched.kv_available(), sched.kv_capacity(),
               "pressure run leaked blocks");
}

#[test]
fn stalled_prefills_requeue_newest_deterministically() {
    // Both prompts fit max_seq but the arena (4 blocks × 8 = 32 tokens)
    // cannot hold both at once mid-chunked-prefill. The scheduler must
    // not livelock AND must not fail anyone: the NEWEST prefilling
    // sequence releases its blocks and goes back to the head of the
    // pending queue (transient backpressure, not an error), both
    // requests eventually complete, and every block comes back.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 0,
            kv_block: 8,
            kv_blocks: 4,
            max_seq: 32,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk: 8,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    let prompt: Vec<u32> = (0..24).map(|t| 3 + t % 90).collect();
    sched.submit(Request::new(1, prompt.clone(), 2)).unwrap();
    sched.submit(Request::new(2, prompt, 2)).unwrap();
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), 2, "every request answered exactly once");
    for r in &rs {
        assert!(r.error.is_none(),
                "pool pressure must never fail a request: {:?}", r.error);
        assert!(!r.tokens.is_empty(), "request {} starved", r.id);
    }
    // The requeued request is served after re-admission; nothing is
    // counted as failed, and the stall is visible in kv_requeues.
    assert_eq!(rs[1].tokens.len(), 2);
    assert_eq!(rs[1].finish, FinishReason::Length);
    assert_eq!(sched.metrics.failed, 0);
    assert!(sched.metrics.kv_requeues >= 1,
            "stall resolution must be observable");
    assert_eq!(sched.kv_available(), sched.kv_capacity(),
               "requeue leaked blocks");
}

#[test]
fn bursty_mixed_priority_fleet_conserves_blocks_and_starves_no_one() {
    // Adversarial §15 workload: two arrival bursts of 6–10 lanes with
    // priorities 0..=3, impossible and generous deadlines, and
    // cancellations, through a tight arena (6 blocks × 8 tokens) that
    // forces preemption churn. With the prefix cache off the physical
    // ledger must balance after EVERY tick — free + live == capacity —
    // and every lane must get exactly one terminal response with no
    // starvation (preempted lanes resume, they are never dropped).
    use mergequant::coordinator::Event;
    check(2029, 10, common::gen_burst_fleet, |trace| {
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        let mut sched = Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 4,
                kv_slabs: 0,
                kv_block: 8,
                kv_blocks: 6,
                max_seq: 48,
                max_prefills_per_iter: 2,
                queue_cap: 64,
                prefill_chunk: 0,
                threads: 1,
                kv_dtype: KvDtype::F32,
                prefix_cache: false,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        );
        let horizon = trace
            .lanes
            .iter()
            .map(|l| l.cancel_at.unwrap_or(l.submit_at))
            .max()
            .unwrap_or(0);
        let mut responses = Vec::new();
        let mut tick = 0usize;
        while tick <= horizon || sched.has_work() {
            for l in &trace.lanes {
                if l.submit_at == tick {
                    let params = GenerationParams {
                        priority: l.priority,
                        deadline_ms: l.deadline_ms,
                        ..GenerationParams::greedy(l.max_new)
                    };
                    sched
                        .submit(Request::with_params(
                            l.id, l.prompt.clone(), params))
                        .map_err(|_| "queue full unexpectedly")?;
                }
                if l.cancel_at == Some(tick) {
                    sched.cancel(l.id);
                }
            }
            sched.step();
            // The per-tick ledger, preemption churn included.
            if sched.kv_available() + sched.kv_live_blocks()
                != sched.kv_capacity()
            {
                return Err(format!(
                    "tick {tick}: {} free + {} live != {} capacity",
                    sched.kv_available(), sched.kv_live_blocks(),
                    sched.kv_capacity()));
            }
            for ev in sched.take_events() {
                if let Event::Done { response }
                | Event::Error { response } = ev
                {
                    responses.push(response);
                }
            }
            tick += 1;
            if tick >= 100_000 {
                return Err("fleet livelock".into());
            }
        }
        if responses.len() != trace.lanes.len() {
            return Err(format!("{} responses for {} lanes",
                               responses.len(), trace.lanes.len()));
        }
        let ids: HashSet<u64> = responses.iter().map(|r| r.id).collect();
        if ids.len() != trace.lanes.len() {
            return Err("duplicate response ids".into());
        }
        for r in &responses {
            if let Some(e) = &r.error {
                return Err(format!("lane {} failed: {e}", r.id));
            }
            let lane = &trace.lanes[r.id as usize];
            if r.tokens.len() > lane.max_new {
                return Err(format!("lane {} over budget: {} > {}",
                                   r.id, r.tokens.len(), lane.max_new));
            }
            // No starvation: every lane that was not cancelled streams
            // at least its first token (CacheFull cuts still do).
            if r.finish != FinishReason::Cancelled && r.tokens.is_empty() {
                return Err(format!("lane {} starved", r.id));
            }
        }
        if sched.kv_available() != sched.kv_capacity() {
            return Err("bursty fleet leaked blocks at drain".into());
        }
        // Same trace through a prefix-on scheduler: the drain ledger
        // balances against the retained index instead.
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        let mut on = Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 4,
                kv_slabs: 0,
                kv_block: 8,
                kv_blocks: 6,
                max_seq: 48,
                max_prefills_per_iter: 2,
                queue_cap: 64,
                prefill_chunk: 0,
                threads: 1,
                kv_dtype: KvDtype::F32,
                prefix_cache: true,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        );
        let rs_on = common::drive_fleet(&mut on, trace);
        if rs_on.len() != trace.lanes.len() {
            return Err(format!("prefix-on: {} responses for {} lanes",
                               rs_on.len(), trace.lanes.len()));
        }
        if on.kv_available() + on.prefix_cached_blocks()
            != on.kv_capacity()
        {
            return Err(format!(
                "prefix-on drain leak: {} free + {} cached != {}",
                on.kv_available(), on.prefix_cached_blocks(),
                on.kv_capacity()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Prefix sharing: CoW refcount accounting + scheduler-level
// on/off-equivalence (DESIGN.md §14)
// ---------------------------------------------------------------------

fn make_prefix_scheduler(prefix: bool) -> Scheduler {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 6,
            kv_slabs: 8,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: prefix,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

#[test]
fn prefix_cache_changes_timing_never_tokens() {
    // The same seeded shared-prefix fleet (staggered admission,
    // mid-block divergence, mid-share cancellation) through a prefix-on
    // and a prefix-off scheduler: completed lanes stream identically;
    // cancelled lanes are each a prefix of the same pure stream, so the
    // shorter of the two must be a prefix of the longer (cancellation
    // at a fixed tick cuts the faster run at a different length).
    check(1201, 8, common::gen_fleet, |trace| {
        let mut on = make_prefix_scheduler(true);
        let mut off = make_prefix_scheduler(false);
        let rs_on = common::drive_fleet(&mut on, trace);
        let rs_off = common::drive_fleet(&mut off, trace);
        if rs_on.len() != trace.lanes.len()
            || rs_off.len() != trace.lanes.len()
        {
            return Err(format!("{}/{} responses for {} lanes",
                               rs_on.len(), rs_off.len(),
                               trace.lanes.len()));
        }
        for (a, b) in rs_on.iter().zip(&rs_off) {
            if let Some(e) =
                a.error.as_deref().or(b.error.as_deref())
            {
                return Err(format!("lane {} failed: {e}", a.id));
            }
            let cancelled = a.finish == FinishReason::Cancelled
                || b.finish == FinishReason::Cancelled;
            if cancelled {
                let n = a.tokens.len().min(b.tokens.len());
                if a.tokens[..n] != b.tokens[..n] {
                    return Err(format!(
                        "cancelled lane {} diverged before the cut: \
                         {:?} vs {:?}", a.id, a.tokens, b.tokens));
                }
            } else if a.tokens != b.tokens {
                return Err(format!(
                    "prefix cache changed lane {}'s stream: {:?} vs \
                     {:?}", a.id, a.tokens, b.tokens));
            }
        }
        // Drain invariants per mode: off returns everything to the
        // free list; on deliberately retains the index's blocks.
        if off.kv_available() != off.kv_capacity()
            || off.prefix_cached_blocks() != 0
        {
            return Err("prefix-off scheduler retained blocks".into());
        }
        if on.kv_available() + on.prefix_cached_blocks()
            != on.kv_capacity()
        {
            return Err(format!(
                "prefix-on drain leak: {} free + {} cached != {}",
                on.kv_available(), on.prefix_cached_blocks(),
                on.kv_capacity()));
        }
        let m = &on.metrics;
        if m.prefix_hits > m.prefix_lookups {
            return Err("more hits than lookups".into());
        }
        if m.prefix_hits > 0 && m.prefix_matched_tokens == 0 {
            return Err("hits recorded without matched tokens".into());
        }
        if off.metrics.prefix_lookups != 0 {
            return Err("prefix-off scheduler consulted the index".into());
        }
        Ok(())
    });
}

/// Sharing churn script: per step (op, arg) with op ∈ {admit, grow
/// (CoW via `reserve_writable`), release, attach-shared-clone}.
fn gen_share_churn(r: &mut Rng) -> Vec<(usize, usize)> {
    let n = r.usize(6, 48);
    (0..n).map(|_| (r.usize(0, 4), r.usize(1, 64))).collect()
}

#[test]
fn shared_block_churn_accounts_distinct_physical_blocks() {
    // The §14 refcount ledger: however many tables share a block, the
    // pool's books count it once — distinct physical blocks held across
    // every live table + free list == arena, and the alloc/freed
    // counters track exactly that (attaching an `Arc` clone moves
    // neither; a shared handle's release frees nothing until it is the
    // last). CoW growth of shared boundaries rides the same script.
    check(1717, 24, gen_share_churn, |script| {
        let bt = 8usize;
        let max_seq = 48usize;
        let mut pool = BlockPool::new(8, bt, 2, max_seq, 16);
        let mut live: Vec<mergequant::engine::KvCache> = Vec::new();
        for &(op, arg) in script {
            match op {
                0 => live.push(pool.new_sequence()),
                1 if !live.is_empty() => {
                    let i = arg % live.len();
                    let want = (arg % max_seq).max(1);
                    let before = pool.free_blocks();
                    let need = pool.blocks_needed(&live[i], want);
                    match pool.reserve_writable(&mut live[i], want) {
                        Ok(()) => {
                            if pool.free_blocks() != before - need {
                                return Err("reserve_writable took a \
                                            wrong block count".into());
                            }
                            // simulate the forward pass writing rows
                            live[i].len = live[i].len.max(want);
                        }
                        Err(missing) => {
                            if missing == 0 || need <= before {
                                return Err("failed with blocks \
                                            free".into());
                            }
                            if pool.free_blocks() != before {
                                return Err("failed reserve must be \
                                            all-or-nothing".into());
                            }
                        }
                    }
                }
                2 if !live.is_empty() => {
                    let i = arg % live.len();
                    let mut c = live.swap_remove(i);
                    pool.release(&mut c);
                }
                _ if !live.is_empty() => {
                    // attach a shared clone of a donor's prefix — the
                    // admission path of a prefix hit
                    let d = arg % live.len();
                    if live[d].len > 0 {
                        let take = (arg % live[d].len) + 1;
                        let mut c = pool.new_sequence();
                        for b in 0..take.div_ceil(bt) {
                            c.push_block(live[d].block_arc(b));
                        }
                        c.len = take;
                        live.push(c);
                    }
                }
                _ => {}
            }
            let distinct: HashSet<*const mergequant::engine::KvBlock> =
                live.iter()
                    .flat_map(|c| {
                        (0..c.n_blocks()).map(|b| c.block_ptr(b))
                    })
                    .collect();
            if distinct.len() + pool.free_blocks()
                != pool.total_blocks()
            {
                return Err(format!(
                    "physical ledger broke: {} distinct + {} free != \
                     {} total", distinct.len(), pool.free_blocks(),
                    pool.total_blocks()));
            }
            if pool.blocks_alloc() - pool.blocks_freed()
                != pool.allocated_blocks() as u64
            {
                return Err("alloc/freed counters drifted under \
                            sharing".into());
            }
        }
        for mut c in live {
            pool.release(&mut c);
        }
        if pool.free_blocks() != pool.total_blocks() {
            return Err("sharing churn leaked blocks".into());
        }
        Ok(())
    });
}

#[test]
#[should_panic(expected = "double free")]
fn double_release_panics_under_cow_sharing() {
    // The PR-5 double-free contract survives sharing: a table that CoW'd
    // a shared boundary and grew private blocks still panics on a second
    // release rather than corrupting the free list.
    let mut pool = BlockPool::new(8, 8, 2, 48, 16);
    let mut donor = pool.new_sequence();
    pool.reserve_writable(&mut donor, 12).unwrap();
    donor.len = 12;
    let mut c = pool.new_sequence();
    c.push_block(donor.block_arc(0));
    c.push_block(donor.block_arc(1));
    c.len = 12;
    pool.reserve_writable(&mut c, 20).unwrap(); // CoW + growth
    pool.release(&mut c);
    pool.release(&mut c);
}

#[test]
fn prefix_pressure_evicts_cached_blocks_and_balances_at_drain() {
    // A tight arena (6 blocks × 8 tokens) with the index unbounded:
    // retained prefixes eventually occupy blocks that admissions and
    // decode growth need, so the scheduler must evict LRU leaves under
    // pressure instead of stalling or failing — and the books balance
    // at drain.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 2,
            kv_slabs: 0,
            kv_block: 8,
            kv_blocks: 6,
            max_seq: 32,
            max_prefills_per_iter: 1,
            queue_cap: 16,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: true,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    for i in 0..4u64 {
        let prompt: Vec<u32> =
            (0..16).map(|t| 3 + (t * 3 + i as u32 * 17) % 90).collect();
        sched.submit(Request::new(i, prompt, 2)).unwrap();
        let rs = sched.run_to_completion();
        assert_eq!(rs.len(), 1);
        assert!(rs[0].error.is_none(),
                "pressure must evict, not fail: {:?}", rs[0].error);
        assert_eq!(rs[0].tokens.len(), 2);
    }
    assert_eq!(sched.metrics.failed, 0);
    assert!(sched.metrics.prefix_evicted_blocks >= 2,
            "retention must have been pushed out under pressure (got \
             {})", sched.metrics.prefix_evicted_blocks);
    assert_eq!(sched.kv_available() + sched.prefix_cached_blocks(),
               sched.kv_capacity(),
               "eviction under pressure leaked blocks");
}

#[test]
fn paged_admission_outpacks_slab_admission_at_equal_bytes() {
    // The capacity thesis (DESIGN.md §13): at equal arena bytes, short
    // sequences admit proportionally to their actual token usage, not
    // to max_seq reservations. Arena = 4 × 64 tokens either way; 16
    // short requests (5-token prompt + 3 decode) peak at 4 concurrent
    // under slab reservations vs 16 under paging.
    let peak = |kv_block: usize| -> (usize, f64) {
        let engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
        let mut sched = Scheduler::new(
            engine,
            SchedulerConfig {
                max_batch: 32,
                kv_slabs: 4,
                kv_block,
                kv_blocks: 0,
                max_seq: 64,
                max_prefills_per_iter: 16,
                queue_cap: 64,
                prefill_chunk: 0,
                threads: 1,
                kv_dtype: KvDtype::F32,
                prefix_cache: false,
                prefix_cache_blocks: 0,
                max_decode_latency: 0,
                speculative: false,
                draft_k: 0,
                draft_layers: 0,
            },
        );
        for i in 0..16u64 {
            let prompt: Vec<u32> = (0..5).map(|t| 3 + t % 90).collect();
            sched.submit(Request::new(i, prompt, 3)).unwrap();
        }
        let mut peak = 0usize;
        while sched.has_work() {
            sched.step();
            peak = peak.max(sched.active_len() + sched.prefilling_len());
        }
        (peak, sched.metrics.kv_util_mean())
    };
    let (slab_peak, slab_util) = peak(0);
    let (paged_peak, paged_util) = peak(8);
    assert!(slab_peak <= 4, "slab reservations cap concurrency at 4, \
                             got {slab_peak}");
    assert!(paged_peak >= 4 * slab_peak,
            "paged admission must pack ≥4× more short sequences \
             (slab {slab_peak}, paged {paged_peak})");
    assert!(paged_util > slab_util,
            "paged utilization ({paged_util:.2}) must beat slab \
             ({slab_util:.2})");
}
