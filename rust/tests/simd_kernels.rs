//! SIMD microkernel equivalence suite (DESIGN.md §17): every dispatch
//! variant this host can run must be **bitwise identical** to the
//! pinned scalar reference — at the dot level (ragged tails shorter
//! than one SIMD lane, the empty dot, mismatched slice lengths), at
//! the GEMM level (m = 1 decode GEMV rows and odd packed-INT4
//! reduction lengths included), and end-to-end (one shared-prefix
//! serving trace plus an int8-KV decode replayed under every forced
//! kernel, on the channel-static W4A4 engine). The CI engine matrix
//! additionally runs this whole binary with `MQ_KERNEL=scalar`
//! exported, covering the dispatcher's env-var path.

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    GenerationParams, Request, Scheduler, SchedulerConfig,
};
use mergequant::engine::{Engine, KvDtype};
use mergequant::quant::gemm::{dot_i8_scalar, gemm_i8, gemm_i8_packed4};
use mergequant::quant::pack::pack_int4;
use mergequant::quant::parallel::{
    par_gemm_i8, par_gemm_i8_packed4, ThreadPool,
};
use mergequant::quant::simd;
use mergequant::util::rng::Rng;

/// Tests that `force()` the process-wide dispatch run serialized:
/// all variants are bit-identical so a concurrent force cannot change
/// any *output*, but `active().kind()` assertions would race.
static KERNEL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    KERNEL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Full-range i8 operands — the activation side is not int4-bounded.
fn full_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.usize(0, 256) as u8 as i8).collect()
}

#[test]
fn dot_variants_bitwise_match_scalar_on_ragged_lengths() {
    let mut rng = Rng::new(0x51D0);
    let lens = [0usize, 1, 2, 3, 7, 8, 15, 16, 17, 31, 32, 33, 63, 64,
                65, 100, 255, 256, 257, 1000];
    for kind in simd::available() {
        let kern = simd::for_kind(kind).expect("listed as available");
        for &n in &lens {
            let a = full_i8(&mut rng, n);
            // One operand 5 longer: every variant must share the
            // scalar zip's min-length truncation semantics.
            let b = full_i8(&mut rng, n + 5);
            assert_eq!(kern.dot(&a, &b), dot_i8_scalar(&a, &b),
                       "{} n={n} ragged", kind.name());
            assert_eq!(kern.dot(&a, &b[..n]), dot_i8_scalar(&a, &b[..n]),
                       "{} n={n}", kind.name());
        }
    }
}

#[test]
fn gemm_bitwise_identical_under_every_forced_kernel() {
    let _g = lock();
    let prev = simd::active().kind();
    let mut rng = Rng::new(0x6E33);
    // m = 1 is the decode GEMV row; odd/prime n exercises packed-INT4
    // half-byte tails; (12, 255, 40) engages the packed row path.
    for (m, n, j) in [(1usize, 97usize, 33usize), (5, 31, 7),
                      (8, 130, 17), (12, 255, 40)] {
        let xq = full_i8(&mut rng, m * n);
        let wt: Vec<i8> =
            (0..j * n).map(|_| rng.usize(0, 15) as i8 - 7).collect();
        let mut packed = Vec::new();
        for c in 0..j {
            packed.extend(pack_int4(&wt[c * n..(c + 1) * n]));
        }
        assert!(simd::force(simd::KernelKind::Scalar));
        let mut want = vec![0i32; m * j];
        gemm_i8(&xq, &wt, m, n, j, &mut want);
        let mut scratch = Vec::new();
        let mut want4 = vec![0i32; m * j];
        gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch, &mut want4);
        assert_eq!(want, want4, "scalar packed self-check m{m} n{n} j{j}");
        let pool = ThreadPool::new(4);
        for kind in simd::available() {
            assert!(simd::force(kind));
            let mut got = vec![0i32; m * j];
            gemm_i8(&xq, &wt, m, n, j, &mut got);
            assert_eq!(got, want, "{} gemm_i8 m{m} n{n} j{j}",
                       kind.name());
            let mut got4 = vec![0i32; m * j];
            gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch,
                            &mut got4);
            assert_eq!(got4, want, "{} packed4 m{m} n{n} j{j}",
                       kind.name());
            let mut gotp = vec![0i32; m * j];
            par_gemm_i8(&pool, &xq, &wt, m, n, j, &mut gotp);
            assert_eq!(gotp, want, "{} par_gemm_i8 m{m} n{n} j{j}",
                       kind.name());
            let mut gotp4 = vec![0i32; m * j];
            par_gemm_i8_packed4(&pool, &xq, &packed, m, n, j,
                                &mut scratch, &mut gotp4);
            assert_eq!(gotp4, want, "{} par packed m{m} n{n} j{j}",
                       kind.name());
        }
    }
    simd::force(prev);
}

/// Shared-prefix fleet over the channel-static W4A4 engine — the
/// serving trace whose streams and scheduling counters every kernel
/// must reproduce exactly.
fn trace_scheduler() -> Scheduler {
    Scheduler::new(
        Engine::new(synthetic_model("mergequant_static", 64, 128, 2, 96)),
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 24,
            max_seq: 256,
            max_prefills_per_iter: 1,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 2,
            kv_dtype: KvDtype::F32,
            prefix_cache: true,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

fn run_trace() -> (Vec<Vec<u32>>, u64) {
    let mut sched = trace_scheduler();
    for i in 0..4u64 {
        let mut prompt: Vec<u32> =
            (0..48u32).map(|t| 3 + (t * 5) % 90).collect();
        prompt.extend((0..6u32).map(|t| 7 + (t * 11 + i as u32) % 90));
        sched.submit(Request::new(i, prompt, 8)).unwrap();
    }
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    for r in &rs {
        assert!(r.error.is_none(), "lane failed: {:?}", r.error);
    }
    (rs.into_iter().map(|r| r.tokens).collect(),
     sched.metrics.prefill_rows)
}

#[test]
fn serving_trace_is_kernel_invariant() {
    let _g = lock();
    let prev = simd::active().kind();
    assert!(simd::force(simd::KernelKind::Scalar));
    let (base_streams, base_rows) = run_trace();
    for kind in simd::available() {
        assert!(simd::force(kind));
        let (streams, rows) = run_trace();
        assert_eq!(streams, base_streams,
                   "kernel {} changed stream content", kind.name());
        assert_eq!(rows, base_rows,
                   "kernel {} changed scheduling", kind.name());
    }
    simd::force(prev);
}

#[test]
fn int8_kv_decode_is_kernel_invariant() {
    // Covers the attention-side dot (paged int8 KV) under every
    // kernel, not just the linear-layer GEMMs.
    let _g = lock();
    let prev = simd::active().kind();
    let model = synthetic_model("mergequant_static", 64, 128, 2, 96);
    let prompt: Vec<u32> = (0..24u32).map(|i| 3 + (i * 7) % 90).collect();
    let sampler = GenerationParams::greedy(12).sampler();
    let mut base: Option<Vec<u32>> = None;
    for kind in simd::available() {
        assert!(simd::force(kind));
        let mut engine = Engine::new(model.clone());
        engine.ensure_kv_scales().unwrap();
        let out = engine
            .generate_seeded(&prompt, 12, prompt.len() + 20,
                             KvDtype::Int8, &sampler)
            .unwrap();
        match &base {
            None => base = Some(out),
            Some(b) => assert_eq!(&out, b,
                                  "kernel {} changed int8-KV decode",
                                  kind.name()),
        }
    }
    simd::force(prev);
}
