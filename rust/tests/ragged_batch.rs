//! Ragged-batch equivalence (DESIGN.md §12): `Engine::forward_batch`
//! over ANY interleaving of prefill chunks and decode steps is **bitwise
//! identical** to the sequential seed replay (`prefill` per chunk +
//! `decode_batch` over the tick's decode lanes), across thread counts
//! and KV dtypes. Row math is per-row independent in the tiled kernels,
//! so stacking spans can relabel rows but never change their values.
//!
//! CI matrix knobs (DESIGN.md §7/§10): `MQ_TEST_THREADS` feeds an extra
//! thread count into the sweeps, `MQ_TEST_KV` restricts the dtype axis.

mod common;

use std::collections::VecDeque;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::BlockPool;
use mergequant::engine::{
    BatchPlan, Engine, EngineError, KvCache, KvDtype, SpanLogits, Workspace,
};
use mergequant::util::proptest::{check, Shrink};
use mergequant::util::rng::Rng;

fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1, 4];
    if let Some(extra) = std::env::var("MQ_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        if extra > 0 && !counts.contains(&extra) {
            counts.push(extra);
        }
    }
    counts
}

fn kv_dtypes() -> Vec<KvDtype> {
    match std::env::var("MQ_TEST_KV").as_deref() {
        Ok("int8") => vec![KvDtype::Int8],
        Ok("f32") => vec![KvDtype::F32],
        _ => vec![KvDtype::F32, KvDtype::Int8],
    }
}

/// Paged block sizes for the paged≡slab sweeps: small (many blocks per
/// sequence), medium, and 0 ⇒ the slab layout itself (one block of
/// `cap`). `MQ_TEST_KV_BLOCK` feeds an extra size in from the CI
/// matrix.
fn kv_block_sizes() -> Vec<usize> {
    let mut sizes = vec![16, 64, 0];
    if let Some(extra) = std::env::var("MQ_TEST_KV_BLOCK")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        if !sizes.contains(&extra) {
            sizes.push(extra);
        }
    }
    sizes
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

fn test_engine(threads: usize) -> Engine {
    let mut engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 2, 96), threads);
    engine.ensure_kv_scales().unwrap();
    engine
}

// ---------------------------------------------------------------------
// Property: any interleaving ≡ the sequential seed replay
// ---------------------------------------------------------------------

/// One scripted lifecycle step of a sequence: consume a prompt chunk or
/// decode one teacher-forced token.
#[derive(Clone, Debug)]
enum Op {
    Chunk(usize),
    Decode(u32),
}

/// A scripted serving trace: per-sequence prompts plus a tick schedule.
/// Each tick advances a subset of the sequences by one op — ticks that
/// mix a prefill chunk with decode lanes are exactly the ragged shape
/// the scheduler builds.
#[derive(Clone, Debug)]
struct Scenario {
    prompts: Vec<Vec<u32>>,
    /// Each tick: (sequence index, op), ascending by sequence index,
    /// at most one op per sequence.
    ticks: Vec<Vec<(usize, Op)>>,
}

impl Shrink for Scenario {}

fn gen_scenario(r: &mut Rng) -> Scenario {
    let n = r.usize(1, 4);
    let prompts: Vec<Vec<u32>> = (0..n)
        .map(|_| {
            let len = r.usize(2, 13);
            (0..len).map(|_| 3 + r.usize(0, 90) as u32).collect()
        })
        .collect();
    let mut queues: Vec<VecDeque<Op>> = prompts
        .iter()
        .map(|p| {
            let mut q = VecDeque::new();
            let mut off = 0usize;
            while off < p.len() {
                let c = r.usize(1, p.len() - off + 1);
                q.push_back(Op::Chunk(c));
                off += c;
            }
            for _ in 0..r.usize(1, 6) {
                q.push_back(Op::Decode(3 + r.usize(0, 90) as u32));
            }
            q
        })
        .collect();
    let mut ticks = Vec::new();
    while queues.iter().any(|q| !q.is_empty()) {
        let mut tick: Vec<(usize, Op)> = Vec::new();
        for (i, q) in queues.iter_mut().enumerate() {
            if !q.is_empty() && r.usize(0, 4) > 0 {
                tick.push((i, q.pop_front().unwrap()));
            }
        }
        if tick.is_empty() {
            let i = queues.iter().position(|q| !q.is_empty()).unwrap();
            tick.push((i, queues[i].pop_front().unwrap()));
        }
        ticks.push(tick);
    }
    Scenario { prompts, ticks }
}

fn make_caches(engine: &Engine, sc: &Scenario, kv: KvDtype) -> Vec<KvCache> {
    let cfg = engine.config();
    sc.prompts
        .iter()
        .map(|p| KvCache::with_dtype(kv, cfg.n_layers, p.len() + 8,
                                     cfg.d_model))
        .collect()
}

/// Paged variant of [`make_caches`]: block tables of `block_tokens`-row
/// blocks grown lazily (0 ⇒ slab: one block of the whole capacity).
fn make_paged_caches(engine: &Engine, sc: &Scenario, kv: KvDtype,
                     block_tokens: usize) -> Vec<KvCache> {
    let cfg = engine.config();
    sc.prompts
        .iter()
        .map(|p| {
            let cap = p.len() + 8;
            let bt = if block_tokens == 0 { cap } else { block_tokens };
            KvCache::paged(kv, cfg.n_layers, cap, cfg.d_model, bt)
        })
        .collect()
}

/// Replay the trace with one ragged `forward_batch` per tick over the
/// given caches (slab or paged); returns the emitted logits bits (span
/// order) plus final cache lengths.
fn run_unified(engine: &Engine, sc: &Scenario, mut caches: Vec<KvCache>)
               -> (Vec<u32>, Vec<usize>) {
    let mut consumed = vec![0usize; sc.prompts.len()];
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    for tick in &sc.ticks {
        let lanes: Vec<usize> = tick.iter().map(|(s, _)| *s).collect();
        let mut plan = BatchPlan::new();
        for (k, (seq, op)) in tick.iter().enumerate() {
            match op {
                Op::Chunk(c) => {
                    let toks =
                        &sc.prompts[*seq][consumed[*seq]..consumed[*seq] + c];
                    let last =
                        consumed[*seq] + c == sc.prompts[*seq].len();
                    plan.push_span(k, toks, if last {
                        SpanLogits::Last
                    } else {
                        SpanLogits::None
                    });
                }
                Op::Decode(t) => {
                    plan.push_span(k, std::slice::from_ref(t),
                                   SpanLogits::Last);
                }
            }
        }
        let mut refs: Vec<&mut KvCache> = caches
            .iter_mut()
            .enumerate()
            .filter_map(|(i, c)| lanes.contains(&i).then_some(c))
            .collect();
        engine.forward_batch(&plan, &mut refs, &mut ws).unwrap();
        out.extend(bits(&ws.logits));
        for (seq, op) in tick {
            if let Op::Chunk(c) = op {
                consumed[*seq] += c;
            }
        }
    }
    (out, caches.iter().map(|c| c.len).collect())
}

/// Replay the same trace on the sequential seed paths: one `prefill`
/// call per chunk, one `decode_batch` over each tick's decode lanes;
/// assemble the emitted rows in the same span order as the unified run.
fn run_sequential(engine: &Engine, sc: &Scenario, kv: KvDtype)
                  -> (Vec<u32>, Vec<usize>) {
    let cfg = engine.config().clone();
    let v = cfg.vocab;
    let mut caches = make_caches(engine, sc, kv);
    let mut consumed = vec![0usize; sc.prompts.len()];
    let mut ws = Workspace::new();
    let mut out = Vec::new();
    for tick in &sc.ticks {
        // Per-op emitted row, keyed by position in the tick.
        let mut emitted: Vec<Option<Vec<u32>>> = vec![None; tick.len()];
        let mut decode_ops: Vec<(usize, usize, u32)> = Vec::new();
        for (k, (seq, op)) in tick.iter().enumerate() {
            match op {
                Op::Chunk(c) => {
                    let toks =
                        &sc.prompts[*seq][consumed[*seq]..consumed[*seq] + c];
                    engine.prefill(toks, &mut caches[*seq], &mut ws)
                        .unwrap();
                    if consumed[*seq] + c == sc.prompts[*seq].len() {
                        emitted[k] =
                            Some(bits(&ws.logits[(c - 1) * v..c * v]));
                    }
                    consumed[*seq] += c;
                }
                Op::Decode(t) => decode_ops.push((k, *seq, *t)),
            }
        }
        if !decode_ops.is_empty() {
            let toks: Vec<u32> =
                decode_ops.iter().map(|&(_, _, t)| t).collect();
            let seqs: Vec<usize> =
                decode_ops.iter().map(|&(_, s, _)| s).collect();
            let mut refs: Vec<&mut KvCache> = caches
                .iter_mut()
                .enumerate()
                .filter_map(|(i, c)| seqs.contains(&i).then_some(c))
                .collect();
            engine.decode_batch(&toks, &mut refs, &mut ws).unwrap();
            for (bi, &(k, _, _)) in decode_ops.iter().enumerate() {
                emitted[k] = Some(bits(&ws.logits[bi * v..(bi + 1) * v]));
            }
        }
        for row in emitted.into_iter().flatten() {
            out.extend(row);
        }
    }
    (out, caches.iter().map(|c| c.len).collect())
}

#[test]
fn ragged_forward_bitwise_equals_sequential_replay() {
    for kv in kv_dtypes() {
        for &threads in &thread_counts() {
            let engine = test_engine(threads);
            check(7919 + threads as u64, 5, gen_scenario, |sc| {
                let (ub, ulen) =
                    run_unified(&engine, sc, make_caches(&engine, sc, kv));
                let (sb, slen) = run_sequential(&engine, sc, kv);
                if ulen != slen {
                    return Err(format!(
                        "cache lengths diverged: {ulen:?} vs {slen:?} \
                         (kv {kv:?}, threads {threads})"));
                }
                if ub != sb {
                    return Err(format!(
                        "logits bits diverged (kv {kv:?}, \
                         threads {threads})"));
                }
                Ok(())
            });
        }
    }
}

// ---------------------------------------------------------------------
// Property: paged KV ≡ slab KV, bitwise, on scripted serving traces
// (DESIGN.md §13) — across {threads}×{kv dtype}×{kv_block}.
// ---------------------------------------------------------------------

#[test]
fn paged_kv_bitwise_equals_slab_kv() {
    for kv in kv_dtypes() {
        for &threads in &thread_counts() {
            let engine = test_engine(threads);
            check(6271 + threads as u64, 5, gen_scenario, |sc| {
                let (slab_bits, slab_len) =
                    run_unified(&engine, sc, make_caches(&engine, sc, kv));
                for bt in kv_block_sizes() {
                    let (pb, pl) = run_unified(
                        &engine, sc,
                        make_paged_caches(&engine, sc, kv, bt));
                    if pl != slab_len {
                        return Err(format!(
                            "cache lengths diverged: {pl:?} vs \
                             {slab_len:?} (kv {kv:?}, threads {threads}, \
                             kv_block {bt})"));
                    }
                    if pb != slab_bits {
                        return Err(format!(
                            "paged logits bits diverged from slab \
                             (kv {kv:?}, threads {threads}, \
                             kv_block {bt})"));
                    }
                }
                Ok(())
            });
        }
    }
}

#[test]
fn paged_cache_reports_block_proportional_bytes() {
    // The capacity story in bytes: a short sequence in a paged cache
    // holds only ⌈len/B⌉ blocks, not a full max_seq slab.
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut slab = KvCache::new(cfg.n_layers, 512, cfg.d_model);
    let mut paged =
        KvCache::paged(KvDtype::F32, cfg.n_layers, 512, cfg.d_model, 16);
    engine.prefill(&[3, 4, 5, 6, 7], &mut slab, &mut ws).unwrap();
    engine.prefill(&[3, 4, 5, 6, 7], &mut paged, &mut ws).unwrap();
    assert_eq!(paged.n_blocks(), 1, "5 tokens fit one 16-token block");
    assert_eq!(slab.bytes() / paged.bytes(), 512 / 16,
               "slab reserves the whole capacity, paged only the blocks \
                in use");
}

// ---------------------------------------------------------------------
// Directed unit coverage of the plan contract
// ---------------------------------------------------------------------

#[test]
fn mixed_plan_matches_separate_prefill_and_decode_calls() {
    // One plan carrying a whole-prompt admission (All rows) + two decode
    // lanes must reproduce the separate seed calls bitwise — including
    // the (t, vocab) prefill logits layout.
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let v = cfg.vocab;
    let prompt_a: Vec<u32> = (0..7).map(|i| 3 + i * 5).collect();
    let prompt_b: Vec<u32> = (0..4).map(|i| 9 + i * 3).collect();
    let incoming: Vec<u32> = (0..6).map(|i| 4 + i * 7).collect();

    // Seed replay: two prefills, then one batched decode, then the
    // incoming prefill on its own.
    let mut ws = Workspace::new();
    let mut ca = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut cb = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut ci = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    engine.prefill(&prompt_a, &mut ca, &mut ws).unwrap();
    engine.prefill(&prompt_b, &mut cb, &mut ws).unwrap();
    let toks = [5u32, 11u32];
    let mut refs = [&mut ca, &mut cb];
    engine.decode_batch(&toks, &mut refs, &mut ws).unwrap();
    let want_decode = bits(&ws.logits[..2 * v]);
    engine.prefill(&incoming, &mut ci, &mut ws).unwrap();
    let want_prefill = bits(&ws.logits[..incoming.len() * v]);

    // Unified: one ragged call — the incoming admission (All) rides with
    // both decode lanes.
    let mut ws2 = Workspace::new();
    let mut ca2 = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut cb2 = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut ci2 = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    engine.prefill(&prompt_a, &mut ca2, &mut ws2).unwrap();
    engine.prefill(&prompt_b, &mut cb2, &mut ws2).unwrap();
    let mut plan = BatchPlan::new();
    plan.push_span(0, &incoming, SpanLogits::All);
    plan.push_span(1, &[5u32], SpanLogits::Last);
    plan.push_span(2, &[11u32], SpanLogits::Last);
    let mut refs2 = [&mut ci2, &mut ca2, &mut cb2];
    engine.forward_batch(&plan, &mut refs2, &mut ws2).unwrap();

    assert_eq!(plan.emitted_rows(), incoming.len() + 2);
    assert_eq!(plan.logits_rows(0), 0..incoming.len());
    let got_prefill = bits(&ws2.logits[..incoming.len() * v]);
    assert_eq!(got_prefill, want_prefill,
               "admission span logits diverged from seed prefill");
    let r1 = plan.logits_rows(1).start;
    let got_decode = bits(&ws2.logits[r1 * v..(r1 + 2) * v]);
    assert_eq!(got_decode, want_decode,
               "decode lane logits diverged from seed decode_batch");
    assert_eq!(ci2.len, incoming.len());
    assert_eq!(ca2.len, prompt_a.len() + 1);
    assert_eq!(cb2.len, prompt_b.len() + 1);
}

#[test]
fn overflow_names_the_offending_span_and_mutates_nothing() {
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut big = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut small = KvCache::new(cfg.n_layers, 4, cfg.d_model);
    engine.prefill(&[3, 4, 5], &mut big, &mut ws).unwrap();
    let mut plan = BatchPlan::new();
    plan.push_span(0, &[7], SpanLogits::Last);
    plan.push_span(1, &[3, 4, 5, 6, 7], SpanLogits::Last); // 5 > cap 4
    let mut refs = [&mut big, &mut small];
    let err = engine.forward_batch(&plan, &mut refs, &mut ws).unwrap_err();
    assert_eq!(err, EngineError::KvOverflow { lane: 1, pos: 4, cap: 4 });
    assert_eq!(big.len, 3, "validation must precede any state mutation");
    assert_eq!(small.len, 0);
}

#[test]
fn none_spans_emit_no_logits_rows() {
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut c = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut plan = BatchPlan::new();
    plan.push_span(0, &[3, 4, 5, 6], SpanLogits::None);
    let mut refs = [&mut c];
    engine.forward_batch(&plan, &mut refs, &mut ws).unwrap();
    assert_eq!(plan.emitted_rows(), 0);
    assert!(ws.logits.is_empty(),
            "a non-final prefill chunk must emit no logits");
    assert_eq!(c.len, 4, "the chunk must still fill the cache");
    // Continue with a Last chunk: identical to chunked seed prefill.
    let mut plan2 = BatchPlan::new();
    plan2.push_span(0, &[7, 8], SpanLogits::Last);
    let mut refs2 = [&mut c];
    engine.forward_batch(&plan2, &mut refs2, &mut ws).unwrap();
    let got = bits(&ws.logits);

    let mut ws2 = Workspace::new();
    let mut c2 = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    engine.prefill(&[3, 4, 5, 6, 7, 8], &mut c2, &mut ws2).unwrap();
    let v = cfg.vocab;
    let want = bits(&ws2.logits[5 * v..6 * v]);
    assert_eq!(got, want, "None→Last chunking diverged from single-shot");
}

#[test]
fn empty_plan_is_a_noop() {
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut c = KvCache::new(cfg.n_layers, 8, cfg.d_model);
    let plan = BatchPlan::new();
    assert!(plan.is_empty());
    let mut refs = [&mut c];
    engine.forward_batch(&plan, &mut refs, &mut ws).unwrap();
    assert_eq!(c.len, 0);
    assert!(ws.logits.is_empty());
}

#[test]
#[should_panic(expected = "duplicate lane")]
fn duplicate_lane_in_plan_panics() {
    // The paged analogue of the slab pool's duplicate-id contract: two
    // spans appending to the same cache in one call is a plan-
    // construction bug and must panic, not corrupt the cache.
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut c = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut plan = BatchPlan::new();
    plan.push_span(0, &[3], SpanLogits::Last);
    plan.push_span(0, &[4], SpanLogits::Last);
    let mut refs = [&mut c];
    let _ = engine.forward_batch(&plan, &mut refs, &mut ws);
}

// ---------------------------------------------------------------------
// Property: shared-prefix block tables + CoW ≡ cold unshared replay,
// bitwise (DESIGN.md §14) — the engine-level half of the prefix-sharing
// determinism suite. Frozen KV rows are pure functions of the token
// prefix, so lanes reading another lane's blocks through Arc handles
// must emit the exact bits of a private prefill of the same tokens.
// ---------------------------------------------------------------------

/// Run one lane: a single prefill span (the unmatched prompt tail) and
/// then `dec` teacher-forced decode steps; returns every emitted logits
/// row as bits. The caller has already reserved the prompt's blocks.
fn run_lane(engine: &Engine, ws: &mut Workspace, pool: &mut BlockPool,
            c: &mut KvCache, span: &[u32], dec: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut plan = BatchPlan::new();
    plan.push_span(0, span, SpanLogits::Last);
    let mut refs = [&mut *c];
    engine.forward_batch(&plan, &mut refs, ws).unwrap();
    out.extend(bits(&ws.logits));
    for &t in dec {
        pool.reserve_writable(c, c.len + 1)
            .expect("decode growth exceeds the test arena");
        let mut plan = BatchPlan::new();
        plan.push_span(0, std::slice::from_ref(&t), SpanLogits::Last);
        let mut refs = [&mut *c];
        engine.forward_batch(&plan, &mut refs, ws).unwrap();
        out.extend(bits(&ws.logits));
    }
    out
}

#[test]
fn shared_prefix_tables_bitwise_equal_cold_replay() {
    const BT: usize = 8;
    for kv in kv_dtypes() {
        for &threads in &thread_counts() {
            let engine = test_engine(threads);
            let cfg = engine.config().clone();
            check(5381 + threads as u64, 4, common::gen_fleet, |trace| {
                let mut pool = BlockPool::with_dtype(
                    kv, 48, BT, cfg.n_layers, 64, cfg.d_model);
                let mut ws = Workspace::new();

                // Donor lane: prefill the fleet's shared prefix once;
                // its frozen blocks are what every lane borrows.
                let mut donor = pool.new_sequence();
                pool.reserve_writable(&mut donor, trace.prefix.len())
                    .expect("donor exceeds the test arena");
                let mut plan = BatchPlan::new();
                plan.push_span(0, &trace.prefix, SpanLogits::None);
                let mut refs = [&mut donor];
                engine.forward_batch(&plan, &mut refs, &mut ws).unwrap();

                // Keep every shared table alive until the end so blocks
                // are multiply shared while later lanes attach.
                let mut held: Vec<KvCache> = Vec::new();
                for lane in &trace.lanes {
                    let matched =
                        lane.prefix_take.min(lane.prompt.len() - 1);
                    let dec: Vec<u32> = (0..3)
                        .map(|s| 3 + ((lane.id as usize * 7 + s * 13)
                                      % 90) as u32)
                        .collect();

                    // Shared run: attach the donor's covering blocks
                    // (the last one possibly part-full — the CoW
                    // boundary), then reserve writable growth.
                    let mut c = pool.new_sequence();
                    let full = matched / BT;
                    for b in 0..full {
                        c.push_block(donor.block_arc(b));
                    }
                    if matched % BT != 0 {
                        c.push_block(donor.block_arc(full));
                    }
                    c.len = matched;
                    let was_shared = c.shared_blocks();
                    pool.reserve_writable(&mut c, lane.prompt.len())
                        .expect("lane exceeds the test arena");
                    if c.shared_blocks() != full {
                        return Err(format!(
                            "lane {}: {} shared blocks after CoW, want \
                             the {full} frozen ones (had {was_shared}; \
                             kv {kv:?}, threads {threads})",
                            lane.id, c.shared_blocks()));
                    }
                    let got = run_lane(&engine, &mut ws, &mut pool,
                                       &mut c, &lane.prompt[matched..],
                                       &dec);
                    held.push(c);

                    // Cold unshared replay of the identical token
                    // sequence: whole prompt privately prefilled.
                    let mut c2 = pool.new_sequence();
                    pool.reserve_writable(&mut c2, lane.prompt.len())
                        .expect("cold lane exceeds the test arena");
                    let want = run_lane(&engine, &mut ws, &mut pool,
                                        &mut c2, &lane.prompt, &dec);
                    pool.release(&mut c2);

                    if got != want {
                        return Err(format!(
                            "lane {} (take {}, matched {matched}) \
                             diverged from cold replay (kv {kv:?}, \
                             threads {threads})",
                            lane.id, lane.prefix_take));
                    }
                }
                for mut c in held {
                    pool.release(&mut c);
                }
                pool.release(&mut donor);
                if pool.free_blocks() != pool.total_blocks() {
                    return Err(format!(
                        "pool leaked: {} free of {} after release",
                        pool.free_blocks(), pool.total_blocks()));
                }
                if pool.blocks_alloc() != pool.blocks_freed() {
                    return Err(format!(
                        "alloc/freed imbalance at drain: {} vs {}",
                        pool.blocks_alloc(), pool.blocks_freed()));
                }
                Ok(())
            });
        }
    }
}

// ---------------------------------------------------------------------
// Property: a k-token All-rows span ≡ k sequential Last-span decodes,
// bitwise (DESIGN.md §18) — the identity the speculative verify path
// rests on. The verify span scores every drafted position in ONE
// forward; each row must carry the exact bits the lane would have
// emitted had it decoded those tokens one forward at a time.
// ---------------------------------------------------------------------

/// A prompt plus a short teacher-forced continuation to verify.
#[derive(Clone, Debug)]
struct VerifyCase {
    prompt: Vec<u32>,
    toks: Vec<u32>,
}

impl Shrink for VerifyCase {}

fn gen_verify_case(r: &mut Rng) -> VerifyCase {
    let plen = r.usize(2, 13);
    let prompt = (0..plen).map(|_| 3 + r.usize(0, 90) as u32).collect();
    // The speculative draft depths the scheduler actually runs.
    let k = [2usize, 4, 8][r.usize(0, 3)];
    let toks = (0..k).map(|_| 3 + r.usize(0, 90) as u32).collect();
    VerifyCase { prompt, toks }
}

#[test]
fn verify_span_bitwise_equals_sequential_last_decodes() {
    for kv in kv_dtypes() {
        for &threads in &thread_counts() {
            let engine = test_engine(threads);
            let cfg = engine.config().clone();
            let v = cfg.vocab;
            check(4409 + threads as u64, 6, gen_verify_case, |case| {
                let k = case.toks.len();
                let cap = case.prompt.len() + k + 2;

                // One ragged verify span carrying all k tokens, every
                // row emitting logits.
                let mut ws = Workspace::new();
                let mut ca = KvCache::with_dtype(
                    kv, cfg.n_layers, cap, cfg.d_model);
                engine.prefill(&case.prompt, &mut ca, &mut ws).unwrap();
                let mut plan = BatchPlan::new();
                plan.push_verify_span(0, case.toks[0], &case.toks[1..]);
                let mut refs = [&mut ca];
                engine.forward_batch(&plan, &mut refs, &mut ws).unwrap();
                if plan.emitted_rows() != k {
                    return Err(format!(
                        "verify span emitted {} rows, want {k}",
                        plan.emitted_rows()));
                }
                let got = bits(&ws.logits[..k * v]);

                // The seed path: k sequential single-token Last spans.
                let mut ws2 = Workspace::new();
                let mut cb = KvCache::with_dtype(
                    kv, cfg.n_layers, cap, cfg.d_model);
                engine.prefill(&case.prompt, &mut cb, &mut ws2).unwrap();
                let mut want = Vec::new();
                for &t in &case.toks {
                    let mut plan = BatchPlan::new();
                    plan.push_span(0, std::slice::from_ref(&t),
                                   SpanLogits::Last);
                    let mut refs = [&mut cb];
                    engine.forward_batch(&plan, &mut refs, &mut ws2)
                        .unwrap();
                    want.extend(bits(&ws2.logits[..v]));
                }

                if ca.len != cb.len {
                    return Err(format!(
                        "cache lengths diverged: {} vs {} (kv {kv:?}, \
                         threads {threads}, k {k})", ca.len, cb.len));
                }
                if got != want {
                    return Err(format!(
                        "verify-span logits diverged from sequential \
                         decodes (kv {kv:?}, threads {threads}, k {k})"));
                }
                Ok(())
            });
        }
    }
}

#[test]
fn pooled_cache_without_blocks_is_kv_exhausted_not_overflow() {
    // The §13 error split: a pooled cache under its logical cap but
    // past its reserved blocks fails with the typed KvExhausted (a pool
    // condition), while exceeding `cap` stays KvOverflow (a per-
    // sequence condition) — and validation precedes any state mutation.
    let engine = test_engine(1);
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut pooled =
        KvCache::pooled(KvDtype::F32, cfg.n_layers, 16, cfg.d_model, 4);
    let mut plan = BatchPlan::new();
    plan.push_span(0, &[3, 4, 5], SpanLogits::Last);
    let mut refs = [&mut pooled];
    let err = engine.forward_batch(&plan, &mut refs, &mut ws).unwrap_err();
    assert_eq!(err,
               EngineError::KvExhausted { lane: 0, pos: 2, reserved: 0 });
    assert_eq!(pooled.len, 0, "validation must precede state mutation");
    assert!(ws.logits.is_empty());
}
