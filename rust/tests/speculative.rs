//! Self-speculative decoding determinism (DESIGN.md §18): speculation
//! is a pure perf knob — the emitted stream is the target sampler
//! stream draw by draw, so turning the draft lane on (at any draft_k,
//! any draft depth) must be **bitwise invisible** in every token
//! stream, greedy or sampled, across thread counts and KV dtypes, on
//! scripted serving fleets with staggered admission and mid-stream
//! cancellation.
//!
//! CI matrix knobs (DESIGN.md §7/§10): `MQ_TEST_THREADS` feeds an
//! extra thread count into the sweeps, `MQ_TEST_KV` restricts the
//! dtype axis.

mod common;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    GenerationParams, Request, Scheduler, SchedulerConfig,
};
use mergequant::engine::{Engine, KvDtype};
use mergequant::util::proptest::check;

use common::{drive_fleet, gen_fleet, kv_dtypes, thread_counts};

/// Paged-arena scheduler over the 2-layer synthetic bundle (2 layers so
/// `draft_layers: 1` is a true truncation). `draft_k == 0` ⇒ the plain
/// non-speculative scheduler the goldens come from.
fn sched_with(threads: usize, kv: KvDtype, draft_k: usize,
              draft_layers: usize) -> Scheduler {
    let engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 2, 96), threads);
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 8,
            kv_slabs: 0,
            kv_block: 16,
            kv_blocks: 24,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads,
            kv_dtype: kv,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: draft_k > 0,
            draft_k,
            draft_layers,
        },
    )
}

// ---------------------------------------------------------------------
// Property: greedy speculative fleets ≡ the non-speculative goldens
// ---------------------------------------------------------------------

#[test]
fn speculative_fleets_bitwise_equal_plain_fleets() {
    for kv in kv_dtypes() {
        for &threads in &thread_counts() {
            check(2707 + threads as u64, 3, gen_fleet, |trace| {
                let mut plain = sched_with(threads, kv, 0, 0);
                let golden = drive_fleet(&mut plain, trace);
                for draft_layers in [0usize, 1] {
                    for draft_k in [2usize, 4, 8] {
                        let mut sched = sched_with(
                            threads, kv, draft_k, draft_layers);
                        let got = drive_fleet(&mut sched, trace);
                        if got.len() != golden.len() {
                            return Err(format!(
                                "response count diverged: {} vs {} \
                                 (kv {kv:?}, threads {threads}, \
                                 draft_k {draft_k}, draft_layers \
                                 {draft_layers})",
                                got.len(), golden.len()));
                        }
                        for (g, w) in got.iter().zip(&golden) {
                            if g.tokens != w.tokens
                                || g.finish != w.finish
                            {
                                return Err(format!(
                                    "lane {} diverged: {:?}/{:?} vs \
                                     {:?}/{:?} (kv {kv:?}, threads \
                                     {threads}, draft_k {draft_k}, \
                                     draft_layers {draft_layers})",
                                    g.id, g.tokens, g.finish,
                                    w.tokens, w.finish));
                            }
                        }
                    }
                }
                Ok(())
            });
        }
    }
}

// ---------------------------------------------------------------------
// Seeded stochastic acceptance: replayable, and still stream-invariant
// ---------------------------------------------------------------------

/// Three sampled lanes (distinct seeds) through one scheduler; returns
/// the streams sorted by id.
fn run_sampled(mut sched: Scheduler) -> Vec<Vec<u32>> {
    for i in 0..3u64 {
        let prompt: Vec<u32> =
            (0..12).map(|t| 3 + (t * 7 + i as u32 * 11) % 90).collect();
        sched.submit(Request::with_params(i, prompt, GenerationParams {
            temperature: 0.8,
            top_k: 24,
            top_p: 0.95,
            seed: 11 + i,
            ..GenerationParams::greedy(10)
        })).unwrap();
    }
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), 3);
    for r in &rs {
        assert!(r.error.is_none(), "lane {} failed: {:?}", r.id, r.error);
    }
    rs.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn sampled_speculative_streams_are_replayable_and_invariant() {
    // The counter-based sampler draws from the target's verify rows at
    // the lane's committed step index, so a sampled speculative run is
    // (a) identical when replayed with the same seeds and (b) identical
    // to the non-speculative run of the same seeds — stochastic
    // acceptance never forks the stream.
    let golden = run_sampled(sched_with(1, KvDtype::F32, 0, 0));
    for draft_layers in [0usize, 1] {
        for draft_k in [2usize, 4, 8] {
            let a = run_sampled(
                sched_with(1, KvDtype::F32, draft_k, draft_layers));
            let b = run_sampled(
                sched_with(1, KvDtype::F32, draft_k, draft_layers));
            assert_eq!(a, b,
                       "same seeds must replay identically (draft_k \
                        {draft_k}, draft_layers {draft_layers})");
            assert_eq!(a, golden,
                       "sampling + speculation must match the plain \
                        sampled run (draft_k {draft_k}, draft_layers \
                        {draft_layers})");
        }
    }
}

// ---------------------------------------------------------------------
// Per-request opt-out + speculative metrics
// ---------------------------------------------------------------------

#[test]
fn per_request_opt_out_disables_drafting_for_that_lane() {
    let prompt: Vec<u32> = (0..12).map(|t| 3 + (t * 7) % 90).collect();

    // Opted-out lane on a speculative scheduler: no draft forwards at
    // all (it was the only lane), stream identical to the plain run.
    let mut plain = sched_with(1, KvDtype::F32, 0, 0);
    plain.submit(Request::new(0, prompt.clone(), 8)).unwrap();
    let golden = plain.run_to_completion();

    let mut sched = sched_with(1, KvDtype::F32, 4, 0);
    sched.submit(Request::with_params(0, prompt.clone(),
        GenerationParams {
            speculative: Some(false),
            ..GenerationParams::greedy(8)
        })).unwrap();
    let rs = sched.run_to_completion();
    assert_eq!(rs[0].tokens, golden[0].tokens);
    assert_eq!(sched.metrics.draft_forwards, 0,
               "an opted-out lane must never touch the draft engine");
    assert_eq!(sched.metrics.draft_proposed, 0);

    // Default (None) on the same scheduler config: the draft lane runs
    // and the full-depth self-draft is accepted wholesale.
    let mut on = sched_with(1, KvDtype::F32, 4, 0);
    on.submit(Request::new(0, prompt, 8)).unwrap();
    let rs = on.run_to_completion();
    assert_eq!(rs[0].tokens, golden[0].tokens);
    assert!(on.metrics.draft_forwards > 0);
    assert!(on.metrics.verify_forwards > 0);
    assert_eq!(on.metrics.acceptance_rate(), 1.0,
               "full-depth self-draft proposals must all verify");
    assert!(on.metrics.tokens_per_forward() > 1.0);
    let report = on.metrics.report();
    assert!(report.contains("acceptance_rate="), "{report}");
    assert!(report.contains("tokens_per_forward="), "{report}");
}

#[test]
fn replica_stats_report_speculative_kernel_and_quant_mode() {
    // The satellite observability surface: `stats()` carries the active
    // microkernel and the bundle's quant mode for the router's
    // `{"cmd":"stats"}` snapshot.
    let sched = sched_with(1, KvDtype::F32, 2, 0);
    let stats = sched.stats();
    assert!(!stats.kernel.is_empty());
    assert_eq!(stats.quant_mode, "dynamic",
               "the synthetic mergequant bundle is per-token dynamic");
}
