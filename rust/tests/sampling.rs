//! Sampling determinism: the seeded top-k/top-p sampler is a pure
//! function of (logits, seed, step), so fixed-seed token streams are
//! bitwise identical for every thread count, both KV dtypes, any batch
//! composition, and chunked vs single-shot prefill; `temperature == 0`
//! reproduces the seed greedy argmax streams exactly.
//!
//! CI matrix knobs (DESIGN.md §7/§10): `MQ_TEST_THREADS` feeds an extra
//! thread count into the sweeps, `MQ_TEST_KV` restricts the dtype axis.

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    FinishReason, GenerationParams, Request, Scheduler, SchedulerConfig,
};
use mergequant::engine::{Engine, KvDtype, Sampler};
use mergequant::util::rng::Rng;

fn thread_counts() -> Vec<usize> {
    let mut counts = vec![1, 4];
    if let Some(extra) = std::env::var("MQ_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        if extra > 0 && !counts.contains(&extra) {
            counts.push(extra);
        }
    }
    counts
}

fn kv_dtypes() -> Vec<KvDtype> {
    match std::env::var("MQ_TEST_KV").as_deref() {
        Ok("int8") => vec![KvDtype::Int8],
        Ok("f32") => vec![KvDtype::F32],
        _ => vec![KvDtype::F32, KvDtype::Int8],
    }
}

fn random_logits(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * 3.0).collect()
}

// ------------------------------------------------------------------
// Sampler unit behaviour
// ------------------------------------------------------------------

#[test]
fn temperature_zero_is_argmax_and_touches_no_rng() {
    let mut rng = Rng::new(42);
    let s = Sampler::greedy();
    assert!(s.is_greedy());
    for step in 0..64u64 {
        let logits = random_logits(&mut rng, 96);
        assert_eq!(s.sample(&logits, step) as usize,
                   Sampler::argmax(&logits));
    }
}

#[test]
fn sample_respects_top_k() {
    let mut rng = Rng::new(7);
    let s = Sampler::new(1.5, 3, 1.0, 99);
    for step in 0..256u64 {
        let logits = random_logits(&mut rng, 64);
        let tok = s.sample(&logits, step) as usize;
        let mut order: Vec<usize> = (0..64).collect();
        order.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]));
        assert!(order[..3].contains(&tok),
                "token {tok} outside top-3 at step {step}");
    }
}

#[test]
fn sample_respects_top_p() {
    // One dominant logit carries ~99.9% of the mass: any top_p below
    // that collapses the nucleus to the argmax.
    let mut logits = vec![0.0f32; 32];
    logits[5] = 10.0;
    let s = Sampler::new(1.0, 0, 0.5, 3);
    for step in 0..128u64 {
        assert_eq!(s.sample(&logits, step), 5);
    }
}

#[test]
fn sampler_is_pure_per_step_and_seed() {
    let mut rng = Rng::new(11);
    let logits = random_logits(&mut rng, 96);
    let a = Sampler::new(0.9, 20, 0.95, 1234);
    let b = Sampler::new(0.9, 20, 0.95, 1234);
    // Same (seed, step) ⇒ same draw, in any call order — the RNG is
    // counter-based, not sequential state.
    let forward: Vec<u32> = (0..32).map(|t| a.sample(&logits, t)).collect();
    let backward: Vec<u32> =
        (0..32).rev().map(|t| b.sample(&logits, t)).collect();
    assert_eq!(forward,
               backward.into_iter().rev().collect::<Vec<_>>());
}

#[test]
fn distinct_seeds_diverge_on_flat_logits() {
    // Uniform distribution over 96 tokens: two seeds agreeing on all of
    // 64 draws has probability ~96^-64.
    let logits = vec![1.0f32; 96];
    let a = Sampler::new(1.0, 0, 1.0, 1);
    let b = Sampler::new(1.0, 0, 1.0, 2);
    let sa: Vec<u32> = (0..64).map(|t| a.sample(&logits, t)).collect();
    let sb: Vec<u32> = (0..64).map(|t| b.sample(&logits, t)).collect();
    assert_ne!(sa, sb, "different seeds must give different streams");
    // And every draw is in range.
    assert!(sa.iter().all(|&t| t < 96));
}

#[test]
fn sampler_resumes_bitwise_from_any_split_point() {
    // The preemption path's resume-at-step contract: interrupt a stream
    // after k draws, rebuild the sampler from the same params, continue
    // at step k — the concatenation must equal the uninterrupted stream.
    let mut rng = Rng::new(23);
    let logits: Vec<Vec<f32>> =
        (0..20).map(|_| random_logits(&mut rng, 96)).collect();
    let full = Sampler::new(0.9, 16, 0.92, 4242);
    let golden: Vec<u32> = logits
        .iter()
        .enumerate()
        .map(|(t, l)| full.sample(l, t as u64))
        .collect();
    for split in [1usize, 7, 13, 19] {
        let first = Sampler::new(0.9, 16, 0.92, 4242);
        let second = Sampler::new(0.9, 16, 0.92, 4242);
        let mut resumed: Vec<u32> = logits[..split]
            .iter()
            .enumerate()
            .map(|(t, l)| first.sample(l, t as u64))
            .collect();
        resumed.extend(logits[split..]
            .iter()
            .enumerate()
            .map(|(i, l)| second.sample(l, (split + i) as u64)));
        assert_eq!(golden, resumed,
                   "resumed stream diverged at split {split}");
    }
}

// ------------------------------------------------------------------
// Engine-level stream determinism ({threads} × {kv})
// ------------------------------------------------------------------

#[test]
fn engine_seeded_streams_bitwise_across_threads_and_kv() {
    let prompts: Vec<Vec<u32>> = vec![
        (0..6).map(|i| 3 + i * 2).collect(),
        (0..10).map(|i| 4 + i * 3).collect(),
    ];
    let sampler = Sampler::new(0.8, 20, 0.95, 7);
    for kv in kv_dtypes() {
        let mut golden: Option<Vec<Vec<u32>>> = None;
        for &threads in &thread_counts() {
            let mut engine = Engine::with_threads(
                synthetic_model("mergequant", 64, 128, 2, 96), threads);
            if kv == KvDtype::Int8 {
                engine.ensure_kv_scales().unwrap();
            }
            let streams: Vec<Vec<u32>> = prompts
                .iter()
                .map(|p| engine
                    .generate_seeded(p, 12, 48, kv, &sampler)
                    .unwrap())
                .collect();
            match &golden {
                None => golden = Some(streams),
                Some(g) => assert_eq!(
                    g, &streams,
                    "sampled stream changed: kv {kv:?} threads {threads}"),
            }
        }
    }
}

#[test]
fn temperature_zero_matches_greedy_goldens_both_kv() {
    for kv in kv_dtypes() {
        let mut engine =
            Engine::new(synthetic_model("mergequant", 64, 128, 2, 96));
        if kv == KvDtype::Int8 {
            engine.ensure_kv_scales().unwrap();
        }
        let prompt: Vec<u32> = vec![5, 9, 13];
        let golden = engine.generate_with(&prompt, 16, 64, kv).unwrap();
        let seeded = engine
            .generate_seeded(&prompt, 16, 64, kv, &Sampler::greedy())
            .unwrap();
        assert_eq!(golden, seeded,
                   "temperature=0 must be byte-identical (kv {kv:?})");
    }
}

// ------------------------------------------------------------------
// Scheduler-level stream determinism (continuous batching)
// ------------------------------------------------------------------

/// Mixed workload: greedy, two sampled seeds, and a stop-token request.
fn workload() -> Vec<(Vec<u32>, GenerationParams)> {
    let sampled = |seed| GenerationParams {
        max_new: 10,
        temperature: 0.8,
        top_k: 24,
        top_p: 0.9,
        seed,
        ..GenerationParams::greedy(10)
    };
    vec![
        ((0..5).map(|i| 3 + i * 2).collect(), GenerationParams::greedy(10)),
        ((0..8).map(|i| 4 + i * 3).collect(), sampled(7)),
        ((0..4).map(|i| 10 + i).collect(), sampled(9)),
        ((0..6).map(|i| 5 + i * 5).collect(), GenerationParams {
            stop_tokens: vec![17, 51],
            ..sampled(11)
        }),
    ]
}

fn run_workload(threads: usize, kv: KvDtype, prefill_chunk: usize)
                -> Vec<Vec<u32>> {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 3,
            kv_slabs: 3,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk,
            threads,
            kv_dtype: kv,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    for (i, (prompt, params)) in workload().into_iter().enumerate() {
        sched
            .submit(Request::with_params(i as u64, prompt, params))
            .unwrap();
    }
    let mut responses = sched.run_to_completion();
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), 4);
    for r in &responses {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert!(r.finish == FinishReason::Length
                    || r.finish == FinishReason::Stop);
    }
    responses.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn scheduler_streams_bitwise_across_threads_kv_and_chunking() {
    for kv in kv_dtypes() {
        let mut golden: Option<Vec<Vec<u32>>> = None;
        for &threads in &thread_counts() {
            for chunk in [0usize, 3] {
                let streams = run_workload(threads, kv, chunk);
                match &golden {
                    None => golden = Some(streams),
                    Some(g) => assert_eq!(
                        g, &streams,
                        "stream changed: kv {kv:?} threads {threads} \
                         chunk {chunk}"),
                }
            }
        }
    }
}

#[test]
fn scheduler_greedy_lane_unaffected_by_sampled_neighbours() {
    // The greedy request in the mixed batch must emit the same tokens as
    // the same workload where every other lane is also greedy — sampling
    // one lane cannot perturb another (counter-based RNG, lane-local
    // logits).
    let mixed = run_workload(1, KvDtype::F32, 0);
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 3,
            kv_slabs: 3,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 16,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    for (i, (prompt, _)) in workload().into_iter().enumerate() {
        sched
            .submit(Request::new(i as u64, prompt, 10))
            .unwrap();
    }
    let mut all_greedy = sched.run_to_completion();
    all_greedy.sort_by_key(|r| r.id);
    assert_eq!(mixed[0], all_greedy[0].tokens,
               "greedy lane must not depend on neighbour sampling");
}
