//! Router-tier replay suite (DESIGN.md §16): routing decides placement,
//! never stream content. Pins the acceptance properties of the
//! replica-sharded front door:
//!
//!   (a) every routed stream is bitwise identical to a standalone
//!       server run of the same request, across the {threads} ×
//!       {kv dtype} determinism matrix;
//!   (b) session-affinity turns land on the pinned replica and hit its
//!       warm prefix blocks; with affinity off nothing is pinned;
//!   (c) a mid-fleet drain completes in-flight streams bitwise-intact
//!       while the router keeps admitting, then tears the replica down
//!       and respawns it with clean block accounting
//!       (`kv_available + prefix_cached_blocks == kv_capacity`).

mod common;

use std::sync::Arc;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    Event, FinishReason, GenerationParams, Router, RouterConfig,
    SchedulerConfig, Server,
};
use mergequant::engine::{Engine, KvDtype};

fn replica_engine() -> Engine {
    Engine::new(synthetic_model("mergequant", 64, 128, 1, 96))
}

/// Whole-box scheduler settings; the router splits the 48-block arena
/// across the fleet (`RouterConfig::per_replica`).
fn whole_box(threads: usize, kv: KvDtype, prefix: bool)
             -> SchedulerConfig {
    SchedulerConfig {
        max_batch: 4,
        kv_slabs: 0,
        kv_block: 16,
        kv_blocks: 48,
        max_seq: 96,
        max_prefills_per_iter: 2,
        queue_cap: 64,
        prefill_chunk: 0,
        threads,
        kv_dtype: kv,
        prefix_cache: prefix,
        prefix_cache_blocks: 0,
        max_decode_latency: 0,
        speculative: false,
        draft_k: 0,
        draft_layers: 0,
    }
}

fn router_with(replicas: usize, cfg: SchedulerConfig) -> Arc<Router> {
    Arc::new(Router::start(RouterConfig::new(replicas, cfg),
                           |_i| replica_engine()))
}

#[test]
fn streams_are_bitwise_identical_to_standalone() {
    for threads in common::thread_counts() {
        for kv in common::kv_dtypes() {
            let cfg = whole_box(threads, kv, true);
            let per = RouterConfig::new(3, cfg.clone()).per_replica();
            let standalone = Server::start(replica_engine(), per);
            let router = router_with(3, cfg);
            for (i, seed) in [0u64, 7, 11, 0].into_iter().enumerate() {
                let prompt: Vec<u32> = (0..10 + i)
                    .map(|t| 3 + (t as u32 * 7 + i as u32) % 90)
                    .collect();
                let mut params = GenerationParams::greedy(6);
                params.session = Some(format!("s{i}"));
                if seed > 0 {
                    params.temperature = 0.8;
                    params.top_k = 16;
                    params.top_p = 0.9;
                    params.seed = seed;
                }
                let golden = standalone
                    .generate(prompt.clone(), params.clone())
                    .unwrap()
                    .wait();
                let routed =
                    router.generate(prompt, params).unwrap().wait();
                assert!(golden.error.is_none());
                assert_eq!(routed.tokens, golden.tokens,
                           "threads={threads} kv={kv:?} req={i}");
                assert_eq!(routed.finish, golden.finish);
            }
            standalone.shutdown();
            router.shutdown();
        }
    }
}

#[test]
fn affinity_pins_sessions_to_warm_replicas() {
    const SESSIONS: usize = 4;
    const TURNS: usize = 3;
    // Multi-turn chats: each turn's prompt is the previous prompt plus
    // the previous completion plus fresh user tokens. Base prompts
    // start on distinct tokens so every prefix hit is same-session.
    let run = |affinity: bool| -> (Arc<Router>, u64, u64) {
        let mut cfg =
            RouterConfig::new(2, whole_box(1, KvDtype::F32, true));
        cfg.affinity = affinity;
        let router =
            Arc::new(Router::start(cfg, |_i| replica_engine()));
        let mut prompts: Vec<Vec<u32>> = (0..SESSIONS)
            .map(|s| {
                (0..32)
                    .map(|j| 3 + ((s * 31 + j * 7) % 89) as u32)
                    .collect()
            })
            .collect();
        let mut pins: Vec<Option<usize>> = vec![None; SESSIONS];
        for turn in 0..TURNS {
            for (s, prompt) in prompts.iter_mut().enumerate() {
                if turn > 0 {
                    prompt.extend((0..6).map(|j| {
                        5 + ((s * 13 + turn * 17 + j * 5) % 89) as u32
                    }));
                }
                let sid = format!("chat-{s}");
                let mut params = GenerationParams::greedy(4);
                params.session = Some(sid.clone());
                let resp = router
                    .generate(prompt.clone(), params)
                    .unwrap()
                    .wait();
                assert!(resp.error.is_none());
                prompt.extend(&resp.tokens);
                if affinity {
                    let pin = router.session_replica(&sid);
                    assert!(pin.is_some(), "session must stay pinned");
                    match pins[s] {
                        None => pins[s] = pin,
                        Some(first) => assert_eq!(
                            pin, Some(first),
                            "pin must be stable across turns"),
                    }
                } else {
                    assert_eq!(router.session_replica(&sid), None,
                               "affinity off must pin nothing");
                }
            }
        }
        let (mut hits, mut lookups) = (0u64, 0u64);
        for st in router.stats() {
            hits += st.prefix_hits;
            lookups += st.prefix_lookups;
        }
        (router, hits, lookups)
    };

    let (pinned, warm_hits, warm_lookups) = run(true);
    let m = pinned.metrics();
    assert_eq!(m.affinity_hits as usize, SESSIONS * (TURNS - 1));
    assert_eq!(m.affinity_misses as usize, SESSIONS);
    assert_eq!(m.rerouted, 0);
    assert_eq!(warm_lookups as usize, SESSIONS * TURNS);
    assert_eq!(warm_hits as usize, SESSIONS * (TURNS - 1),
               "every pinned turn must land on warm prefix blocks");

    let (shuffled, cold_hits, cold_lookups) = run(false);
    assert_eq!(shuffled.metrics().affinity_hits, 0);
    assert_eq!(cold_lookups, warm_lookups);
    assert!(cold_hits <= warm_hits,
            "least-loaded dispatch cannot beat session pinning");

    pinned.shutdown();
    shuffled.shutdown();
}

#[test]
fn drain_mid_fleet_completes_streams_and_respawns_clean() {
    let mut cfg = whole_box(1, KvDtype::F32, true);
    // Long runway for the holder lane: it keeps the draining replica
    // busy for thousands of decode steps and is cancelled at the end,
    // so the drain choreography below never races its completion
    // (same construction as the queue_full backpressure test).
    cfg.max_seq = 4096;
    let per = RouterConfig::new(2, cfg.clone()).per_replica();
    // Golden stream from a standalone server with the identical
    // per-replica config. The routed copy decodes batched next to the
    // long holder lane — batch composition must not change it.
    let gold_prompt: Vec<u32> =
        (0..12).map(|t| 9 + (t * 7) % 80).collect();
    let standalone = Server::start(replica_engine(), per);
    let golden = standalone
        .generate(gold_prompt.clone(), GenerationParams::greedy(24))
        .unwrap()
        .wait();
    assert!(golden.error.is_none());
    standalone.shutdown();

    let router = router_with(2, cfg);
    // A long-running holder lane keeps its replica busy for the whole
    // drain window (cancelled at the end, so no timing races).
    let mut hold_params = GenerationParams::greedy(100_000);
    hold_params.session = Some("drain-me".into());
    let holder = router
        .generate(vec![3, 4, 5], hold_params)
        .unwrap();
    assert!(matches!(holder.recv(), Some(Event::Token { .. })));
    let victim = router.session_replica("drain-me").expect("pinned");

    // The golden copy rides the same session, hence the same replica.
    let mut gold_params = GenerationParams::greedy(24);
    gold_params.session = Some("drain-me".into());
    let routed = router
        .generate(gold_prompt.clone(), gold_params)
        .unwrap();

    router.drain(victim).expect("drain accepted");
    assert_eq!(router.poll_drains(), 1,
               "in-flight work keeps the replica draining");
    // Error paths while the drain is in progress:
    let again = router.drain(victim).unwrap_err();
    assert!(again.contains("already draining"), "{again}");
    let last = router.drain(1 - victim).unwrap_err();
    assert!(last.contains("last live replica"), "{last}");
    let bogus = router.drain(9).unwrap_err();
    assert!(bogus.contains("no replica"), "{bogus}");

    // The router keeps admitting throughout the drain — new work lands
    // on the other replica.
    let side = router
        .generate(vec![40, 41, 42], GenerationParams::greedy(4))
        .unwrap()
        .wait();
    assert!(side.error.is_none());
    assert_eq!(side.tokens.len(), 4);
    assert!(router.stats()[victim].draining);

    // The in-flight stream survives the drain bitwise-intact.
    let resp = routed.wait();
    assert!(resp.error.is_none());
    assert_eq!(resp.tokens, golden.tokens,
               "drain must never alter an in-flight stream");

    // Release the holder; the replica runs idle, tears down, respawns.
    holder.cancel();
    assert_eq!(holder.wait().finish, FinishReason::Cancelled);
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(10);
    while router.poll_drains() > 0 {
        assert!(std::time::Instant::now() < deadline, "drain stuck");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let m = router.metrics();
    assert_eq!(m.drains, 1);
    assert_eq!(m.respawns, 1);
    let stats = router.stats();
    for st in &stats {
        assert!(!st.draining);
        assert_eq!(st.kv_available + st.prefix_cached_blocks,
                   st.kv_capacity,
                   "replica {} leaks blocks", st.replica);
    }
    assert_eq!(stats[victim].requests_completed, 0,
               "respawned replica starts fresh");

    // The stale session pin re-routes instead of erroring.
    let mut stale = GenerationParams::greedy(4);
    stale.session = Some("drain-me".into());
    let r2 = router.generate(gold_prompt, stale).unwrap().wait();
    assert!(r2.error.is_none());
    assert_eq!(router.metrics().rerouted, 1);
    router.shutdown();
}

#[test]
fn drain_refused_on_single_replica_fleet() {
    let router = router_with(1, whole_box(1, KvDtype::F32, false));
    let err = router.drain(0).unwrap_err();
    assert!(err.contains("last live replica"), "{err}");
    // The fleet still serves after the refusal.
    let resp = router
        .generate(vec![3, 9, 12], GenerationParams::greedy(3))
        .unwrap()
        .wait();
    assert_eq!(resp.tokens.len(), 3);
    router.shutdown();
}
