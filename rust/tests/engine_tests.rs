//! Engine integration tests on synthetic bundles (no artifacts needed):
//! prefill/decode parity, causality, batching consistency, generation.

use mergequant::bench::synthetic_model;
use mergequant::engine::{
    Engine, EngineError, KvCache, KvDtype, Sampler, Workspace,
};

fn engines() -> Vec<(&'static str, Engine)> {
    ["fp16", "mergequant", "rtn", "quarot"]
        .into_iter()
        .map(|m| (m, Engine::new(synthetic_model(m, 64, 128, 2, 96))))
        .collect()
}

#[test]
fn decode_matches_prefill_all_modes() {
    for (name, engine) in engines() {
        let cfg = engine.config().clone();
        let toks: Vec<u32> = (0..12).map(|i| 3 + (i * 7) % 90).collect();
        let mut ws = Workspace::new();
        let mut cache = KvCache::new(cfg.n_layers, 16, cfg.d_model);
        engine.prefill(&toks, &mut cache, &mut ws).unwrap();
        let full = ws.logits.clone();

        let mut cache2 = KvCache::new(cfg.n_layers, 16, cfg.d_model);
        let mut ws2 = Workspace::new();
        // prefill first token only, then decode the rest step by step
        engine.prefill(&toks[..1], &mut cache2, &mut ws2).unwrap();
        let mut got = ws2.logits[..cfg.vocab].to_vec();
        let mut rows = vec![got.clone()];
        for t in 1..toks.len() {
            let tok = [toks[t]];
            let mut caches = [&mut cache2];
            engine.decode_batch(&tok, &mut caches, &mut ws2).unwrap();
            got = ws2.logits[..cfg.vocab].to_vec();
            rows.push(got.clone());
        }
        for (pos, row) in rows.iter().enumerate() {
            let want = &full[pos * cfg.vocab..(pos + 1) * cfg.vocab];
            for (a, b) in row.iter().zip(want) {
                assert!((a - b).abs() < 2e-3,
                        "{name} pos {pos}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn batched_decode_matches_single() {
    for (name, engine) in engines() {
        let cfg = engine.config().clone();
        let prompts: Vec<Vec<u32>> = vec![
            (0..5).map(|i| 3 + i * 2).collect(),
            (0..9).map(|i| 4 + i * 3).collect(),
            (0..3).map(|i| 10 + i).collect(),
        ];
        // single-sequence decode results
        let mut singles = Vec::new();
        for p in &prompts {
            let mut ws = Workspace::new();
            let mut cache = KvCache::new(cfg.n_layers, 32, cfg.d_model);
            engine.prefill(p, &mut cache, &mut ws).unwrap();
            let next = [7u32];
            let mut caches = [&mut cache];
            engine.decode_batch(&next, &mut caches, &mut ws).unwrap();
            singles.push(ws.logits[..cfg.vocab].to_vec());
        }
        // batched decode over all three at once
        let mut ws = Workspace::new();
        let mut caches: Vec<KvCache> = prompts
            .iter()
            .map(|p| {
                let mut c = KvCache::new(cfg.n_layers, 32, cfg.d_model);
                engine.prefill(p, &mut c, &mut ws).unwrap();
                c
            })
            .collect();
        let toks = vec![7u32; 3];
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        engine.decode_batch(&toks, &mut refs, &mut ws).unwrap();
        for (i, single) in singles.iter().enumerate() {
            let row = &ws.logits[i * cfg.vocab..(i + 1) * cfg.vocab];
            for (a, b) in row.iter().zip(single) {
                assert!((a - b).abs() < 1e-4,
                        "{name} seq {i} batched != single");
            }
        }
    }
}

#[test]
fn causality_future_token_does_not_change_past() {
    for (name, engine) in engines() {
        let cfg = engine.config().clone();
        let mut toks: Vec<u32> = (0..10).map(|i| 3 + i * 5).collect();
        let mut ws = Workspace::new();
        let mut cache = KvCache::new(cfg.n_layers, 16, cfg.d_model);
        engine.prefill(&toks, &mut cache, &mut ws).unwrap();
        let before = ws.logits[..9 * cfg.vocab].to_vec();
        toks[9] = 88;
        cache.reset();
        engine.prefill(&toks, &mut cache, &mut ws).unwrap();
        let after = &ws.logits[..9 * cfg.vocab];
        for (a, b) in before.iter().zip(after) {
            assert!((a - b).abs() < 1e-5, "{name} causality violated");
        }
    }
}

#[test]
fn generate_is_deterministic_and_bounded() {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 2, 96));
    let prompt: Vec<u32> = vec![5, 9, 13];
    let a = engine.generate(&prompt, 16, 64).unwrap();
    let b = engine.generate(&prompt, 16, 64).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.len(), 16);
    assert!(a.iter().all(|&t| (t as usize) < 96));
}

#[test]
fn seeded_greedy_sampler_matches_generate_goldens() {
    // temperature == 0 is the greedy special case of the v2 sampler: it
    // must reproduce `Engine::generate`'s token streams byte for byte,
    // for every quantization method.
    for (name, engine) in engines() {
        let prompt: Vec<u32> = vec![5, 9, 13];
        let golden = engine.generate(&prompt, 16, 64).unwrap();
        let seeded = engine
            .generate_seeded(&prompt, 16, 64, KvDtype::F32,
                             &Sampler::greedy())
            .unwrap();
        assert_eq!(golden, seeded, "{name}: seeded greedy diverged");
    }
}

#[test]
fn static_path_output_is_finite_with_outliers() {
    // Feed extreme token embeddings through the quantized path.
    let engine = Engine::new(synthetic_model("mergequant", 128, 256, 2, 96));
    let cfg = engine.config().clone();
    let toks: Vec<u32> = (0..8).map(|i| i % 96).collect();
    let mut ws = Workspace::new();
    let mut cache = KvCache::new(cfg.n_layers, 8, cfg.d_model);
    engine.prefill(&toks, &mut cache, &mut ws).unwrap();
    assert!(ws.logits.iter().all(|v| v.is_finite()));
}

#[test]
fn kv_cache_overflow_is_typed_error_not_panic() {
    let engine = Engine::new(synthetic_model("fp16", 64, 128, 1, 96));
    let cfg = engine.config().clone();
    let toks: Vec<u32> = (0..9).collect();
    let mut ws = Workspace::new();
    let mut cache = KvCache::new(cfg.n_layers, 8, cfg.d_model);
    let err = engine.prefill(&toks, &mut cache, &mut ws).unwrap_err();
    assert_eq!(err, EngineError::KvOverflow { lane: 0, pos: 8, cap: 8 });
    // Validation happens before any state is touched.
    assert_eq!(cache.len, 0, "failed prefill must not advance the cache");
    // The cache remains usable after the error.
    engine.prefill(&toks[..8], &mut cache, &mut ws).unwrap();
    assert_eq!(cache.len, 8);
}

#[test]
fn decode_overflow_names_the_offending_lane() {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut big = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    let mut small = KvCache::new(cfg.n_layers, 4, cfg.d_model);
    engine.prefill(&[3, 4, 5], &mut big, &mut ws).unwrap();
    engine.prefill(&[3, 4, 5, 6], &mut small, &mut ws).unwrap();
    let toks = [7u32, 8u32];
    let mut caches = [&mut big, &mut small];
    let err = engine.decode_batch(&toks, &mut caches, &mut ws).unwrap_err();
    assert_eq!(err, EngineError::KvOverflow { lane: 1, pos: 4, cap: 4 });
    // Neither lane advanced — the batch can be retried without lane 1.
    assert_eq!(big.len, 3);
    assert_eq!(small.len, 4);
    let toks = [7u32];
    let mut caches = [&mut big];
    engine.decode_batch(&toks, &mut caches, &mut ws).unwrap();
    assert_eq!(big.len, 4);
}

#[test]
fn workspace_reuse_no_state_leak() {
    let engine = Engine::new(synthetic_model("rtn", 64, 128, 2, 96));
    let cfg = engine.config().clone();
    let toks: Vec<u32> = (0..6).collect();
    let mut ws = Workspace::new();
    let mut c1 = KvCache::new(cfg.n_layers, 8, cfg.d_model);
    engine.prefill(&toks, &mut c1, &mut ws).unwrap();
    let first = ws.logits.clone();
    // run something else through the same workspace
    let other: Vec<u32> = (10..18).collect();
    let mut c2 = KvCache::new(cfg.n_layers, 8, cfg.d_model);
    engine.prefill(&other, &mut c2, &mut ws).unwrap();
    // then repeat the original
    let mut c3 = KvCache::new(cfg.n_layers, 8, cfg.d_model);
    engine.prefill(&toks, &mut c3, &mut ws).unwrap();
    for (a, b) in first.iter().zip(&ws.logits) {
        assert_eq!(a, b, "workspace reuse changed results");
    }
}

#[test]
fn chunked_prefill_matches_single_shot() {
    // Both-dtype, multi-chunk-size, bitwise chunked-equivalence lives in
    // tests/kv_quant.rs; this keeps the original f32 smoke variant.
    for (name, engine) in engines() {
        let cfg = engine.config().clone();
        let toks: Vec<u32> = (0..20).map(|i| 3 + (i * 5) % 90).collect();
        let mut ws = Workspace::new();
        let mut cache = KvCache::new(cfg.n_layers, 24, cfg.d_model);
        engine.prefill(&toks, &mut cache, &mut ws).unwrap();
        let last = ws.logits[19 * cfg.vocab..20 * cfg.vocab].to_vec();

        // same prompt in three chunks continuing the same cache
        let mut cache2 = KvCache::new(cfg.n_layers, 24, cfg.d_model);
        let mut ws2 = Workspace::new();
        for chunk in [&toks[..7], &toks[7..13], &toks[13..]] {
            engine.prefill(chunk, &mut cache2, &mut ws2).unwrap();
        }
        assert_eq!(cache2.len, 20);
        let got = &ws2.logits[6 * cfg.vocab..7 * cfg.vocab];
        for (a, b) in got.iter().zip(&last) {
            assert!((a - b).abs() < 2e-3, "{name}: chunked prefill mismatch");
        }
    }
}

#[test]
fn multi_turn_cache_reuse() {
    // prefill prompt, decode a bit, then append a second "user turn" via
    // prefill-continue — logits must match a from-scratch run.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 2, 96));
    let cfg = engine.config().clone();
    let turn1: Vec<u32> = vec![3, 9, 12, 40];
    let turn2: Vec<u32> = vec![55, 61, 7];

    let mut ws = Workspace::new();
    let mut cache = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    engine.prefill(&turn1, &mut cache, &mut ws).unwrap();
    engine.prefill(&turn2, &mut cache, &mut ws).unwrap();
    let reused = ws.logits[2 * cfg.vocab..3 * cfg.vocab].to_vec();

    let mut full: Vec<u32> = turn1.clone();
    full.extend(&turn2);
    let mut cache2 = KvCache::new(cfg.n_layers, 16, cfg.d_model);
    engine.prefill(&full, &mut cache2, &mut ws).unwrap();
    let scratch = &ws.logits[6 * cfg.vocab..7 * cfg.vocab];
    for (a, b) in reused.iter().zip(scratch) {
        assert!((a - b).abs() < 2e-3, "multi-turn reuse mismatch");
    }
}
