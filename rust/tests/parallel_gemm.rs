//! Determinism properties of the parallel execution subsystem
//! (DESIGN.md §7): every tiled multi-threaded kernel must be **bitwise
//! identical** to its serial counterpart across random shapes — ragged
//! m/n/j not divisible by the tile sizes included — and thread counts
//! 1–8. This is the guarantee that lets `tests/artifact_parity.rs` and
//! the golden tests hold regardless of the configured parallelism.

use mergequant::bench::synthetic_model;
use mergequant::engine::{Engine, KvCache, Workspace};
use mergequant::quant::gemm::{
    epilogue_asym, epilogue_sym, gemm_f32, gemm_i8, gemm_i8_packed4,
    rowsum_i8, PACKED_MIN_ROWS,
};
use mergequant::quant::pack::pack_int4;
use mergequant::quant::parallel::{
    par_gemm_f32, par_gemm_i8, par_gemm_i8_packed4, par_qlinear,
    ThreadPool, PAR_MIN_MACS,
};
use mergequant::util::rng::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn rand_i8(rng: &mut Rng, len: usize) -> Vec<i8> {
    (0..len).map(|_| rng.usize(0, 15) as i8 - 7).collect()
}

/// Random shape large enough that the parallel path actually engages
/// (m·n·j ≥ PAR_MIN_MACS), ragged w.r.t. the 32-row / 8..64-column tiles.
fn par_shape(rng: &mut Rng) -> (usize, usize, usize) {
    loop {
        let m = rng.usize(16, 49);
        let n = rng.usize(64, 161);
        let j = rng.usize(65, 161);
        if m * n * j >= PAR_MIN_MACS {
            return (m, n, j);
        }
    }
}

/// Small ragged shapes exercise the serial fallback inside the par_*
/// entry points (trivially identical, but keeps the API contract honest).
fn small_shape(rng: &mut Rng) -> (usize, usize, usize) {
    (rng.usize(1, 9), rng.usize(1, 70), rng.usize(1, 40))
}

#[test]
fn par_gemm_f32_bitwise_identical_for_threads_1_to_8() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..6 {
        let (m, n, j) =
            if case < 4 { par_shape(&mut rng) } else { small_shape(&mut rng) };
        let x: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let wt: Vec<f32> = (0..j * n).map(|_| rng.normal()).collect();
        let mut want = vec![0f32; m * j];
        gemm_f32(&x, &wt, m, n, j, &mut want);
        for th in 1..=8 {
            let pool = ThreadPool::new(th);
            let mut got = vec![0f32; m * j];
            par_gemm_f32(&pool, &x, &wt, m, n, j, &mut got);
            assert_eq!(bits(&got), bits(&want),
                       "case {case}: m{m} n{n} j{j} threads {th}");
        }
    }
}

#[test]
fn par_gemm_i8_exact_for_threads_1_to_8() {
    let mut rng = Rng::new(0xBEE);
    for case in 0..6 {
        let (m, n, j) =
            if case < 4 { par_shape(&mut rng) } else { small_shape(&mut rng) };
        let xq = rand_i8(&mut rng, m * n);
        let wt = rand_i8(&mut rng, j * n);
        let mut want = vec![0i32; m * j];
        gemm_i8(&xq, &wt, m, n, j, &mut want);
        for th in 1..=8 {
            let pool = ThreadPool::new(th);
            let mut got = vec![0i32; m * j];
            par_gemm_i8(&pool, &xq, &wt, m, n, j, &mut got);
            assert_eq!(got, want, "case {case}: m{m} n{n} j{j} threads {th}");
        }
    }
}

#[test]
fn par_gemm_packed4_matches_serial_for_threads_1_to_8() {
    let mut rng = Rng::new(0xCAB);
    for case in 0..5 {
        let (m, n, j) =
            if case < 3 { par_shape(&mut rng) } else { small_shape(&mut rng) };
        let xq = rand_i8(&mut rng, m * n);
        let wt = rand_i8(&mut rng, j * n);
        let mut packed = Vec::new();
        for c in 0..j {
            packed.extend(pack_int4(&wt[c * n..(c + 1) * n]));
        }
        let mut scratch = Vec::new();
        let mut want = vec![0i32; m * j];
        gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch, &mut want);
        for th in 1..=8 {
            let pool = ThreadPool::new(th);
            let mut got = vec![0i32; m * j];
            par_gemm_i8_packed4(&pool, &xq, &packed, m, n, j, &mut scratch,
                                &mut got);
            assert_eq!(got, want, "case {case}: m{m} n{n} j{j} threads {th}");
        }
    }
}

#[test]
fn fused_qlinear_bitwise_matches_gemm_plus_epilogue() {
    // The engine's hot path: fused GEMM + in-tile epilogue vs the
    // unfused serial chain, symmetric and asymmetric, with and without
    // row scales, across thread counts.
    let mut rng = Rng::new(0xD1CE);
    for case in 0..5 {
        let (m, n, j) =
            if case < 3 { par_shape(&mut rng) } else { small_shape(&mut rng) };
        let xq = rand_i8(&mut rng, m * n);
        let wt = rand_i8(&mut rng, j * n);
        let mut packed = Vec::new();
        for c in 0..j {
            packed.extend(pack_int4(&wt[c * n..(c + 1) * n]));
        }
        let col_scale: Vec<f32> =
            (0..j).map(|_| 0.01 + rng.f32() * 0.05).collect();
        let row_scale: Vec<f32> = (0..m).map(|_| 0.5 + rng.f32()).collect();
        let zero: Vec<i32> =
            (0..j).map(|_| rng.usize(0, 5) as i32 - 2).collect();

        // Serial reference: the pre-fusion engine sequence.
        let mut acc = vec![0i32; m * j];
        let mut scratch = Vec::new();
        if m >= PACKED_MIN_ROWS {
            gemm_i8_packed4(&xq, &packed, m, n, j, &mut scratch, &mut acc);
        } else {
            gemm_i8(&xq, &wt, m, n, j, &mut acc);
        }
        let mut rsum = Vec::new();
        rowsum_i8(&xq, m, n, &mut rsum);
        let mut want_sym = vec![0f32; m * j];
        epilogue_sym(&acc, &col_scale, None, m, j, &mut want_sym);
        let mut want_asym = vec![0f32; m * j];
        epilogue_asym(&acc, &rsum, &zero, &col_scale, Some(&row_scale), m,
                      j, &mut want_asym);

        for th in 1..=8 {
            let pool = ThreadPool::new(th);
            let mut got = vec![0f32; m * j];
            par_qlinear(&pool, &xq, &wt, Some(&packed), m, n, j, &col_scale,
                        None, None, None, &mut scratch, &mut got);
            assert_eq!(bits(&got), bits(&want_sym),
                       "sym case {case}: m{m} n{n} j{j} threads {th}");
            let mut got2 = vec![0f32; m * j];
            par_qlinear(&pool, &xq, &wt, Some(&packed), m, n, j, &col_scale,
                        Some(&zero), Some(&rsum), Some(&row_scale),
                        &mut scratch, &mut got2);
            assert_eq!(bits(&got2), bits(&want_asym),
                       "asym case {case}: m{m} n{n} j{j} threads {th}");
        }
    }
}

#[test]
fn engine_forward_bitwise_identical_across_thread_counts() {
    // End-to-end: prefill + batched decode on the full quantized engine
    // must produce bit-identical logits for 1, 3 and 6 threads (this is
    // what keeps goldens/artifact parity valid under parallel serving).
    let model = synthetic_model("mergequant", 128, 256, 2, 256);
    let prompt: Vec<u32> = (0..48).map(|i| 3 + (i * 7) % 250).collect();
    let cfg = model.config.clone();

    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    // CI's {threads} matrix feeds an extra count into the sweep.
    let mut counts = vec![1usize, 3, 6];
    if let Some(t) = std::env::var("MQ_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
    {
        counts.push(std::cmp::max(t, 1));
    }
    for threads in counts {
        let engine = Engine::with_threads(model.clone(), threads);
        assert_eq!(engine.threads(), threads);
        let mut ws = Workspace::new();

        // prefill logits
        let mut caches: Vec<KvCache> = (0..3)
            .map(|_| KvCache::new(cfg.n_layers, 96, cfg.d_model))
            .collect();
        engine.prefill(&prompt, &mut caches[0], &mut ws).unwrap();
        let prefill_bits = bits(&ws.logits[..prompt.len() * cfg.vocab]);

        // batched decode logits (3 lanes, staggered cache lengths)
        engine.prefill(&prompt[..20], &mut caches[1], &mut ws).unwrap();
        engine.prefill(&prompt[..33], &mut caches[2], &mut ws).unwrap();
        let mut decode_bits = Vec::new();
        let mut toks = [5u32, 9, 11];
        for _ in 0..4 {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            engine.decode_batch(&toks, &mut refs, &mut ws).unwrap();
            decode_bits.extend(bits(&ws.logits[..3 * cfg.vocab]));
            for (i, t) in toks.iter_mut().enumerate() {
                *t = mergequant::engine::Sampler::argmax(
                    &ws.logits[i * cfg.vocab..(i + 1) * cfg.vocab],
                ) as u32;
            }
        }

        match &reference {
            None => reference = Some((prefill_bits, decode_bits)),
            Some((pref, dec)) => {
                assert_eq!(&prefill_bits, pref,
                           "prefill logits differ at {threads} threads");
                assert_eq!(&decode_bits, dec,
                           "decode logits differ at {threads} threads");
            }
        }
    }
}

#[test]
fn dynamic_baseline_engine_also_thread_invariant() {
    // The dynamic-quant baselines share the fused kernel path (per-row
    // scales + hadamard variants) — they must be deterministic too.
    let model = synthetic_model("quarot", 128, 256, 1, 192);
    let prompt: Vec<u32> = (0..40).map(|i| 3 + (i * 5) % 180).collect();
    let cfg = model.config.clone();
    let mut want: Option<Vec<u32>> = None;
    for threads in [1usize, 4] {
        let engine = Engine::with_threads(model.clone(), threads);
        let mut ws = Workspace::new();
        let mut cache = KvCache::new(cfg.n_layers, 64, cfg.d_model);
        engine.prefill(&prompt, &mut cache, &mut ws).unwrap();
        let got = bits(&ws.logits[..prompt.len() * cfg.vocab]);
        match &want {
            None => want = Some(got),
            Some(w) => assert_eq!(&got, w,
                                  "quarot logits differ at {threads} threads"),
        }
    }
}
