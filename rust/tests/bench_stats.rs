//! Coverage for the measurement substrate itself (the harness every
//! paper table rides on): percentile math in `util/stats.rs`,
//! adaptive-iteration stopping, and the JSONL records `src/bench/mod.rs`
//! persists — round-tripped through `util/json.rs`.

use std::time::Duration;

use mergequant::bench::Bench;
use mergequant::util::json::Json;
use mergequant::util::stats::{summarize, time_adaptive, time_iters};

// ---------------------------------------------------------------------
// Percentile math
// ---------------------------------------------------------------------

#[test]
fn percentiles_on_known_distribution() {
    // 1..=100 — nearest-rank on (p·(n−1)).round() indices.
    let xs: Vec<f64> = (1..=100).map(|v| v as f64).collect();
    let s = summarize(&xs);
    assert_eq!(s.n, 100);
    assert!((s.mean - 50.5).abs() < 1e-12);
    assert_eq!(s.min, 1.0);
    assert_eq!(s.max, 100.0);
    assert_eq!(s.p50, 51.0); // (0.5·99).round() = 50 → xs[50] = 51
    assert_eq!(s.p90, 90.0); // (0.9·99).round() = 89 → 90
    assert_eq!(s.p99, 99.0); // (0.99·99).round() = 98 → 99
    // std of a discrete uniform over 1..100: sqrt((n²−1)/12) ≈ 28.866
    assert!((s.std - 28.866).abs() < 0.01, "std {}", s.std);
}

#[test]
fn percentiles_sort_unordered_input() {
    let s = summarize(&[5.0, 1.0, 4.0, 2.0, 3.0]);
    assert_eq!(s.min, 1.0);
    assert_eq!(s.p50, 3.0);
    assert_eq!(s.max, 5.0);
}

#[test]
fn percentiles_degenerate_sizes() {
    let one = summarize(&[7.5]);
    assert_eq!((one.n, one.p50, one.p90, one.p99), (1, 7.5, 7.5, 7.5));
    assert_eq!(one.std, 0.0);
    let two = summarize(&[2.0, 4.0]);
    assert_eq!(two.p50, 4.0); // (0.5·1).round() = 1 (round half away)
    assert_eq!(two.p90, 4.0);
    assert_eq!(two.min, 2.0);
    assert_eq!(summarize(&[]).n, 0);
}

// ---------------------------------------------------------------------
// Adaptive-iteration stopping
// ---------------------------------------------------------------------

#[test]
fn adaptive_runs_at_least_three_iterations() {
    let mut count = 0usize;
    let ts = time_adaptive(Duration::ZERO, 100, || count += 1);
    assert_eq!(ts.len(), 3, "min_time elapsed ⇒ floor of 3 measured iters");
    assert_eq!(count, 4, "one unmeasured warmup + 3 measured");
}

#[test]
fn adaptive_stops_at_max_iters_even_under_min_time() {
    let mut count = 0usize;
    let ts = time_adaptive(Duration::from_secs(3600), 7, || count += 1);
    assert_eq!(ts.len(), 7, "max_iters caps the run");
    assert_eq!(count, 8);
    assert!(ts.iter().all(|t| *t >= 0.0));
}

#[test]
fn adaptive_runs_until_min_time() {
    // A ~1ms body against a 20ms budget must run well past the 3-iter
    // floor and stop before the 10_000 cap.
    let ts = time_adaptive(Duration::from_millis(20), 10_000, || {
        std::thread::sleep(Duration::from_millis(1));
    });
    assert!(ts.len() > 3 && ts.len() < 10_000, "n = {}", ts.len());
}

#[test]
fn fixed_iters_counts_warmup_separately() {
    let mut count = 0usize;
    let ts = time_iters(3, 6, || count += 1);
    assert_eq!(ts.len(), 6);
    assert_eq!(count, 9);
}

// ---------------------------------------------------------------------
// JSONL records round-trip through util/json.rs
// ---------------------------------------------------------------------

#[test]
fn bench_jsonl_records_roundtrip() {
    // Point the artifacts tree at a scratch dir so `Bench::finish`
    // appends there, then parse every line back.
    let dir = std::env::temp_dir()
        .join(format!("mq_bench_jsonl_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::env::set_var("MERGEQUANT_ARTIFACTS", &dir);

    let mut b = Bench::new("jsonl_roundtrip");
    b.record("kv int8 reduction_factor", 4.0);
    b.record("negative value", -3.25);
    b.measure("noop \"quoted\" label", || {});
    b.finish("round-trip fixture");

    let path = dir.join("bench_results.jsonl");
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> =
        text.lines().filter(|l| !l.trim().is_empty()).collect();
    assert_eq!(lines.len(), 3, "one JSONL record per row");
    let rows: Vec<Json> =
        lines.iter().map(|l| Json::parse(l).unwrap()).collect();
    for r in &rows {
        assert_eq!(r.req_str("bench").unwrap(), "jsonl_roundtrip");
        assert!(r.get("label").is_some() && r.get("mean_s").is_some()
                && r.get("n").is_some());
    }
    assert_eq!(rows[0].req_str("label").unwrap(),
               "kv int8 reduction_factor");
    assert_eq!(rows[0].get("value").unwrap().as_f64().unwrap(), 4.0);
    assert_eq!(rows[1].get("value").unwrap().as_f64().unwrap(), -3.25);
    // measure() rows carry Null value and a real timing summary
    assert_eq!(rows[2].get("value").unwrap(), &Json::Null);
    assert_eq!(rows[2].req_str("label").unwrap(), "noop \"quoted\" label");
    assert!(rows[2].get("mean_s").unwrap().as_f64().unwrap() >= 0.0);
    assert!(rows[2].get("n").unwrap().as_usize().unwrap() >= 3);
    // Serializer → parser fixpoint on the parsed records.
    for r in &rows {
        assert_eq!(&Json::parse(&r.to_string()).unwrap(), r);
    }

    std::env::remove_var("MERGEQUANT_ARTIFACTS");
    let _ = std::fs::remove_dir_all(&dir);
}
