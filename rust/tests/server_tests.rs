//! Server + TCP gateway integration tests (synthetic model, in-process).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::server::TcpGateway;
use mergequant::coordinator::{SchedulerConfig, Server};
use mergequant::engine::{Engine, KvDtype};
use mergequant::util::json::Json;

fn test_server() -> Server {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    Server::start(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 4,
            max_seq: 64,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
        },
    )
}

#[test]
fn submit_roundtrip() {
    let server = test_server();
    let rx = server.submit(vec![3, 4, 5, 6], 8);
    let resp = rx.recv().expect("response");
    assert_eq!(resp.tokens.len(), 8);
    assert_eq!(resp.prompt_len, 4);
    assert!(resp.ttft <= resp.latency);
}

#[test]
fn concurrent_submissions_all_complete() {
    let server = Arc::new(test_server());
    let mut handles = Vec::new();
    for i in 0..12u32 {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            let prompt: Vec<u32> = (0..4 + i % 5).map(|t| 3 + t % 90).collect();
            let resp = s.submit(prompt.clone(), 5).recv().unwrap();
            assert_eq!(resp.prompt_len, prompt.len());
            assert_eq!(resp.tokens.len(), 5);
            resp.id
        }));
    }
    let mut ids: Vec<u64> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 12, "ids must be unique");
}

#[test]
fn shutdown_reports_metrics() {
    let server = test_server();
    server.submit(vec![3, 4], 3).recv().unwrap();
    let report = server.shutdown();
    assert!(report.contains("requests=1"), "report: {report}");
}

#[test]
fn tcp_gateway_end_to_end() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    // valid request
    writeln!(out, "{{\"prompt\":[3,9,12],\"max_new\":4}}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(line.trim()).unwrap();
    assert_eq!(j.get("prompt_len").unwrap().as_usize().unwrap(), 3);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);

    // malformed request -> error object, connection stays usable
    writeln!(out, "not json").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(line.trim()).unwrap().get("error").is_some());

    writeln!(out, "{{\"prompt\":[5],\"max_new\":2}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(line.trim()).unwrap().get("tokens").is_some());

    gw.stop();
}

#[test]
fn gateway_many_clients() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let addr = gw.addr;
    let mut handles = Vec::new();
    for c in 0..4 {
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            for k in 0..3 {
                writeln!(out, "{{\"prompt\":[{},{}],\"max_new\":3}}",
                         3 + c, 4 + k).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = Json::parse(line.trim()).unwrap();
                assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(),
                           3);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    gw.stop();
}
