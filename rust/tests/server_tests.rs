//! Server + TCP gateway integration tests (synthetic model, in-process):
//! the generation API v2 contract — streamed events, typed admission
//! errors, cancellation returning KV blocks, v1/v2 NDJSON framing,
//! malformed/unknown-field protocol errors, mid-stream disconnects.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::server::TcpGateway;
use mergequant::coordinator::{
    Event, FinishReason, GenerationParams, Router, RouterConfig,
    RouterGateway, SchedulerConfig, Server, SubmitError,
};
use mergequant::engine::{Engine, KvDtype};
use mergequant::util::json::Json;

fn server_with(max_batch: usize, kv_slabs: usize, max_seq: usize,
               queue_cap: usize) -> Server {
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    Server::start(
        engine,
        SchedulerConfig {
            max_batch,
            kv_slabs,
            kv_block: 16,
            kv_blocks: 0,
            max_seq,
            max_prefills_per_iter: 2,
            queue_cap,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

fn test_server() -> Server {
    server_with(4, 4, 64, 64)
}

#[test]
fn generate_streams_token_events_then_done() {
    let server = test_server();
    let handle = server
        .generate(vec![3, 4, 5, 6], GenerationParams::greedy(8))
        .expect("admission");
    let mut streamed = Vec::new();
    let response = loop {
        match handle.recv().expect("stream ended without terminal frame") {
            Event::Token { id, index, token } => {
                assert_eq!(id, handle.id());
                assert_eq!(index, streamed.len(), "token frames in order");
                streamed.push(token);
            }
            Event::Done { response } => break response,
            Event::Error { response } => {
                panic!("unexpected error: {:?}", response.error)
            }
        }
    };
    assert_eq!(streamed.len(), 8);
    assert_eq!(response.tokens, streamed,
               "done frame must carry the streamed tokens");
    assert_eq!(response.prompt_len, 4);
    assert_eq!(response.finish, FinishReason::Length);
    assert!(response.ttft <= response.latency);
    // Stream is closed after the terminal frame.
    assert!(handle.recv().is_none());
}

#[test]
fn greedy_generate_matches_engine_generate() {
    // The serving path with temperature=0 must reproduce the seed greedy
    // engine output token for token.
    let engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    let prompt = vec![3u32, 9, 12, 40];
    let golden = engine.generate(&prompt, 8, 64).unwrap();
    let server = test_server();
    let resp = server
        .generate(prompt, GenerationParams::greedy(8))
        .unwrap()
        .wait();
    assert_eq!(resp.tokens, golden);
}

#[test]
fn generate_wait_roundtrip() {
    // The blocking convenience path (generate + wait) — the successor
    // of the removed `Server::submit` shim.
    let server = test_server();
    let resp = server
        .generate(vec![3, 4, 5, 6], GenerationParams::greedy(8))
        .expect("admission")
        .wait();
    assert_eq!(resp.tokens.len(), 8);
    assert_eq!(resp.prompt_len, 4);
    assert!(resp.ttft <= resp.latency);
    assert!(resp.error.is_none());
}

#[test]
fn concurrent_generates_all_complete() {
    let server = Arc::new(test_server());
    let mut handles = Vec::new();
    for i in 0..12u32 {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            let prompt: Vec<u32> = (0..4 + i % 5).map(|t| 3 + t % 90).collect();
            let resp = s
                .generate(prompt.clone(), GenerationParams::greedy(5))
                .expect("admission")
                .wait();
            assert_eq!(resp.prompt_len, prompt.len());
            assert_eq!(resp.tokens.len(), 5);
            resp.id
        }));
    }
    let mut ids: Vec<u64> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), 12, "ids must be unique");
}

#[test]
fn shutdown_reports_metrics_and_later_generates_fail_typed() {
    let server = test_server();
    server
        .generate(vec![3, 4], GenerationParams::greedy(3))
        .unwrap()
        .wait();
    let report = server.shutdown();
    assert!(report.contains("requests=1"), "report: {report}");
    // Dead worker is a typed error, not a panic (the seed behaviour was
    // `.expect("server worker gone")`).
    let err = server
        .generate(vec![3, 4], GenerationParams::greedy(2))
        .unwrap_err();
    assert_eq!(err, SubmitError::WorkerGone);
}

#[test]
fn session_ids_are_validated_and_never_change_streams() {
    let server = test_server();
    // Malformed ids are typed admission errors (DESIGN.md §16: the
    // charset/length contract is enforced at the generate boundary).
    let mut spaced = GenerationParams::greedy(4);
    spaced.session = Some("has space".into());
    match server.generate(vec![3, 4], spaced) {
        Err(SubmitError::InvalidParams(msg)) => {
            assert!(msg.contains("session"), "{msg}")
        }
        other => panic!("expected InvalidParams, got {other:?}"),
    }
    let mut long = GenerationParams::greedy(4);
    long.session = Some("x".repeat(65));
    assert!(matches!(server.generate(vec![3, 4], long),
                     Err(SubmitError::InvalidParams(_))));
    // A valid id is placement metadata only: the standalone server
    // accepts it and streams the identical greedy tokens.
    let plain = server
        .generate(vec![3, 9, 12], GenerationParams::greedy(6))
        .unwrap()
        .wait();
    let mut tagged = GenerationParams::greedy(6);
    tagged.session = Some("chat-1".into());
    let got = server.generate(vec![3, 9, 12], tagged).unwrap().wait();
    assert_eq!(got.tokens, plain.tokens,
               "session is a routing input, never a sampling input");
}

#[test]
fn invalid_params_and_empty_prompt_rejected() {
    let server = test_server();
    let mut p = GenerationParams::greedy(4);
    p.temperature = -0.5;
    match server.generate(vec![3], p) {
        Err(SubmitError::InvalidParams(msg)) => {
            assert!(msg.contains("temperature"), "{msg}")
        }
        other => panic!("expected InvalidParams, got {other:?}"),
    }
    match server.generate(Vec::new(), GenerationParams::greedy(4)) {
        Err(SubmitError::InvalidParams(msg)) => {
            assert!(msg.contains("prompt"), "{msg}")
        }
        other => panic!("expected InvalidParams, got {other:?}"),
    }
}

#[test]
fn queue_full_is_typed_backpressure() {
    // One active slot, one queue slot: the third request must be refused
    // synchronously with QueueFull.
    let server = server_with(1, 1, 4096, 1);
    let h1 = server
        .generate(vec![3, 4, 5], GenerationParams::greedy(100_000))
        .unwrap();
    // First token ⇒ admitted out of the pending queue.
    assert!(matches!(h1.recv(), Some(Event::Token { .. })));
    let h2 = server
        .generate(vec![6, 7], GenerationParams::greedy(4))
        .unwrap();
    let err = server
        .generate(vec![8, 9], GenerationParams::greedy(4))
        .unwrap_err();
    assert_eq!(err, SubmitError::QueueFull { cap: 1 });
    h1.cancel();
    assert_eq!(h1.wait().finish, FinishReason::Cancelled);
    // h2 proceeds normally once the slab frees up.
    assert_eq!(h2.wait().tokens.len(), 4);
}

#[test]
fn cancel_returns_kv_slab_for_reuse() {
    // Single KV slab: the second request can only ever complete if
    // cancelling the first returns its slab to the pool.
    let server = server_with(1, 1, 4096, 64);
    let h1 = server
        .generate(vec![3, 4, 5], GenerationParams::greedy(100_000))
        .unwrap();
    for _ in 0..2 {
        assert!(matches!(h1.recv(), Some(Event::Token { .. })));
    }
    let h2 = server
        .generate(vec![10, 11, 12], GenerationParams::greedy(4))
        .unwrap();
    h1.cancel();
    let r1 = h1.wait();
    assert_eq!(r1.finish, FinishReason::Cancelled);
    assert!(r1.tokens.len() >= 2, "tokens streamed before cancel remain");
    assert!(r1.error.is_none());
    let r2 = h2.wait();
    assert_eq!(r2.tokens.len(), 4, "cancelled slab must be reusable");
    assert_eq!(r2.finish, FinishReason::Length);
    let report = server.shutdown();
    assert!(report.contains("cancelled=1"), "report: {report}");
}

#[test]
fn dropped_handle_cancels_request() {
    // Dropping the handle mid-stream must tear the request out (a
    // vanished consumer must not keep burning decode steps + slab).
    let server = server_with(1, 1, 4096, 64);
    {
        let h1 = server
            .generate(vec![3, 4, 5], GenerationParams::greedy(100_000))
            .unwrap();
        assert!(matches!(h1.recv(), Some(Event::Token { .. })));
        // handle dropped here without cancel()
    }
    // The next request can only complete once the worker notices the
    // dead sink and frees the slab.
    let r = server
        .generate(vec![6, 7], GenerationParams::greedy(3))
        .unwrap()
        .wait();
    assert_eq!(r.tokens.len(), 3);
    let report = server.shutdown();
    assert!(report.contains("cancelled=1"), "report: {report}");
}

// ---------------------------------------------------------------------
// TCP gateway
// ---------------------------------------------------------------------

fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap_or_else(|e| {
        panic!("bad frame {line:?}: {e}")
    })
}

#[test]
fn tcp_gateway_v1_single_shot() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    writeln!(out, "{{\"prompt\":[3,9,12],\"max_new\":4}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("prompt_len").unwrap().as_usize().unwrap(), 3);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(j.get("finish").unwrap().as_str().unwrap(), "length");
    assert!(j.get("event").is_none(), "v1 replies are not framed");

    gw.stop();
}

#[test]
fn tcp_gateway_rejects_malformed_and_unknown_fields() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    // malformed JSON -> error frame, connection stays usable
    writeln!(out, "not json").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("event").unwrap().as_str().unwrap(), "error");
    assert!(j.get("error").is_some());

    // unknown top-level field (a typo'd max_new) -> protocol error
    writeln!(out, "{{\"prompt\":[3],\"max_mew\":4}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("max_mew"));

    // unknown params field -> protocol error
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"temprature\":0.5}}}}")
        .unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap()
        .contains("temprature"));

    // non-array prompt -> protocol error
    writeln!(out, "{{\"prompt\":\"hi\",\"max_new\":2}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("prompt"));

    // empty prompt -> typed admission error
    writeln!(out, "{{\"prompt\":[],\"max_new\":2}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("prompt"));

    // bad sampling params -> typed admission error
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"temperature\":-2}}}}")
        .unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap()
        .contains("temperature"));

    // negative/fractional integer params are protocol errors, never
    // silently saturated casts
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"seed\":-1}}}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("seed"));
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"max_new\":3.9}}}}")
        .unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap().contains("max_new"));

    // session must be a JSON string (protocol error at parse time)...
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"session\":42}}}}")
        .unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap()
        .contains("session"));

    // ...with the documented charset (typed admission error)...
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"session\":\
                   \"has space\"}}}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap()
        .contains("session"));

    // ...and length bound.
    let long_id = "x".repeat(65);
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"session\":\
                   \"{long_id}\"}}}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap()
        .contains("session"));

    // A fleet control frame is a protocol error on a standalone
    // server's gateway (`cmd` is not a request field).
    writeln!(out, "{{\"cmd\":\"stats\"}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").is_some(),
            "standalone gateway must reject control frames");

    // ...and a well-formed request still works on the same connection.
    writeln!(out, "{{\"prompt\":[5],\"max_new\":2}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);

    gw.stop();
}

#[test]
fn tcp_gateway_v2_priority_and_deadline_params() {
    // The §15 scheduling params ride the v2 params object: a valid
    // priority/deadline_ms pair is accepted (and on an uncontended
    // server changes nothing about the stream), a class that does not
    // fit u8 is a protocol error that names the bound, and the
    // connection stays usable throughout.
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4,\
                   \"priority\":2,\"deadline_ms\":250}}}}").unwrap();
    let mut classed = Vec::new();
    loop {
        let j = read_json(&mut reader);
        match j.get("event").unwrap().as_str().unwrap() {
            "token" => classed.push(j.get("token").unwrap()
                .as_usize().unwrap()),
            "done" => break,
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(classed.len(), 4);

    // Out-of-range class: a typed protocol error naming the u8 bound.
    writeln!(out, "{{\"prompt\":[3],\"params\":{{\"max_new\":2,\
                   \"priority\":300}}}}").unwrap();
    let j = read_json(&mut reader);
    assert!(j.get("error").unwrap().as_str().unwrap()
        .contains("priority must be <= 255"));

    // The class annotation never changes the tokens: same prompt with
    // default class streams the identical greedy tokens.
    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4}}}}")
        .unwrap();
    let mut plain = Vec::new();
    loop {
        let j = read_json(&mut reader);
        match j.get("event").unwrap().as_str().unwrap() {
            "token" => plain.push(j.get("token").unwrap()
                .as_usize().unwrap()),
            "done" => break,
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(plain, classed,
               "priority/deadline are scheduling inputs, not sampling \
                inputs");

    gw.stop();
}

#[test]
fn tcp_gateway_v2_streaming_framing() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4,\
                   \"temperature\":0.8,\"top_k\":16,\"top_p\":0.9,\
                   \"seed\":11}}}}").unwrap();
    let mut streamed = Vec::new();
    let done = loop {
        let j = read_json(&mut reader);
        match j.get("event").unwrap().as_str().unwrap() {
            "token" => {
                assert_eq!(j.get("index").unwrap().as_usize().unwrap(),
                           streamed.len());
                streamed.push(j.get("token").unwrap().as_usize().unwrap());
            }
            "done" => break j,
            other => panic!("unexpected frame {other:?}"),
        }
    };
    assert_eq!(streamed.len(), 4, "one token frame per generated token");
    let final_tokens: Vec<usize> = done.get("tokens").unwrap().as_arr()
        .unwrap().iter().map(|v| v.as_usize().unwrap()).collect();
    assert_eq!(final_tokens, streamed);
    assert_eq!(done.get("finish").unwrap().as_str().unwrap(), "length");
    assert_eq!(done.get("prompt_len").unwrap().as_usize().unwrap(), 3);

    // Same seed replays the same stream (deterministic sampling).
    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4,\
                   \"temperature\":0.8,\"top_k\":16,\"top_p\":0.9,\
                   \"seed\":11}}}}").unwrap();
    let mut replay = Vec::new();
    loop {
        let j = read_json(&mut reader);
        match j.get("event").unwrap().as_str().unwrap() {
            "token" => replay.push(j.get("token").unwrap()
                .as_usize().unwrap()),
            "done" => break,
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(replay, streamed, "fixed-seed stream must replay bitwise");

    gw.stop();
}

#[test]
fn tcp_gateway_v2_greedy_matches_v1_tokens() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    writeln!(out, "{{\"prompt\":[3,9,12],\"max_new\":4}}").unwrap();
    let v1 = read_json(&mut reader);
    let v1_tokens: Vec<usize> = v1.get("tokens").unwrap().as_arr().unwrap()
        .iter().map(|v| v.as_usize().unwrap()).collect();

    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4}}}}")
        .unwrap();
    let mut v2_tokens = Vec::new();
    loop {
        let j = read_json(&mut reader);
        match j.get("event").unwrap().as_str().unwrap() {
            "token" => v2_tokens.push(j.get("token").unwrap()
                .as_usize().unwrap()),
            "done" => break,
            other => panic!("unexpected frame {other:?}"),
        }
    }
    assert_eq!(v2_tokens, v1_tokens,
               "default v2 params are greedy == v1 semantics");

    gw.stop();
}

#[test]
fn tcp_gateway_disconnect_cancels_and_frees_slab() {
    // One slab, one batch slot: a mid-stream client disconnect must
    // cancel the request (visible in the metrics) and return its slab so
    // a later client can be served.
    let server = Arc::new(server_with(1, 1, 4096, 64));
    let gw = TcpGateway::start(server.clone(), 0).unwrap();

    {
        let stream = TcpStream::connect(gw.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut out = stream;
        writeln!(out, "{{\"prompt\":[3,4,5],\"params\":{{\
                       \"max_new\":100000}}}}").unwrap();
        // Prove the stream is live, then vanish without cancelling.
        for _ in 0..2 {
            let j = read_json(&mut reader);
            assert_eq!(j.get("event").unwrap().as_str().unwrap(), "token");
        }
    } // client connection dropped here

    // A fresh client can only be served once the slab is back.
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;
    writeln!(out, "{{\"prompt\":[6,7],\"max_new\":3}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    drop(out);
    drop(reader);

    gw.stop();
    let report = server.shutdown();
    assert!(report.contains("cancelled=1"), "report: {report}");
}

#[test]
fn gateway_many_clients() {
    let server = Arc::new(test_server());
    let gw = TcpGateway::start(server.clone(), 0).unwrap();
    let addr = gw.addr;
    let mut handles = Vec::new();
    for c in 0..4 {
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            for k in 0..3 {
                writeln!(out, "{{\"prompt\":[{},{}],\"max_new\":3}}",
                         3 + c, 4 + k).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let j = Json::parse(line.trim()).unwrap();
                assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(),
                           3);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    gw.stop();
}

// ---------------------------------------------------------------------
// Router gateway (replica-sharded front door, DESIGN.md §16)
// ---------------------------------------------------------------------

fn test_router(replicas: usize) -> Arc<Router> {
    let cfg = SchedulerConfig {
        max_batch: 4,
        kv_slabs: 0,
        kv_block: 16,
        kv_blocks: 32,
        max_seq: 64,
        max_prefills_per_iter: 2,
        queue_cap: 64,
        prefill_chunk: 0,
        threads: 1,
        kv_dtype: KvDtype::F32,
        prefix_cache: false,
        prefix_cache_blocks: 0,
        max_decode_latency: 0,
        speculative: false,
        draft_k: 0,
        draft_layers: 0,
    };
    Arc::new(Router::start(
        RouterConfig::new(replicas, cfg),
        |_i| Engine::new(synthetic_model("mergequant", 64, 128, 1, 96)),
    ))
}

fn read_stream_tokens(reader: &mut BufReader<TcpStream>) -> Vec<usize> {
    let mut tokens = Vec::new();
    loop {
        let j = read_json(reader);
        match j.get("event").unwrap().as_str().unwrap() {
            "token" => tokens.push(
                j.get("token").unwrap().as_usize().unwrap()),
            "done" => return tokens,
            other => panic!("unexpected frame {other:?}"),
        }
    }
}

#[test]
fn router_gateway_requests_stats_and_strict_control_frames() {
    let router = test_router(2);
    let gw = RouterGateway::start(router.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    // v1 and v2 request frames speak the standalone protocol verbatim.
    writeln!(out, "{{\"prompt\":[3,9,12],\"max_new\":4}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":3,\
                   \"session\":\"chat-a\"}}}}").unwrap();
    assert_eq!(read_stream_tokens(&mut reader).len(), 3);

    // The stats frame reports every replica machine-readably.
    writeln!(out, "{{\"cmd\":\"stats\"}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("event").unwrap().as_str().unwrap(), "stats");
    let reps = j.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 2);
    for (i, r) in reps.iter().enumerate() {
        assert_eq!(r.get("replica").unwrap().as_usize().unwrap(), i);
        assert!(r.get("kv_capacity").unwrap().as_usize().unwrap() > 0);
        assert_eq!(r.get("draining").unwrap(), &Json::Bool(false));
    }

    // Control frames are strict: unknown fields, unknown commands and
    // out-of-range replicas are protocol errors that keep the
    // connection usable.
    for bad in ["{\"cmd\":\"stats\",\"verbose\":true}",
                "{\"cmd\":\"drain\",\"replica\":0,\"force\":true}",
                "{\"cmd\":\"drain\"}",
                "{\"cmd\":\"drain\",\"replica\":1.5}",
                "{\"cmd\":\"restart\"}",
                "{\"cmd\":\"drain\",\"replica\":9}"] {
        writeln!(out, "{bad}").unwrap();
        let j = read_json(&mut reader);
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "error",
                   "frame must be rejected: {bad}");
    }

    // ...and the connection still serves requests afterwards.
    writeln!(out, "{{\"prompt\":[5,6],\"max_new\":2}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 2);

    gw.stop();
    router.shutdown();
}

#[test]
fn router_gateway_drain_reroutes_sessions_instead_of_erroring() {
    let router = test_router(2);
    let gw = RouterGateway::start(router.clone(), 0).unwrap();
    let stream = TcpStream::connect(gw.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut out = stream;

    // Pin a session, then capture its greedy stream.
    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4,\
                   \"session\":\"chat-b\"}}}}").unwrap();
    let first = read_stream_tokens(&mut reader);
    let pinned = router.session_replica("chat-b").expect("pinned");

    // Drain the pinned replica over the wire; it is idle, so it tears
    // down and respawns immediately.
    writeln!(out, "{{\"cmd\":\"drain\",\"replica\":{pinned}}}").unwrap();
    let j = read_json(&mut reader);
    assert_eq!(j.get("event").unwrap().as_str().unwrap(), "drain");
    assert_eq!(j.get("replica").unwrap().as_usize().unwrap(), pinned);
    assert_eq!(j.get("status").unwrap().as_str().unwrap(), "draining");

    // The stale pin re-routes (bitwise-identical stream), no error.
    writeln!(out, "{{\"prompt\":[3,9,12],\"params\":{{\"max_new\":4,\
                   \"session\":\"chat-b\"}}}}").unwrap();
    let replay = read_stream_tokens(&mut reader);
    assert_eq!(replay, first,
               "re-routed session must stream identical tokens");
    let m = router.metrics();
    assert_eq!(m.drains, 1);
    assert_eq!(m.respawns, 1);
    assert_eq!(m.rerouted, 1);

    gw.stop();
    router.shutdown();
}
