//! Preempt/resume replay suite (DESIGN.md §15) — the CI matrix target
//! for priority classes, transparent decode-lane preemption, and the
//! SLO gate.
//!
//! The pinned claim: preemption changes *when* a stream's tokens are
//! computed, never *what* they are. A decode lane evicted under block
//! pressure by a strictly-higher class re-enters pending with its
//! generation state, recomputes its KV (prefix-cache hit when the index
//! is on), and continues its counter-based sampler at the next step —
//! so every lane of a bursty mixed-priority fleet that runs to a normal
//! finish streams **bitwise identically** to an uninterrupted solo
//! replay of the same prompt, across
//! {threads}×{kv f32,int8}×{kv_block}×{prefix on,off}×{chunking}.
//! Victim selection is deterministic (lowest class, then youngest) and
//! observable via `Scheduler::preemption_log`; a preempted stream never
//! surfaces `cache_full`.
//!
//! CI matrix knobs: `MQ_TEST_THREADS`, `MQ_TEST_KV`, `MQ_TEST_KV_BLOCK`
//! (DESIGN.md §7/§10/§13).

mod common;

use std::cell::Cell;

use mergequant::bench::synthetic_model;
use mergequant::coordinator::{
    Event, FinishReason, GenerationParams, Request, Scheduler,
    SchedulerConfig,
};
use mergequant::engine::{Engine, KvDtype};
use mergequant::util::proptest::check;

use common::{drive_fleet, gen_burst_fleet, FleetTrace};

/// Tight-arena scheduler: `⌈max_seq/kv_block⌉ + 1` blocks — enough for
/// one full sequence plus change, so a bursty fleet is guaranteed to
/// contend and higher classes must preempt to make progress.
fn tight_scheduler(prefix_on: bool, threads: usize, kv: KvDtype,
                   kv_block: usize, chunk: usize) -> Scheduler {
    let engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 1, 96), threads);
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 0,
            kv_block,
            kv_blocks: 48usize.div_ceil(kv_block) + 1,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: chunk,
            threads,
            kv_dtype: kv,
            prefix_cache: prefix_on,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

/// Ample-arena scheduler for solo goldens and the hand-scripted unit
/// scenarios below.
fn roomy_scheduler(threads: usize, kv: KvDtype, kv_block: usize,
                   kv_blocks: usize, max_seq: usize) -> Scheduler {
    let engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 1, 96), threads);
    Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 0,
            kv_block,
            kv_blocks,
            max_seq,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads,
            kv_dtype: kv,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 0,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    )
}

/// Uninterrupted solo replay: the lane's prompt alone through an
/// uncontended scheduler — the golden stream preemption must reproduce.
fn solo_stream(threads: usize, kv: KvDtype, kv_block: usize,
               prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut sched = roomy_scheduler(threads, kv, kv_block, 8, 48);
    sched.submit(Request::new(0, prompt.to_vec(), max_new)).unwrap();
    let rs = sched.run_to_completion();
    assert!(rs[0].error.is_none(), "golden failed: {:?}", rs[0].error);
    rs[0].tokens.clone()
}

fn check_fleet_against_goldens(trace: &FleetTrace, mut sched: Scheduler,
                               ctx: &str, goldens: &[Vec<u32>],
                               preempt_total: &Cell<u64>)
                               -> Result<(), String> {
    let rs = drive_fleet(&mut sched, trace);
    if rs.len() != trace.lanes.len() {
        return Err(format!("{} responses for {} lanes {ctx}",
                           rs.len(), trace.lanes.len()));
    }
    for (r, golden) in rs.iter().zip(goldens) {
        if let Some(e) = &r.error {
            return Err(format!("lane {} failed: {e} {ctx}", r.id));
        }
        match r.finish {
            // Cancellation and same-class CacheFull truncate a stream;
            // neither may rewrite it.
            FinishReason::Cancelled | FinishReason::CacheFull => {
                if r.tokens.len() > golden.len()
                    || r.tokens[..] != golden[..r.tokens.len()]
                {
                    return Err(format!(
                        "truncated lane {} ({:?}) diverged from its solo \
                         replay: {:?} not a prefix of {:?} {ctx}",
                        r.id, r.finish, r.tokens, golden));
                }
            }
            // A normal finish must be the whole uninterrupted stream —
            // preemption and resume bitwise invisible.
            _ => {
                if &r.tokens != golden {
                    return Err(format!(
                        "lane {} diverged from its solo replay: {:?} != \
                         {:?} {ctx}", r.id, r.tokens, golden));
                }
            }
        }
    }
    // The ledger balances at drain (the per-tick variant lives in
    // coordinator_props); with the index on, retained blocks account
    // for the difference.
    if sched.kv_available() + sched.prefix_cached_blocks()
        != sched.kv_capacity()
    {
        return Err(format!(
            "drain leak: {} free + {} cached != {} capacity {ctx}",
            sched.kv_available(), sched.prefix_cached_blocks(),
            sched.kv_capacity()));
    }
    preempt_total.set(preempt_total.get() + sched.metrics.preemptions);
    Ok(())
}

#[test]
fn preempted_streams_bitwise_match_uninterrupted_replay() {
    // The headline §15 property over the full determinism matrix. The
    // sweep must actually exercise preemption: the aggregate count
    // across all fleets is asserted non-zero at the end.
    let preempt_total = Cell::new(0u64);
    for kv in common::kv_dtypes() {
        for &threads in &common::thread_counts() {
            for kv_block in common::sched_kv_blocks() {
                check(5407 + threads as u64 + kv_block as u64, 2,
                      gen_burst_fleet, |trace| {
                    let goldens: Vec<Vec<u32>> = trace
                        .lanes
                        .iter()
                        .map(|l| solo_stream(threads, kv, kv_block,
                                             &l.prompt, l.max_new))
                        .collect();
                    for prefix_on in [false, true] {
                        for chunk in [0usize, 5] {
                            let ctx = format!(
                                "(prefix {prefix_on}, kv {kv:?}, threads \
                                 {threads}, kv_block {kv_block}, chunk \
                                 {chunk})");
                            check_fleet_against_goldens(
                                trace,
                                tight_scheduler(prefix_on, threads, kv,
                                                kv_block, chunk),
                                &ctx, &goldens, &preempt_total)?;
                        }
                    }
                    Ok(())
                });
            }
        }
    }
    assert!(preempt_total.get() > 0,
            "the tight-arena sweep never preempted anyone — the matrix \
             exercised nothing");
}

// ---------------------------------------------------------------------
// Deterministic victim selection (the §15 scheduling contract)
// ---------------------------------------------------------------------

fn classed(id: u64, prompt: Vec<u32>, max_new: usize, class: u8)
           -> Request {
    Request::with_params(id, prompt, GenerationParams {
        priority: class,
        ..GenerationParams::greedy(max_new)
    })
}

/// Drive two low lanes to steady decode (2 blocks each of the 4-block
/// arena), then admit one high-class lane whose prefill needs a block —
/// forcing exactly one preemption. Returns the scheduler post-drain and
/// the responses sorted by id.
fn preempt_scenario(low_classes: [u8; 2], high_class: u8)
                    -> (Scheduler, Vec<mergequant::coordinator::Response>) {
    // 4 blocks × 16 tokens, max_seq 64 (the arena covers one max_seq
    // sequence). 16-token prompts fill one block exactly; the first
    // decode step claims each lane's second block, so the high-class
    // arrival at tick 3 finds the free list empty.
    let mut sched = roomy_scheduler(1, KvDtype::F32, 16, 4, 64);
    let prompt: Vec<u32> = (0..16).map(|t| 3 + (t * 7) % 90).collect();
    sched.submit(classed(1, prompt.clone(), 4, low_classes[0])).unwrap();
    sched.submit(classed(2, prompt.clone(), 4, low_classes[1])).unwrap();
    sched.step(); // both prefill + first token (1 block each)
    sched.step(); // second token — each lane claims its second block
    assert_eq!(sched.kv_available(), 0, "scenario geometry drifted");
    sched.submit(classed(3, prompt, 4, high_class)).unwrap();
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    (sched, rs)
}

#[test]
fn victim_selection_lowest_class_first() {
    // Lanes of class 0 and 1 hold the arena; a class-2 admission must
    // evict the class-0 lane — even though the class-1 lane is younger.
    let (sched, rs) = preempt_scenario([0, 1], 2);
    assert_eq!(sched.preemption_log(), &[1],
               "victim must be the lowest class, not the youngest lane");
    assert_eq!(sched.metrics.preemptions, 1);
    for r in &rs {
        assert!(r.error.is_none(), "lane {} failed: {:?}", r.id, r.error);
        assert_eq!(r.finish, FinishReason::Length,
                   "lane {} finished {:?}", r.id, r.finish);
        assert_eq!(r.tokens.len(), 4, "lane {} truncated", r.id);
    }
    // The preempted lane's stream equals its solo replay bitwise.
    let golden = solo_stream(1, KvDtype::F32, 16,
                             &(0..16).map(|t| 3 + (t * 7) % 90)
                                 .collect::<Vec<u32>>(), 4);
    assert_eq!(rs[0].tokens, golden,
               "preempt/resume changed the victim's stream");
}

#[test]
fn victim_selection_youngest_within_class() {
    // Both low lanes are class 0: the tie breaks to the youngest (the
    // higher lane index — lane index equals arrival order), so lane 2.
    let (sched, rs) = preempt_scenario([0, 0], 1);
    assert_eq!(sched.preemption_log(), &[2],
               "equal classes must evict the youngest lane");
    assert_eq!(sched.metrics.preemptions, 1);
    for r in &rs {
        assert!(r.error.is_none());
        assert_eq!(r.finish, FinishReason::Length);
        assert_eq!(r.tokens.len(), 4);
    }
}

#[test]
fn preemption_is_invisible_in_the_event_stream() {
    // Transparent-backpressure regression: the victim's event stream
    // must look exactly like an uninterrupted run — consecutive Token
    // frames 0..n with no duplicates or re-emissions around the
    // preemption, then exactly one terminal Done with finish `length`,
    // and never `cache_full`.
    let mut sched = roomy_scheduler(1, KvDtype::F32, 16, 4, 64);
    let prompt: Vec<u32> = (0..16).map(|t| 3 + (t * 7) % 90).collect();
    sched.submit(classed(1, prompt.clone(), 4, 0)).unwrap();
    sched.submit(classed(2, prompt.clone(), 4, 1)).unwrap();
    let mut victim_events = Vec::new();
    let drain = |sched: &mut Scheduler,
                 victim_events: &mut Vec<Event>| {
        for ev in sched.take_events() {
            if ev.id() == 1 {
                victim_events.push(ev);
            }
        }
    };
    sched.step();
    sched.step();
    drain(&mut sched, &mut victim_events);
    sched.submit(classed(3, prompt.clone(), 4, 2)).unwrap();
    while sched.has_work() {
        sched.step();
        drain(&mut sched, &mut victim_events);
    }
    assert_eq!(sched.preemption_log(), &[1], "lane 1 must be the victim");
    let (terminals, tokens): (Vec<&Event>, Vec<&Event>) =
        victim_events.iter().partition(|e| e.is_terminal());
    assert_eq!(tokens.len(), 4, "4 Token frames for max_new 4");
    for (i, ev) in tokens.iter().enumerate() {
        let Event::Token { index, .. } = ev else { unreachable!() };
        assert_eq!(*index, i,
                   "token frames must stay consecutive across the \
                    preemption (no re-emission, no gap)");
    }
    assert_eq!(terminals.len(), 1, "exactly one terminal frame");
    let Event::Done { response } = terminals[0] else {
        panic!("victim must finish Done, got {:?}", terminals[0]);
    };
    assert_eq!(response.finish, FinishReason::Length,
               "a preempted lane must never surface cache_full");
    let golden = solo_stream(1, KvDtype::F32, 16, &prompt, 4);
    assert_eq!(response.tokens, golden);
}

#[test]
fn same_class_pressure_keeps_cache_full_fifo_cut() {
    // The pre-§15 contract survives: uniform-priority block pressure
    // still cuts the youngest lane CacheFull deterministically (the
    // `decode_lanes_finish_cache_full_fifo_under_block_pressure`
    // geometry — 5 blocks × 8 tokens, max_seq 32) even when a lane of a
    // *lower* class was preempted out of the arena earlier: preemption
    // never reorders the same-class cut.
    let mut sched = roomy_scheduler(1, KvDtype::F32, 8, 5, 32);
    let prompt: Vec<u32> = (0..8).map(|t| 3 + t % 90).collect();
    // A class-0 background lane admits first and starts decoding…
    sched.submit(classed(7, prompt.clone(), 30, 0)).unwrap();
    sched.step();
    sched.step();
    // …then two class-1 lanes arrive and grow until the pool runs dry;
    // their admissions preempt the background lane out of the way.
    sched.submit(classed(1, prompt.clone(), 30, 1)).unwrap();
    sched.submit(classed(2, prompt, 30, 1)).unwrap();
    let mut rs = sched.run_to_completion();
    rs.sort_by_key(|r| r.id);
    assert_eq!(rs.len(), 3);
    for r in &rs {
        assert!(r.error.is_none(), "pressure must not error: {:?}",
                r.error);
    }
    assert!(sched.metrics.preemptions >= 1,
            "the class-1 burst must preempt the background lane");
    assert!(sched.preemption_log().iter().all(|&id| id == 7),
            "only the class-0 lane may be preempted: {:?}",
            sched.preemption_log());
    // Same-class cut: lane 2 (younger) is cut CacheFull first, lane 1
    // outlives it — bitwise the pre-§15 deterministic order.
    assert_eq!(rs[1].finish, FinishReason::CacheFull,
               "the younger same-class lane must be cut first");
    assert!(rs[1].tokens.len() < rs[0].tokens.len(),
            "FIFO priority inverted: lane 1 ({}) vs lane 2 ({})",
            rs[0].tokens.len(), rs[1].tokens.len());
    // The preempted background lane was never cut: it resumed and ran
    // to its budget or a graceful CacheFull — never an error, and its
    // stream is a prefix of (or equal to) its solo replay.
    let golden = solo_stream(1, KvDtype::F32, 8,
                             &(0..8).map(|t| 3 + t % 90)
                                 .collect::<Vec<u32>>(), 30);
    let bg = &rs[2];
    assert!(!bg.tokens.is_empty(), "background lane starved");
    assert_eq!(bg.tokens[..], golden[..bg.tokens.len()],
               "background lane diverged from its solo replay");
    assert_eq!(sched.kv_available(), sched.kv_capacity(),
               "pressure run leaked blocks");
}

// ---------------------------------------------------------------------
// SLO accounting (observational — never a token-stream input)
// ---------------------------------------------------------------------

#[test]
fn no_slo_violations_when_capacity_suffices() {
    // Generous deadlines + a generous decode-latency target on an
    // uncontended scheduler: nothing may be deferred and nothing may be
    // counted violated.
    let engine = Engine::with_threads(
        synthetic_model("mergequant", 64, 128, 1, 96), 1);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig {
            max_batch: 4,
            kv_slabs: 8,
            kv_block: 16,
            kv_blocks: 0,
            max_seq: 48,
            max_prefills_per_iter: 2,
            queue_cap: 64,
            prefill_chunk: 0,
            threads: 1,
            kv_dtype: KvDtype::F32,
            prefix_cache: false,
            prefix_cache_blocks: 0,
            max_decode_latency: 60_000,
            speculative: false,
            draft_k: 0,
            draft_layers: 0,
        },
    );
    for i in 0..3u64 {
        let prompt: Vec<u32> =
            (0..8).map(|t| 3 + (t + i as u32) % 90).collect();
        sched.submit(Request::with_params(i, prompt, GenerationParams {
            priority: (i % 3) as u8,
            deadline_ms: Some(60_000),
            ..GenerationParams::greedy(4)
        })).unwrap();
    }
    let rs = sched.run_to_completion();
    assert_eq!(rs.len(), 3);
    for r in &rs {
        assert!(r.error.is_none());
        assert_eq!(r.tokens.len(), 4);
    }
    assert_eq!(sched.metrics.slo_violations, 0,
               "generous deadlines must never count as violated");
    assert_eq!(sched.metrics.slo_deferrals, 0,
               "a 60s decode target must never defer admission");
    assert_eq!(sched.metrics.preemptions, 0,
               "an uncontended arena must never preempt");
}

#[test]
fn impossible_deadline_counts_violations_without_touching_tokens() {
    // deadline_ms = 0 cannot be met; every such completion increments
    // slo_violations — and the tokens are exactly the undeadlined run's.
    let run = |deadline: Option<u64>| {
        let mut sched = roomy_scheduler(1, KvDtype::F32, 16, 8, 48);
        let prompt: Vec<u32> = (0..8).map(|t| 3 + t % 90).collect();
        sched.submit(Request::with_params(1, prompt, GenerationParams {
            deadline_ms: deadline,
            ..GenerationParams::greedy(5)
        })).unwrap();
        let rs = sched.run_to_completion();
        assert!(rs[0].error.is_none());
        (rs[0].tokens.clone(), sched.metrics.slo_violations)
    };
    let (tokens_none, v_none) = run(None);
    let (tokens_zero, v_zero) = run(Some(0));
    assert_eq!(v_none, 0);
    assert_eq!(v_zero, 1, "an impossible deadline must be counted");
    assert_eq!(tokens_zero, tokens_none,
               "deadlines are observational: tokens must not change");
}
