//! Property tests for the statically-quantized INT8 KV cache
//! (DESIGN.md §10): round-trip error bounds, decode-logit drift vs the
//! f32-KV baseline, bitwise thread determinism of the integer attention
//! path (extending the §7 guarantee), chunked-prefill equivalence for
//! both KV dtypes, and the typed-error contract for scale-less bundles.
//!
//! CI runs this suite across a {threads} × {kv dtype} matrix; the env
//! knobs `MQ_TEST_THREADS` (extra thread count for the determinism
//! sweep) and `MQ_TEST_KV` (dtype under test where a single dtype is
//! exercised) hook the matrix in without duplicating test code.

use mergequant::bench::synthetic_model;
use mergequant::engine::{
    Engine, EngineError, KvCache, KvDtype, Sampler, Workspace,
};
use mergequant::quant::kv::{dequantize_row_i8, quantize_row_i8, KV_QMAX};
use mergequant::util::proptest::check;
use mergequant::util::rng::Rng;

fn env_threads() -> Option<usize> {
    std::env::var("MQ_TEST_THREADS").ok().and_then(|v| v.parse().ok())
}

fn env_kv() -> KvDtype {
    std::env::var("MQ_TEST_KV")
        .ok()
        .and_then(|v| KvDtype::parse(&v))
        .unwrap_or(KvDtype::Int8)
}

// ---------------------------------------------------------------------
// Round-trip error bound
// ---------------------------------------------------------------------

#[test]
fn kv_roundtrip_error_bounded_by_half_scale_per_element() {
    // For any per-channel scale vector and any value within the
    // representable range |x| <= 127·s, quantize→dequantize must land
    // within s/2 of the original (round-half-away + exact dequant).
    check(41, 40, |r: &mut Rng| (r.usize(1, 96), r.usize(0, 1_000_000)),
          |&(d, seed)| {
        let mut rng = Rng::new(seed as u64 + 1);
        let scale: Vec<f32> =
            (0..d).map(|_| 0.001 + rng.f32() * 0.5).collect();
        let inv: Vec<f32> = scale.iter().map(|s| 1.0 / s).collect();
        let x: Vec<f32> = (0..d)
            .map(|c| (rng.f32() * 2.0 - 1.0) * scale[c] * KV_QMAX as f32)
            .collect();
        let mut q = vec![0i8; d];
        quantize_row_i8(&x, &inv, &mut q);
        let mut back = vec![0f32; d];
        dequantize_row_i8(&q, &scale, &mut back);
        for c in 0..d {
            let err = (x[c] - back[c]).abs();
            if err > scale[c] / 2.0 + scale[c] * 1e-4 {
                return Err(format!(
                    "channel {c}: |{} - {}| = {err} > scale/2 = {}",
                    x[c], back[c], scale[c] / 2.0));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Decode-logit drift vs the f32-KV baseline
// ---------------------------------------------------------------------

/// Prefill `prompt` then decode `steps` greedy tokens; returns the final
/// logits row and the generated tokens.
fn run_decode(engine: &Engine, prompt: &[u32], steps: usize, kv: KvDtype)
              -> (Vec<f32>, Vec<u32>) {
    let cfg = engine.config().clone();
    let cap = prompt.len() + steps + 2;
    let mut cache = KvCache::with_dtype(kv, cfg.n_layers, cap, cfg.d_model);
    let mut ws = Workspace::new();
    engine.prefill(prompt, &mut cache, &mut ws).unwrap();
    let v = cfg.vocab;
    let mut next =
        Sampler::argmax(
            &ws.logits[(prompt.len() - 1) * v..prompt.len() * v]) as u32;
    let mut toks = vec![next];
    for _ in 0..steps {
        let t = [next];
        let mut caches = [&mut cache];
        engine.decode_batch(&t, &mut caches, &mut ws).unwrap();
        next = Sampler::argmax(&ws.logits[..v]) as u32;
        toks.push(next);
    }
    (ws.logits[..v].to_vec(), toks)
}

#[test]
fn int8_kv_decode_logits_stay_close_to_f32_kv() {
    let mut engine =
        Engine::new(synthetic_model("mergequant", 64, 128, 2, 96));
    engine.ensure_kv_scales().unwrap();
    check(52, 10, |r: &mut Rng| {
        (0..r.usize(2, 24)).map(|_| r.usize(3, 95) as u32).collect::<Vec<u32>>()
    }, |prompt| {
        if prompt.len() < 2 {
            return Ok(());
        }
        let (f32_logits, _) = run_decode(&engine, prompt, 6, KvDtype::F32);
        let (i8_logits, _) = run_decode(&engine, prompt, 6, KvDtype::Int8);
        let scale = f32_logits.iter().fold(1e-6f32, |a, v| a.max(v.abs()));
        let worst = f32_logits
            .iter()
            .zip(&i8_logits)
            .fold(0f32, |a, (x, y)| a.max((x - y).abs()));
        // Per-channel static INT8 KV keeps relative drift small; the
        // bound is loose enough to be robust, tight enough to catch a
        // broken scale fold (which produces O(scale) garbage).
        if worst > 0.25 * scale {
            return Err(format!("drift {worst} vs logit scale {scale}"));
        }
        Ok(())
    });
}

#[test]
fn int8_kv_argmax_mostly_matches_f32_kv_teacher_forced() {
    // Drive both cache dtypes down the *same* token path (the f32-KV
    // greedy trajectory) so per-step argmaxes are comparable, then demand
    // majority agreement. A broken scale fold produces garbage logits
    // (~1/vocab agreement); honest int8 drift only flips near-ties.
    let mut engine =
        Engine::new(synthetic_model("mergequant", 64, 128, 2, 96));
    engine.ensure_kv_scales().unwrap();
    let engine = engine;
    let cfg = engine.config().clone();
    let prompt: Vec<u32> = (0..12).map(|i| 3 + (i * 7) % 90).collect();
    let steps = 24usize;
    let (_, path) = run_decode(&engine, &prompt, steps, KvDtype::F32);
    let v = cfg.vocab;
    let mut agree = 0usize;
    let mut total = 0usize;
    let mut argmaxes: Vec<Vec<usize>> = Vec::new();
    for kv in [KvDtype::F32, KvDtype::Int8] {
        let cap = prompt.len() + steps + 2;
        let mut cache =
            KvCache::with_dtype(kv, cfg.n_layers, cap, cfg.d_model);
        let mut ws = Workspace::new();
        engine.prefill(&prompt, &mut cache, &mut ws).unwrap();
        let mut maxes =
            vec![Sampler::argmax(
                &ws.logits[(prompt.len() - 1) * v..prompt.len() * v])];
        for &tok in &path[..steps] {
            let t = [tok];
            let mut caches = [&mut cache];
            engine.decode_batch(&t, &mut caches, &mut ws).unwrap();
            maxes.push(Sampler::argmax(&ws.logits[..v]));
        }
        argmaxes.push(maxes);
    }
    for (a, b) in argmaxes[0].iter().zip(&argmaxes[1]) {
        total += 1;
        agree += usize::from(a == b);
    }
    assert!(agree * 2 >= total,
            "int8-KV teacher-forced argmax agreement too low: \
             {agree}/{total}");
}

// ---------------------------------------------------------------------
// Bitwise determinism across thread counts (§7 extended to int8 KV)
// ---------------------------------------------------------------------

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn int8_kv_attention_bitwise_identical_across_threads_1_to_8() {
    // Probe-calibrate once so every thread count shares the same scales.
    let mut base =
        Engine::new(synthetic_model("mergequant", 128, 256, 2, 256));
    base.ensure_kv_scales().unwrap();
    let model = base.model;
    let prompt: Vec<u32> = (0..40).map(|i| 3 + (i * 11) % 250).collect();
    let cfg = model.config.clone();
    let kv = env_kv();
    let mut counts = vec![1usize, 2, 3, 4, 8];
    if let Some(t) = env_threads() {
        counts.push(t.max(1));
    }
    let mut reference: Option<(Vec<u32>, Vec<u32>)> = None;
    for threads in counts {
        let engine = Engine::with_threads(model.clone(), threads);
        let mut ws = Workspace::new();
        let mut caches: Vec<KvCache> = (0..3)
            .map(|_| KvCache::with_dtype(kv, cfg.n_layers, 96, cfg.d_model))
            .collect();
        engine.prefill(&prompt, &mut caches[0], &mut ws).unwrap();
        let prefill_bits = bits(&ws.logits[..prompt.len() * cfg.vocab]);
        engine.prefill(&prompt[..17], &mut caches[1], &mut ws).unwrap();
        engine.prefill(&prompt[..29], &mut caches[2], &mut ws).unwrap();
        let mut decode_bits = Vec::new();
        let mut toks = [5u32, 9, 11];
        for _ in 0..4 {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            engine.decode_batch(&toks, &mut refs, &mut ws).unwrap();
            decode_bits.extend(bits(&ws.logits[..3 * cfg.vocab]));
            for (i, t) in toks.iter_mut().enumerate() {
                *t = Sampler::argmax(
                    &ws.logits[i * cfg.vocab..(i + 1) * cfg.vocab]) as u32;
            }
        }
        match &reference {
            None => reference = Some((prefill_bits, decode_bits)),
            Some((p, d)) => {
                assert_eq!(&prefill_bits, p,
                           "int8-KV prefill differs at {threads} threads");
                assert_eq!(&decode_bits, d,
                           "int8-KV decode differs at {threads} threads");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Chunked prefill ≡ single-shot, both dtypes (per-row math is
// m-independent: same dots, same order, same epilogues)
// ---------------------------------------------------------------------

#[test]
fn chunked_prefill_identical_to_single_shot_for_both_kv_dtypes() {
    for mode in ["fp16", "mergequant", "rtn"] {
        let mut engine = Engine::new(synthetic_model(mode, 64, 128, 2, 96));
        engine.ensure_kv_scales().unwrap();
        let cfg = engine.config().clone();
        let toks: Vec<u32> = (0..33).map(|i| 3 + (i * 5) % 90).collect();
        for kv in [KvDtype::F32, KvDtype::Int8] {
            let mut ws = Workspace::new();
            let mut cache =
                KvCache::with_dtype(kv, cfg.n_layers, 40, cfg.d_model);
            engine.prefill(&toks, &mut cache, &mut ws).unwrap();
            let last_row = (toks.len() - 1) * cfg.vocab;
            let want = bits(&ws.logits[last_row..last_row + cfg.vocab]);
            for chunk in [1usize, 7, 32] {
                let mut c2 =
                    KvCache::with_dtype(kv, cfg.n_layers, 40, cfg.d_model);
                let mut ws2 = Workspace::new();
                let mut off = 0;
                let mut got = Vec::new();
                while off < toks.len() {
                    let end = (off + chunk).min(toks.len());
                    engine.prefill(&toks[off..end], &mut c2, &mut ws2)
                        .unwrap();
                    let rows = end - off;
                    got = bits(&ws2.logits
                        [(rows - 1) * cfg.vocab..rows * cfg.vocab]);
                    off = end;
                }
                assert_eq!(c2.len, toks.len());
                assert_eq!(got, want,
                           "{mode} kv {:?}: chunk {chunk} final logits \
                            differ from single-shot", kv);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Typed-error contract for bundles without calibrated scales
// ---------------------------------------------------------------------

#[test]
fn int8_cache_without_scales_is_typed_error() {
    // Synthetic models ship like pre-format-2 bundles: kv = None.
    let mut engine = Engine::new(synthetic_model("mergequant", 64, 128, 1, 96));
    assert!(engine.model.kv.is_none());
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let mut cache =
        KvCache::with_dtype(KvDtype::Int8, cfg.n_layers, 16, cfg.d_model);
    let err = engine.prefill(&[3, 4, 5], &mut cache, &mut ws).unwrap_err();
    assert_eq!(err, EngineError::MissingKvScales);
    // Probe calibration restores serviceability (and is a no-op after).
    engine.ensure_kv_scales().unwrap();
    assert!(engine.model.kv.is_some());
    engine.prefill(&[3, 4, 5], &mut cache, &mut ws).unwrap();
    assert_eq!(cache.len, 3);
}

// ---------------------------------------------------------------------
// Memory: int8 slabs really are 4× smaller
// ---------------------------------------------------------------------

#[test]
fn int8_cache_bytes_are_quarter_of_f32() {
    let f = KvCache::new(4, 128, 64);
    let q = KvCache::with_dtype(KvDtype::Int8, 4, 128, 64);
    assert_eq!(f.bytes(), 4 * q.bytes());
    assert!(f.bytes() as f64 / q.bytes() as f64 >= 3.5);
}
