//! Table 2: prefill speedup across batch sizes (paper: seq 2048, batch
//! 1–64, Llama-2-7B). Substrate scaling: seq 512, batch 1–16 on
//! tiny-llama-s; the reproduced quantity is the speedup column ordering
//! QuaRot < RTN < MergeQuant (QuaRot pays the online Hadamard, RTN pays
//! the quant pass, MergeQuant pays only the int8 gather).
//!
//! Second axis: intra-op **threads** on the same shape (DESIGN.md §7) —
//! the tiled parallel kernels must show real prefill scaling (target:
//! ≥ 2x at 4 threads vs the 1-thread baseline), with bitwise-identical
//! logits at every point.

mod common;

use mergequant::bench::Bench;
use mergequant::engine::{KvCache, Workspace};

const SEQ: usize = 512;

fn main() {
    let mut b = Bench::new("table2_prefill");
    let methods = ["fp16", "quarot", "rtn", "mergequant"];
    let batches: Vec<usize> =
        if std::env::var("MQ_BENCH_FAST").is_ok() { vec![1] }
        else { vec![1, 4, 8, 16] };
    for &batch in &batches {
        let mut times = std::collections::HashMap::new();
        for m in methods {
            let (engine, _) = common::engine_or_synthetic("tiny-llama-s", m);
            let cfg = engine.config().clone();
            let prompt: Vec<u32> = (0..SEQ)
                .map(|i| 3 + (i as u32 * 13) % (cfg.vocab as u32 - 3))
                .collect();
            let mut ws = Workspace::new();
            let mut caches: Vec<KvCache> = (0..batch)
                .map(|_| KvCache::new(cfg.n_layers, SEQ, cfg.d_model))
                .collect();
            let t = b.measure(&format!("{m} prefill b{batch} seq{SEQ}"), || {
                for c in caches.iter_mut() {
                    c.reset();
                    engine.prefill(&prompt, c, &mut ws).expect("bench prefill");
                }
            });
            times.insert(m, t);
        }
        for m in ["quarot", "rtn", "mergequant"] {
            b.record(&format!("{m} prefill_speedup_vs_fp16 b{batch}"),
                     times["fp16"] / times[m]);
        }
    }

    // ---- threads axis: same prefill shape, parallel-kernel scaling ----
    let threads: Vec<usize> =
        if std::env::var("MQ_BENCH_FAST").is_ok() { vec![1, 4] }
        else { vec![1, 2, 4, 8] };
    for m in ["mergequant", "fp16"] {
        let (mut engine, _) = common::engine_or_synthetic("tiny-llama-s", m);
        let cfg = engine.config().clone();
        let prompt: Vec<u32> = (0..SEQ)
            .map(|i| 3 + (i as u32 * 13) % (cfg.vocab as u32 - 3))
            .collect();
        let mut ws = Workspace::new();
        let mut t1 = f64::NAN;
        for &th in &threads {
            engine.set_threads(th);
            let mut cache = KvCache::new(cfg.n_layers, SEQ, cfg.d_model);
            let t = b.measure(&format!("{m} prefill seq{SEQ} threads{th}"),
                              || {
                cache.reset();
                engine.prefill(&prompt, &mut cache, &mut ws).expect("bench prefill");
            });
            if th == 1 {
                t1 = t;
            } else {
                b.record(&format!("{m} prefill_speedup t{th}_vs_t1"),
                         t1 / t);
            }
        }
    }
    b.finish("prefill speedup across batch sizes + threads (paper Table 2)");
}
