//! Fig. 3: decoding and end-to-end speedup vs batch size.
//!
//! Paper setup: Llama-2-7B, prefill 2048 + decode 256, batch 1…64, RTX
//! 3090. Our substrate: tiny-llama-s on the CPU integer-kernel engine,
//! prefill 256 + decode 64 (same 8:1 ratio), batch 1…32 — the *relative*
//! speedups of MergeQuant vs RTN-dynamic vs QuaRot-dynamic vs FP16 are the
//! reproduced quantity (DESIGN.md §2). Uses the full coordinator path so
//! batching behaviour matches serving reality.
//!
//! Second axis: intra-op **threads** at a fixed batch (DESIGN.md §7) —
//! batched decode fans out across batch lanes and output-column tiles,
//! so tok/s must scale with the pool while staying token-identical.
//!
//! Third axis: **KV-cache dtype** (DESIGN.md §10) — f32 vs statically-
//! quantized int8 KV at a fixed batch, measuring the integer-domain
//! attention path against the f32 baseline.
//!
//! Fourth axis: **ragged batching** (DESIGN.md §12) — a serving-shaped
//! mix of one chunked prefill admission riding with a full decode batch,
//! run as one `forward_batch` ragged call per iteration vs the
//! sequential seed shape (separate prefill + decode_batch calls). The
//! work is identical and bitwise equal; the unified call is what the
//! scheduler issues, so its win is the serving-iteration win.
//!
//! Fifth axis: **paged vs slab KV** (DESIGN.md §13) — concurrent
//! short-sequence capacity at equal KV arena bytes through the full
//! scheduler: block-granular allocation admits sequences proportionally
//! to the tokens they actually use instead of one `max_seq` reservation
//! each.
//!
//! Sixth axis: **shared-prefix fleet** (DESIGN.md §14) — N requests
//! over one system prompt with the radix prefix cache on vs off:
//! storing the prefix blocks once lifts admitted concurrency at a
//! tight arena, and skipping the matched prefill collapses TTFT.
//!
//! Seventh axis: **bursty mixed-priority fleet** (DESIGN.md §15) — a
//! high-class burst landing on a saturated arena with priority classes
//! on vs off: preemption collapses the burst's TTFT from
//! "wait out the whole low-class decode" to ~2 forward calls.

mod common;

use mergequant::bench::Bench;
use mergequant::engine::{
    BatchPlan, Engine, KvCache, KvDtype, SpanLogits, Workspace,
};

const PREFILL: usize = 256;
const DECODE: usize = 64;

/// One full request batch: prefill `batch` sequences then decode them
/// jointly for DECODE steps over `kv`-dtype caches. Returns
/// (decode_secs, e2e_secs).
fn run_batch(engine: &Engine, batch: usize, kv: KvDtype) -> (f64, f64) {
    let cfg = engine.config().clone();
    let mut ws = Workspace::new();
    let prompt: Vec<u32> =
        (0..PREFILL).map(|i| 3 + (i as u32 * 17) % (cfg.vocab as u32 - 3))
            .collect();
    let t0 = std::time::Instant::now();
    let mut caches: Vec<KvCache> = (0..batch)
        .map(|_| {
            let mut c = KvCache::with_dtype(
                kv, cfg.n_layers, PREFILL + DECODE + 2, cfg.d_model);
            engine.prefill(&prompt, &mut c, &mut ws).expect("bench prefill");
            c
        })
        .collect();
    let prefill_done = t0.elapsed();
    // Token selection through the serving contract's sampler (greedy ⇒
    // bitwise the seed argmax path), so the bench measures exactly what
    // `Server::generate` runs per decode step.
    let sampler = mergequant::engine::Sampler::greedy();
    let mut toks: Vec<u32> = vec![5; batch];
    for step in 0..DECODE {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        engine.decode_batch(&toks, &mut refs, &mut ws).expect("bench decode");
        let v = cfg.vocab;
        for i in 0..batch {
            toks[i] = sampler.sample(&ws.logits[i * v..(i + 1) * v],
                                     step as u64 + 1);
        }
    }
    let total = t0.elapsed();
    ((total - prefill_done).as_secs_f64(), total.as_secs_f64())
}

fn main() {
    let mut b = Bench::new("fig3_decode_e2e");
    let methods = ["fp16", "rtn", "quarot", "mergequant"];
    let batches: Vec<usize> =
        if std::env::var("MQ_BENCH_FAST").is_ok() { vec![1, 4] }
        else { vec![1, 4, 8, 16, 32] };
    for &batch in &batches {
        let mut decode_t = std::collections::HashMap::new();
        let mut e2e_t = std::collections::HashMap::new();
        for m in methods {
            let (engine, real) = common::engine_or_synthetic("tiny-llama-s", m);
            if !real && batch == batches[0] {
                eprintln!("note: {m} using synthetic weights (no artifacts)");
            }
            // one warmup, then best-of-N measured runs: small batches are
            // tens of ms and vulnerable to background interference.
            let _ = run_batch(&engine, batch.min(2), KvDtype::F32);
            let reps = if batch <= 4 { 3 } else { 1 };
            let (mut d, mut e) = (f64::INFINITY, f64::INFINITY);
            for _ in 0..reps {
                let (dr, er) = run_batch(&engine, batch, KvDtype::F32);
                d = d.min(dr);
                e = e.min(er);
            }
            decode_t.insert(m, d);
            e2e_t.insert(m, e);
            b.record(&format!("{m} decode_s b{batch}"), d);
            b.record(&format!("{m} decode_tok/s b{batch}"),
                     (batch * DECODE) as f64 / d);
        }
        for m in ["rtn", "quarot", "mergequant"] {
            b.record(&format!("{m} decode_speedup_vs_fp16 b{batch}"),
                     decode_t["fp16"] / decode_t[m]);
            b.record(&format!("{m} e2e_speedup_vs_fp16 b{batch}"),
                     e2e_t["fp16"] / e2e_t[m]);
        }
    }

    // ---- kv axis: fixed batch, f32 vs statically-quantized int8 KV ----
    const KV_BATCH: usize = 8;
    {
        let (mut engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                          "mergequant");
        // Pre-format-2 or synthetic bundle: probe-calibrate KV scales.
        engine.ensure_kv_scales().expect("probe calibration");
        let mut decode_t = std::collections::HashMap::new();
        for kv in [KvDtype::F32, KvDtype::Int8] {
            let _ = run_batch(&engine, 2, kv); // warmup
            let (mut d, mut e) = (f64::INFINITY, f64::INFINITY);
            for _ in 0..2 {
                let (dr, er) = run_batch(&engine, KV_BATCH, kv);
                d = d.min(dr);
                e = e.min(er);
            }
            let _ = e;
            decode_t.insert(kv.as_str(), d);
            b.record(&format!("mergequant decode_tok/s b{KV_BATCH} \
                               kv_{}", kv.as_str()),
                     (KV_BATCH * DECODE) as f64 / d);
        }
        b.record(&format!("mergequant decode_int8kv_vs_f32kv b{KV_BATCH}"),
                 decode_t["f32"] / decode_t["int8"]);
    }

    // ---- ragged axis: mixed prefill+decode, one call vs sequential ----
    {
        const LANES: usize = 7;
        const CHUNK: usize = 32;
        let (engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                      "mergequant");
        let run_mixed = |unified: bool| -> f64 {
            let cfg = engine.config().clone();
            let mut ws = Workspace::new();
            let prompt: Vec<u32> = (0..PREFILL)
                .map(|i| 3 + (i as u32 * 17) % (cfg.vocab as u32 - 3))
                .collect();
            let cap = PREFILL + DECODE + 2;
            // Lane 0 is the incoming admission (prefilled CHUNK tokens
            // per iteration); lanes 1..=LANES decode from full depth.
            let mut caches: Vec<KvCache> = (0..LANES + 1)
                .map(|i| {
                    let mut c = KvCache::new(cfg.n_layers, cap, cfg.d_model);
                    if i > 0 {
                        engine.prefill(&prompt, &mut c, &mut ws)
                            .expect("bench prefill");
                    }
                    c
                })
                .collect();
            let sampler = mergequant::engine::Sampler::greedy();
            let mut toks: Vec<u32> = vec![5; LANES];
            let mut consumed = 0usize;
            let v = cfg.vocab;
            let t0 = std::time::Instant::now();
            for step in 0..DECODE {
                let end = (consumed + CHUNK).min(PREFILL);
                if unified {
                    let mut plan = BatchPlan::new();
                    if consumed < end {
                        plan.push_span(0, &prompt[consumed..end],
                                       SpanLogits::None);
                    }
                    for (i, &t) in toks.iter().enumerate() {
                        plan.push_span(i + 1, std::slice::from_ref(&t),
                                       SpanLogits::Last);
                    }
                    let mut refs: Vec<&mut KvCache> =
                        caches.iter_mut().collect();
                    engine.forward_batch(&plan, &mut refs, &mut ws)
                        .expect("bench ragged forward");
                } else {
                    if consumed < end {
                        engine.prefill(&prompt[consumed..end],
                                       &mut caches[0], &mut ws)
                            .expect("bench chunk prefill");
                    }
                    let mut refs: Vec<&mut KvCache> =
                        caches.iter_mut().skip(1).collect();
                    engine.decode_batch(&toks, &mut refs, &mut ws)
                        .expect("bench decode");
                }
                consumed = end;
                // Decode rows are the trailing LANES logits rows in both
                // modes (the prefill span emits none / is a separate
                // call), so token selection is identical.
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = sampler.sample(&ws.logits[i * v..(i + 1) * v],
                                        step as u64 + 1);
                }
            }
            t0.elapsed().as_secs_f64()
        };
        let mut uni = f64::INFINITY;
        let mut seq = f64::INFINITY;
        let _ = run_mixed(true); // warmup
        for _ in 0..2 {
            uni = uni.min(run_mixed(true));
            seq = seq.min(run_mixed(false));
        }
        let rows = (LANES * DECODE + PREFILL) as f64;
        b.record(&format!("mergequant ragged rows/s lanes{LANES} \
                           chunk{CHUNK} unified"), rows / uni);
        b.record(&format!("mergequant ragged rows/s lanes{LANES} \
                           chunk{CHUNK} sequential"), rows / seq);
        b.record(&format!("mergequant ragged unified_vs_sequential \
                           lanes{LANES} chunk{CHUNK}"), seq / uni);
    }

    // ---- paged axis: concurrent short sequences at equal arena bytes
    // (DESIGN.md §13) — the serving win paged allocation buys: a slab
    // arena of 8 × 512-token reservations admits at most 8 sequences no
    // matter how short they are; the same bytes as 32-token blocks
    // admit one sequence per ~1 block. Recorded: peak concurrent live
    // sequences, throughput, and the scheduler's kv_util packing.
    {
        use mergequant::coordinator::{Request, Scheduler, SchedulerConfig};
        const SHORT_PROMPT: usize = 20;
        const SHORT_NEW: usize = 8;
        const N_SHORT: usize = 192;
        let run_capacity = |kv_block: usize| -> (usize, f64, f64) {
            let (engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                          "mergequant");
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig {
                    max_batch: 256,
                    kv_slabs: 8,      // arena = 8 × 512 tokens either way
                    kv_block,
                    kv_blocks: 0,
                    max_seq: 512,
                    max_prefills_per_iter: 64,
                    queue_cap: N_SHORT,
                    prefill_chunk: 0,
                    threads: 1,
                    kv_dtype: KvDtype::F32,
                    prefix_cache: false,
                    prefix_cache_blocks: 0,
                    max_decode_latency: 0,
                    speculative: false,
                    draft_k: 0,
                    draft_layers: 0,
                },
            );
            let vocab = sched.engine().config().vocab as u32;
            for i in 0..N_SHORT as u64 {
                let prompt: Vec<u32> = (0..SHORT_PROMPT)
                    .map(|t| 3 + (t as u32 * 13 + i as u32) % (vocab - 3))
                    .collect();
                sched.submit(Request::new(i, prompt, SHORT_NEW)).unwrap();
            }
            let t0 = std::time::Instant::now();
            let mut peak = 0usize;
            while sched.has_work() {
                sched.step();
                peak = peak.max(sched.active_len() + sched.prefilling_len());
            }
            let wall = t0.elapsed().as_secs_f64();
            let toks = sched.metrics.generated_tokens as f64;
            (peak, toks / wall, sched.metrics.kv_util_mean())
        };
        let (slab_peak, slab_tps, slab_util) = run_capacity(0);
        let (paged_peak, paged_tps, paged_util) = run_capacity(32);
        b.record("slab concurrent_short_seqs", slab_peak as f64);
        b.record("paged concurrent_short_seqs kvblock32", paged_peak as f64);
        b.record("paged_vs_slab concurrency_at_equal_bytes",
                 paged_peak as f64 / slab_peak as f64);
        b.record("slab short_seq gen_tok/s", slab_tps);
        b.record("paged short_seq gen_tok/s kvblock32", paged_tps);
        b.record("slab kv_util_mean", slab_util);
        b.record("paged kv_util_mean kvblock32", paged_util);
    }

    // ---- prefix axis: shared-prefix fleet, radix cache + CoW blocks
    // (DESIGN.md §14) — a fleet over one system prompt. Sharing stores
    // the 192-token prefix once (6 blocks) instead of per lane, so the
    // 48-block arena admits the whole fleet; matched prefixes skip
    // their prefill, so TTFT collapses toward one decode step.
    {
        use mergequant::coordinator::{Request, Scheduler, SchedulerConfig};
        const FLEET: usize = 24;
        const FLEET_PREFIX: usize = 192;
        const FLEET_SUFFIX: usize = 8;
        const FLEET_NEW: usize = 16;
        let run_fleet = |prefix: bool| -> (usize, f64, f64, f64) {
            let (engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                          "mergequant");
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig {
                    max_batch: 64,
                    kv_slabs: 0,
                    kv_block: 32,
                    kv_blocks: 48, // 1536 tokens: ~6 unshared lanes
                    max_seq: 512,
                    max_prefills_per_iter: 1,
                    queue_cap: FLEET,
                    prefill_chunk: 0,
                    threads: 1,
                    kv_dtype: KvDtype::F32,
                    prefix_cache: prefix,
                    prefix_cache_blocks: 0,
                    max_decode_latency: 0,
                    speculative: false,
                    draft_k: 0,
                    draft_layers: 0,
                },
            );
            let vocab = sched.engine().config().vocab as u32;
            for i in 0..FLEET as u64 {
                let mut prompt: Vec<u32> = (0..FLEET_PREFIX)
                    .map(|t| 3 + (t as u32 * 7) % (vocab - 3))
                    .collect();
                prompt.extend((0..FLEET_SUFFIX).map(|t| {
                    5 + (t as u32 * 11 + i as u32) % (vocab - 3)
                }));
                sched.submit(Request::new(i, prompt, FLEET_NEW)).unwrap();
            }
            let t0 = std::time::Instant::now();
            let mut peak = 0usize;
            while sched.has_work() {
                sched.step();
                peak = peak.max(sched.active_len() + sched.prefilling_len());
            }
            let wall = t0.elapsed().as_secs_f64();
            let m = &sched.metrics;
            (peak, m.prefix_hit_rate(), m.ttft_summary().p50,
             m.generated_tokens as f64 / wall)
        };
        let (u_peak, _, u_ttft, u_tps) = run_fleet(false);
        let (s_peak, hit, s_ttft, s_tps) = run_fleet(true);
        b.record("unshared fleet concurrent_lanes", u_peak as f64);
        b.record("shared fleet concurrent_lanes prefix192", s_peak as f64);
        b.record("shared_vs_unshared fleet concurrency",
                 s_peak as f64 / u_peak as f64);
        b.record("shared fleet prefix_hit_rate", hit);
        b.record("unshared fleet ttft_p50_ms", u_ttft * 1e3);
        b.record("shared fleet ttft_p50_ms", s_ttft * 1e3);
        b.record("unshared fleet gen_tok/s", u_tps);
        b.record("shared fleet gen_tok/s", s_tps);
        b.record("shared_vs_unshared fleet ttft_p50", u_ttft / s_ttft);
    }

    // ---- preemption axis: bursty mixed-priority fleet (DESIGN.md §15)
    // — a long low-class decode lane holds a 4-block arena when a
    // high-class burst arrives. With classes, the burst preempts the
    // lane and its first token lands ~2 forward calls after arrival;
    // without, it queues behind the whole decode. Recorded: the burst's
    // wall-clock TTFT both ways and the preemption count (the victim's
    // stream is bitwise unchanged — tests/preemption.rs pins that).
    {
        use mergequant::coordinator::{
            GenerationParams, Request, Scheduler, SchedulerConfig,
        };
        let run_burst = |classed: bool| -> (f64, u64) {
            let (engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                          "mergequant");
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig {
                    max_batch: 4,
                    kv_slabs: 0,
                    kv_block: 16,
                    kv_blocks: 4,
                    max_seq: 64,
                    max_prefills_per_iter: 2,
                    queue_cap: 16,
                    prefill_chunk: 0,
                    threads: 1,
                    kv_dtype: KvDtype::F32,
                    prefix_cache: false,
                    prefix_cache_blocks: 0,
                    max_decode_latency: 0,
                    speculative: false,
                    draft_k: 0,
                    draft_layers: 0,
                },
            );
            let vocab = sched.engine().config().vocab as u32;
            let low: Vec<u32> = (0..16)
                .map(|t| 3 + (t as u32 * 7) % (vocab - 3)).collect();
            let high: Vec<u32> = (0..33)
                .map(|t| 5 + (t as u32 * 3) % (vocab - 3)).collect();
            sched.submit(Request::new(0, low, 40)).unwrap();
            sched.step();
            sched.step();
            let burst_at = std::time::Instant::now();
            sched.submit(Request::with_params(1, high, GenerationParams {
                priority: if classed { 2 } else { 0 },
                ..GenerationParams::greedy(4)
            })).unwrap();
            let mut ttft = f64::NAN;
            while sched.has_work() {
                sched.step();
                for ev in sched.take_events() {
                    use mergequant::coordinator::Event;
                    if ttft.is_nan()
                        && matches!(ev, Event::Token { id: 1, .. })
                    {
                        ttft = burst_at.elapsed().as_secs_f64();
                    }
                }
            }
            (ttft, sched.metrics.preemptions)
        };
        let (c_ttft, c_preempt) = run_burst(true);
        let (u_ttft, u_preempt) = run_burst(false);
        b.record("classed burst ttft_ms", c_ttft * 1e3);
        b.record("unclassed burst ttft_ms", u_ttft * 1e3);
        b.record("classed_vs_unclassed burst ttft", u_ttft / c_ttft);
        b.record("classed burst preemptions", c_preempt as f64);
        b.record("unclassed burst preemptions", u_preempt as f64);
    }

    // ---- threads axis: fixed batch 8, parallel-kernel scaling ----
    let threads: Vec<usize> =
        if std::env::var("MQ_BENCH_FAST").is_ok() { vec![1, 4] }
        else { vec![1, 2, 4, 8] };
    const TH_BATCH: usize = 8;
    let (mut engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                      "mergequant");
    let (mut d1, mut e1) = (f64::NAN, f64::NAN);
    for &th in &threads {
        engine.set_threads(th);
        let _ = run_batch(&engine, 2, KvDtype::F32); // warmup
        let (mut d, mut e) = (f64::INFINITY, f64::INFINITY);
        for _ in 0..2 {
            let (dr, er) = run_batch(&engine, TH_BATCH, KvDtype::F32);
            d = d.min(dr);
            e = e.min(er);
        }
        b.record(&format!("mergequant decode_tok/s b{TH_BATCH} threads{th}"),
                 (TH_BATCH * DECODE) as f64 / d);
        if th == 1 {
            d1 = d;
            e1 = e;
        } else {
            b.record(&format!("mergequant decode_speedup b{TH_BATCH} \
                               t{th}_vs_t1"), d1 / d);
            b.record(&format!("mergequant e2e_speedup b{TH_BATCH} \
                               t{th}_vs_t1"), e1 / e);
        }
    }
    b.finish("decode + e2e speedup vs batch size + threads + kv dtype \
              (paper Fig. 3)");
}
