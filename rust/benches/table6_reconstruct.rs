//! Table 6: dimension reconstruction vs dynamic quantization step latency.
//!
//! Exactly the paper's sweep — batch {1,16,32} × hidden {4096,5120,8192} ×
//! sequence {1,128,256} — on the raw ops (no model): the per-token dynamic
//! Quant pass (read f32, absmax-reduce, divide, round, write int8) against
//! MergeQuant's only runtime addition, the reconstruction gather over an
//! int8 tensor. Expect gather to win by ~1.5–3×, matching the paper's
//! 1.54×–2.96× column.

use mergequant::bench::Bench;
use mergequant::quant::dynamic::per_token_quant;
use mergequant::quant::reconstruct::reconstruct_i8;
use mergequant::util::rng::Rng;

fn main() {
    let mut b = Bench::new("table6_reconstruct");
    let mut rng = Rng::new(6);
    for &batch in &[1usize, 16, 32] {
        for &hidden in &[4096usize, 5120, 8192] {
            for &seqlen in &[1usize, 128, 256] {
                let m = batch * seqlen;
                let x: Vec<f32> =
                    (0..m * hidden).map(|_| rng.normal() * 2.0).collect();
                let xq_src: Vec<i8> = (0..m * hidden)
                    .map(|_| rng.usize(0, 15) as i8 - 7)
                    .collect();
                let idx: Vec<u32> = (0..hidden)
                    .map(|_| rng.usize(0, hidden) as u32)
                    .collect();
                let mut xq = vec![0i8; m * hidden];
                let mut scales = vec![0f32; m];
                let mut out = vec![0i8; m * hidden];

                let t_dyn = b.measure(
                    &format!("dynamic_quant b{batch} h{hidden} s{seqlen}"),
                    || per_token_quant(&x, m, hidden, 7, 1.0, &mut xq,
                                       &mut scales),
                );
                let t_rec = b.measure(
                    &format!("reconstruction b{batch} h{hidden} s{seqlen}"),
                    || reconstruct_i8(&xq_src, &idx, m, hidden, &mut out),
                );
                b.record(
                    &format!("speedup b{batch} h{hidden} s{seqlen}"),
                    t_dyn / t_rec,
                );
            }
        }
    }
    b.finish("dimension reconstruction vs dynamic quant step (paper Table 6)");
}
