//! Table 4: the component ablation on the Llama-3 stand-in — QuaRot with
//! per-tensor static calibration, then +QSM (per-channel static), then
//! +adaptive clipping, then +LoRA compensation (= full MergeQuant).

mod common;

use mergequant::bench::Bench;

const ROWS: [(&str, &str); 5] = [
    ("FP16", "fp16"),
    ("QuaRot & Static", "quarot_static"),
    ("+ QSM", "mq_qsm_only"),
    ("+ Clipping", "mq_qsm_clip"),
    ("+ LoRA fine-tuning (full MergeQuant)", "mergequant"),
];

fn main() {
    let mut b = Bench::new("table4_ablation");
    if !mergequant::bench::artifacts_ready() {
        eprintln!("table4 requires `make artifacts`; skipping");
        b.finish("SKIPPED (no artifacts)");
        return;
    }
    for (label, method) in ROWS {
        match common::try_engine("tiny-llama3", method) {
            Some(engine) => common::accuracy_row(&mut b, &engine, label),
            None => eprintln!("missing bundle tiny-llama3/{method}"),
        }
    }
    b.finish("QSM / clipping / LoRA ablation on tiny-llama3 (paper Table 4)");
}
