//! Table 1: the main accuracy comparison — perplexity on both corpora and
//! the five zero-shot tasks, for every method row the paper reports per
//! model. Mirrors the paper's row structure exactly (Llama-2-70B gets the
//! reduced method set, Llama-3 only the rotation trio + MergeQuant).
//!
//! Budget knobs: MQ_EVAL_TOKENS (default 6144), MQ_TASK_ITEMS (default
//! 40), MQ_TABLE1_MODELS (comma list, default "tiny-llama-s,tiny-llama3").

mod common;

use mergequant::bench::Bench;

const PLAN: [(&str, &[&str]); 4] = [
    ("tiny-llama-s",
     &["fp16", "smoothquant", "omniquant", "qllm", "quarot_nh",
       "spinquant_nh", "mergequant_nh", "quarot", "spinquant", "mergequant"]),
    ("tiny-llama-m",
     &["fp16", "smoothquant", "omniquant", "qllm", "quarot_nh",
       "spinquant_nh", "mergequant_nh", "quarot", "spinquant", "mergequant"]),
    ("tiny-llama-l",
     &["fp16", "smoothquant", "qllm", "quarot_nh", "mergequant_nh",
       "quarot", "spinquant", "mergequant"]),
    ("tiny-llama3", &["fp16", "quarot", "spinquant", "mergequant"]),
];

fn main() {
    let models_env = std::env::var("MQ_TABLE1_MODELS")
        .unwrap_or_else(|_| "tiny-llama-s,tiny-llama3".into());
    let selected: Vec<&str> = models_env.split(',').collect();
    let mut b = Bench::new("table1_main");
    if !mergequant::bench::artifacts_ready() {
        eprintln!("table1 requires `make artifacts`; skipping");
        b.finish("SKIPPED (no artifacts)");
        return;
    }
    for (model, methods) in PLAN {
        if !selected.contains(&model) {
            continue;
        }
        for m in methods {
            match common::try_engine(model, m) {
                Some(engine) => {
                    common::accuracy_row(&mut b, &engine,
                                         &format!("{model}/{m}"));
                }
                None => eprintln!("missing bundle {model}/{m}; skipped"),
            }
        }
    }
    b.finish("PPL + zero-shot accuracy, all methods (paper Table 1)");
}
