//! Table 7: clipping ablation (no clipping / naive channel clipping /
//! adaptive clipping) under activation-only quantization, PPL on both
//! corpora. Run on the smallest and the hardest-to-quantize models.

mod common;

use mergequant::bench::Bench;

const ROWS: [(&str, &str); 4] = [
    ("FP16", "fp16"),
    ("No-clipping", "mq_noclip"),
    ("Channel-clipping", "mq_channelclip"),
    ("Adaptive clipping", "mq_adaptiveclip"),
];

fn main() {
    let mut b = Bench::new("table7_clipping");
    if !mergequant::bench::artifacts_ready() {
        eprintln!("table7 requires `make artifacts`; skipping");
        b.finish("SKIPPED (no artifacts)");
        return;
    }
    for model in ["tiny-llama-s", "tiny-llama3"] {
        for (label, method) in ROWS {
            match common::try_engine(model, method) {
                Some(engine) => {
                    let mut sum = 0.0;
                    let mut k = 0;
                    for c in ["synth-wiki", "synth-c4"] {
                        if let Some(p) = common::eval_ppl(&engine, c) {
                            b.record(&format!("{model} {label} ppl[{c}]"), p);
                            sum += p;
                            k += 1;
                        }
                    }
                    if k == 2 {
                        b.record(&format!("{model} {label} ppl[avg]"),
                                 sum / 2.0);
                    }
                }
                None => eprintln!("missing bundle {model}/{method}"),
            }
        }
    }
    b.finish("clipping component ablation (paper Table 7)");
}
