//! Shared helpers for the per-table bench binaries.

#![allow(dead_code)]

use mergequant::engine::{Engine, QModel};
use mergequant::{artifacts_dir, bench};

/// Load a trained bundle, or `None` when artifacts are absent.
/// `rtn` aliases the `pertoken_dynamic` bundle (same method, Fig.-1 name).
pub fn try_engine(model: &str, method: &str) -> Option<Engine> {
    let file = if method == "rtn" { "pertoken_dynamic" } else { method };
    let p = artifacts_dir()
        .join("models")
        .join(model)
        .join(format!("{file}.qmod"));
    if !p.exists() {
        return None;
    }
    QModel::load(&p).ok().map(Engine::new)
}

/// Load a bundle, falling back to a synthetic model of the same mode
/// family so speed benches run on a fresh checkout.
pub fn engine_or_synthetic(model: &str, method: &str) -> (Engine, bool) {
    if let Some(e) = try_engine(model, method) {
        return (e, true);
    }
    let mode = match method {
        "fp16" => "fp16",
        "rtn" => "rtn",
        m if m.starts_with("quarot") => "quarot",
        _ => "mergequant",
    };
    (Engine::new(bench::synthetic_model(mode, 128, 512, 4, 512)), false)
}

/// Eval budget knobs (env-tunable so the full run can be scaled).
pub fn eval_tokens() -> usize {
    std::env::var("MQ_EVAL_TOKENS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4096)
}

pub fn task_items() -> usize {
    std::env::var("MQ_TASK_ITEMS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
}

pub fn eval_ppl(engine: &Engine, corpus: &str) -> Option<f64> {
    let toks =
        mergequant::eval::corpus::val_stream(&artifacts_dir(), corpus).ok()?;
    let n = eval_tokens().min(toks.len());
    Some(mergequant::eval::perplexity(engine, &toks[..n], 256))
}

pub fn eval_task(engine: &Engine, task: &str) -> Option<f64> {
    let items = mergequant::eval::parse_task(
        &mergequant::eval::corpus::load_json(
            &artifacts_dir().join("tasks").join(format!("{task}.json")),
        )
        .ok()?,
    )
    .ok()?;
    let n = task_items().min(items.len());
    Some(mergequant::eval::choice_accuracy(engine, &items[..n]))
}

pub const TASKS: [&str; 5] =
    ["piqa", "arc-e", "arc-c", "hellaswag", "winogrande"];

/// Paper-style accuracy row: ppl on both corpora + 5 task accuracies.
pub fn accuracy_row(b: &mut mergequant::bench::Bench, engine: &Engine,
                    label: &str) {
    let mut ppl_sum = 0.0;
    for c in ["synth-wiki", "synth-c4"] {
        if let Some(p) = eval_ppl(engine, c) {
            b.record(&format!("{label} ppl[{c}]"), p);
            ppl_sum += p;
        }
    }
    b.record(&format!("{label} ppl[avg]"), ppl_sum / 2.0);
    let mut accs = Vec::new();
    for t in TASKS {
        if let Some(a) = eval_task(engine, t) {
            b.record(&format!("{label} acc[{t}]"), a * 100.0);
            accs.push(a);
        }
    }
    if !accs.is_empty() {
        b.record(&format!("{label} acc[avg]"),
                 accs.iter().sum::<f64>() / accs.len() as f64 * 100.0);
    }
}
