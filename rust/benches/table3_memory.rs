//! Table 3: memory usage for decoding one token (batch 1, seq 2048).
//!
//! Two views: (a) measured resident bytes of the loaded tiny bundles
//! (weights + KV + workspace), (b) the same accounting formulas projected
//! onto Llama-2-7B dimensions — the paper's absolute column (FP16 ≈ 13.9
//! GB, QuaRot 4.16, RTN 3.90, MergeQuant 3.87; saving ≈ 3.58×). Plus the
//! paged-vs-slab axis (DESIGN.md §13): bytes a short sequence actually
//! pins under block-granular vs whole-slab reservation.

mod common;

use mergequant::bench::Bench;
use mergequant::engine::memory::{account_model, project, projected_kv_bytes,
                                 MethodKind, LLAMA2_7B};
use mergequant::engine::{KvCache, KvDtype};

fn main() {
    let mut b = Bench::new("table3_memory");

    // (a) measured on the tiny bundles
    for m in ["fp16", "rtn", "quarot", "mergequant"] {
        if let Some(engine) = common::try_engine("tiny-llama-s", m) {
            let mb = account_model(&engine.model, 1, 2048, KvDtype::F32);
            b.record(&format!("measured {m} total_MB"),
                     mb.total() as f64 / 1e6);
            b.record(&format!("measured {m} weights_MB"),
                     mb.weights as f64 / 1e6);
            b.record(&format!("measured {m} dyn_overhead_KB"),
                     mb.dynamic_overhead as f64 / 1e3);
        }
    }

    // (a') resident KV bytes vs cache dtype (DESIGN.md §10) — measured on
    // real slabs and on the accounting formulas; int8 storage is exactly
    // 4× smaller per slab (scales live with the weights, not per slab).
    {
        let (engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                      "mergequant");
        let cfg = engine.config().clone();
        let slab = |kv| KvCache::with_dtype(kv, cfg.n_layers, 2048,
                                            cfg.d_model).bytes();
        let (f32b, i8b) = (slab(KvDtype::F32), slab(KvDtype::Int8));
        b.record("measured kv_slab f32_MB", f32b as f64 / 1e6);
        b.record("measured kv_slab int8_MB", i8b as f64 / 1e6);
        b.record("kv int8 reduction_factor", f32b as f64 / i8b as f64);
        for kv in [KvDtype::F32, KvDtype::Int8] {
            let mb = account_model(&engine.model, 1, 2048, kv);
            b.record(&format!("measured mergequant kv_{} total_MB",
                              kv.as_str()),
                     mb.total() as f64 / 1e6);
        }
    }

    // (a'') paged vs slab reservation bytes (DESIGN.md §13): what a
    // short sequence actually pins in the arena. A slab cache reserves
    // the full max_seq plane up front; a paged cache holds only
    // ⌈len/kv_block⌉ blocks — measured on real caches, both dtypes.
    {
        let (mut engine, _) = common::engine_or_synthetic("tiny-llama-s",
                                                          "mergequant");
        engine.ensure_kv_scales().expect("probe calibration");
        let cfg = engine.config().clone();
        const MAX_SEQ: usize = 2048;
        const SHORT: usize = 24; // a 20-token chat + a few decode steps
        const BLOCK: usize = 32;
        let mut ws = mergequant::engine::Workspace::new();
        let prompt: Vec<u32> = (0..SHORT)
            .map(|i| 3 + (i as u32 * 17) % (cfg.vocab as u32 - 3))
            .collect();
        for kv in [KvDtype::F32, KvDtype::Int8] {
            let slab =
                KvCache::with_dtype(kv, cfg.n_layers, MAX_SEQ, cfg.d_model);
            let mut paged = KvCache::paged(kv, cfg.n_layers, MAX_SEQ,
                                           cfg.d_model, BLOCK);
            engine.prefill(&prompt, &mut paged, &mut ws)
                .expect("bench prefill");
            b.record(&format!("reserved per short seq slab kv_{} KB",
                              kv.as_str()),
                     slab.bytes() as f64 / 1e3);
            b.record(&format!("reserved per short seq paged kv_{} KB",
                              kv.as_str()),
                     paged.bytes() as f64 / 1e3);
            b.record(&format!("paged_vs_slab reservation_factor kv_{}",
                              kv.as_str()),
                     slab.bytes() as f64 / paged.bytes() as f64);
        }
    }

    // (b) projected Llama-2-7B (paper's absolute numbers)
    let fp = project(&LLAMA2_7B, &MethodKind::Fp16, 1, 2048, 16).total();
    b.record("7B fp16 GB", fp as f64 / 1e9);
    for (name, kind) in [("quarot", MethodKind::QuarotDynamic),
                         ("rtn", MethodKind::RtnDynamic),
                         ("mergequant", MethodKind::MergeQuant)] {
        let t = project(&LLAMA2_7B, &kind, 1, 2048, 4).total();
        b.record(&format!("7B {name} GB"), t as f64 / 1e9);
        b.record(&format!("7B {name} saving_factor"), fp as f64 / t as f64);
    }

    // (c) paper-scale KV projection: fp16 KV (paper baseline) vs static
    // INT8 KV at Llama-2-7B dimensions, long-context batch serving.
    for (batch, seq) in [(1usize, 2048usize), (32, 4096)] {
        let fp16 = projected_kv_bytes(&LLAMA2_7B, batch, seq, 2);
        let int8 = projected_kv_bytes(&LLAMA2_7B, batch, seq, 1);
        b.record(&format!("7B kv fp16 b{batch} s{seq} GB"),
                 fp16 as f64 / 1e9);
        b.record(&format!("7B kv int8 b{batch} s{seq} GB"),
                 int8 as f64 / 1e9);
    }
    b.finish("memory for single-token decode, batch 1 seq 2048 (paper Table 3)");
}
