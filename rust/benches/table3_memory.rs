//! Table 3: memory usage for decoding one token (batch 1, seq 2048).
//!
//! Two views: (a) measured resident bytes of the loaded tiny bundles
//! (weights + KV + workspace), (b) the same accounting formulas projected
//! onto Llama-2-7B dimensions — the paper's absolute column (FP16 ≈ 13.9
//! GB, QuaRot 4.16, RTN 3.90, MergeQuant 3.87; saving ≈ 3.58×).

mod common;

use mergequant::bench::Bench;
use mergequant::engine::memory::{account_model, project, MethodKind,
                                 LLAMA2_7B};

fn main() {
    let mut b = Bench::new("table3_memory");

    // (a) measured on the tiny bundles
    for m in ["fp16", "rtn", "quarot", "mergequant"] {
        if let Some(engine) = common::try_engine("tiny-llama-s", m) {
            let mb = account_model(&engine.model, 1, 2048);
            b.record(&format!("measured {m} total_MB"),
                     mb.total() as f64 / 1e6);
            b.record(&format!("measured {m} weights_MB"),
                     mb.weights as f64 / 1e6);
            b.record(&format!("measured {m} dyn_overhead_KB"),
                     mb.dynamic_overhead as f64 / 1e3);
        }
    }

    // (b) projected Llama-2-7B (paper's absolute numbers)
    let fp = project(&LLAMA2_7B, &MethodKind::Fp16, 1, 2048, 16).total();
    b.record("7B fp16 GB", fp as f64 / 1e9);
    for (name, kind) in [("quarot", MethodKind::QuarotDynamic),
                         ("rtn", MethodKind::RtnDynamic),
                         ("mergequant", MethodKind::MergeQuant)] {
        let t = project(&LLAMA2_7B, &kind, 1, 2048, 4).total();
        b.record(&format!("7B {name} GB"), t as f64 / 1e9);
        b.record(&format!("7B {name} saving_factor"), fp as f64 / t as f64);
    }
    b.finish("memory for single-token decode, batch 1 seq 2048 (paper Table 3)");
}
