//! Table 5: W3A4 — 3-bit asymmetric and grouped weight quantization on
//! the Llama-2-7B stand-in, QuaRot vs MergeQuant.

mod common;

use mergequant::bench::Bench;

const ROWS: [(&str, &str); 5] = [
    ("FP16", "fp16"),
    ("QuaRot w3-asym", "quarot_w3_asym"),
    ("QuaRot w3-group", "quarot_w3_group"),
    ("MergeQuant w3-asym", "mergequant_w3_asym"),
    ("MergeQuant w3-group", "mergequant_w3_group"),
];

fn main() {
    let mut b = Bench::new("table5_w3a4");
    if !mergequant::bench::artifacts_ready() {
        eprintln!("table5 requires `make artifacts`; skipping");
        b.finish("SKIPPED (no artifacts)");
        return;
    }
    for (label, method) in ROWS {
        match common::try_engine("tiny-llama-s", method) {
            Some(engine) => common::accuracy_row(&mut b, &engine, label),
            None => eprintln!("missing bundle tiny-llama-s/{method}"),
        }
    }
    b.finish("W3A4 weight-quantization variants (paper Table 5)");
}
