//! Fig. 1: accuracy of per-tensor / per-token / per-channel calibration,
//! with and without rotation, on the PIQA-like task (plus PPL for
//! context). The reproduced claim: under 4-bit symmetric quantization only
//! per-channel calibration holds accuracy; per-tensor collapses even with
//! rotation; per-token needs rotation and still cannot be made static.

mod common;

use mergequant::bench::Bench;

const VARIANTS: [(&str, &str); 6] = [
    ("per-tensor static", "pertensor_static"),
    ("per-tensor static + rotation", "quarot_static"),
    ("per-token dynamic", "pertoken_dynamic"),
    ("per-token dynamic + rotation", "pertoken_dynamic_rot"),
    ("per-channel static (QSM)", "perchannel_static"),
    ("per-channel static full (MergeQuant_nh)", "mergequant_nh"),
];

fn main() {
    let mut b = Bench::new("fig1_calibration");
    if !mergequant::bench::artifacts_ready() {
        eprintln!("fig1 requires `make artifacts`; skipping");
        b.finish("SKIPPED (no artifacts)");
        return;
    }
    let model = "tiny-llama-s";
    if let Some(engine) = common::try_engine(model, "fp16") {
        if let Some(acc) = common::eval_task(&engine, "piqa") {
            b.record("fp16 acc[piqa]", acc * 100.0);
        }
        if let Some(p) = common::eval_ppl(&engine, "synth-wiki") {
            b.record("fp16 ppl[synth-wiki]", p);
        }
    }
    for (label, method) in VARIANTS {
        match common::try_engine(model, method) {
            Some(engine) => {
                if let Some(acc) = common::eval_task(&engine, "piqa") {
                    b.record(&format!("{label} acc[piqa]"), acc * 100.0);
                }
                if let Some(p) = common::eval_ppl(&engine, "synth-wiki") {
                    b.record(&format!("{label} ppl[synth-wiki]"), p);
                }
            }
            None => eprintln!("missing bundle {model}/{method}"),
        }
    }
    b.finish("calibration granularity comparison on PIQA (paper Fig. 1)");
}
